#!/usr/bin/env bash
# Run a list of `repro testnet` scenarios (names without .toml, resolved
# under configs/testnet/) and tee one "scenario <name>: PASS|FAIL" line
# per run into the GitHub step summary.  Every scenario runs even after
# one fails; the script exits nonzero if any failed.
set -uo pipefail

SUMMARY="${GITHUB_STEP_SUMMARY:-/dev/null}"
REPRO=./target/release/repro
status=0

for name in "$@"; do
  echo "::group::scenario $name"
  if "$REPRO" testnet --scenario "configs/testnet/$name.toml" \
      --out results/testnet; then
    echo "scenario $name: PASS" | tee -a "$SUMMARY"
  else
    echo "scenario $name: FAIL" | tee -a "$SUMMARY"
    # The per-process logs are the only diagnostics once the fleet is
    # reaped — surface them in the failing leg's output.
    for log in "results/testnet/$name"/*.log; do
      [ -f "$log" ] || continue
      echo "--- $log (tail) ---"
      tail -n 40 "$log"
    done
    status=1
  fi
  echo "::endgroup::"
done

exit "$status"
