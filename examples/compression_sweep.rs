//! Fig. 3 / Table 2: the accuracy-vs-compression trade-off of Local
//! Zampling across weight degrees d.
//!
//!     cargo run --release --example compression_sweep [-- --scale paper]
//!
//! `--scale ci` (default) runs a minutes-scale grid; `--scale paper` is
//! the full §3.1 sweep (d ∈ {1,5,10,50,100} × m/n = 2^0..2^10, 5 seeds).

use zampling::experiments::{compression_sweep, Scale};
use zampling::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(&args.str_or("scale", "ci")).expect("scale");
    let cells = compression_sweep::run(scale);
    compression_sweep::print_table(&cells);
    // The headline trend of Fig. 3: roughly constant drop per doubling.
    println!("\nper-doubling accuracy drop (d=5 row):");
    let row: Vec<_> = cells.iter().filter(|c| c.d == 5).collect();
    for pair in row.windows(2) {
        println!(
            "  m/n {:>4} -> {:>4}: {:+.2} pts",
            pair[0].factor,
            pair[1].factor,
            (pair[1].mean_sampled_acc - pair[0].mean_sampled_acc) * 100.0
        );
    }
}
