//! Fig. 4 + Table 1: the END-TO-END DRIVER (DESIGN.md §validation).
//!
//! Runs Federated Zampling at m/n ∈ {1, 8, 32} plus the FedAvg and FedPM
//! baselines on the MNIST-like task, logging the accuracy curve per round
//! and the Table 1 savings factors.
//!
//!     cargo run --release --example federated_mnist [-- --scale paper]
//!                                                   [--rounds N] [--clients K]
//!
//! At `--scale paper` this is the paper's §3.2 configuration (MnistFc,
//! m = 266,610, 10 clients, 100 rounds); `ci` shrinks to minutes.

use std::path::Path;

use zampling::experiments::federated::{
    fed_config, load_fed_data, print_table1, run_fedavg_row, run_fedpm_row,
    run_zampling_row_with, Table1Row,
};
use zampling::experiments::Scale;
use zampling::runtime::PjrtRuntime;
use zampling::util::cli::Args;
use zampling::zampling::{DenseExecutor, NativeExecutor};

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(&args.str_or("scale", "ci")).expect("scale");
    let rounds_override = args.get("rounds").map(|r| r.parse::<usize>().expect("rounds"));
    let clients_override = args.get("clients").map(|c| c.parse::<usize>().expect("clients"));
    let eval_every = args.usize_or("eval-every", if scale == Scale::Ci { 2 } else { 5 });

    let mut rows: Vec<Table1Row> = Vec::new();
    println!("== baselines ==");
    rows.push(run_fedavg_row(scale, eval_every));
    rows.push(run_fedpm_row(scale, eval_every));

    for factor in [1usize, 8, 32] {
        let mut cfg = fed_config(factor, scale);
        if let Some(r) = rounds_override {
            cfg.rounds = r;
        }
        if let Some(c) = clients_override {
            cfg.clients = c;
        }
        let (shards, test) = load_fed_data(&cfg);
        println!(
            "== federated zampling m/n={} (n={}) clients={} rounds={} ==",
            factor, cfg.train.n, cfg.clients, cfg.rounds
        );
        // Three-layer path when artifacts exist; native oracle otherwise.
        let row = match PjrtRuntime::new(Path::new("artifacts")) {
            Ok(rt) => {
                let mut exec = rt.dense_executor(&cfg.train.arch.name).expect("pjrt exec");
                run_zampling_row_with(&cfg, &mut exec, &shards, &test, scale, eval_every)
            }
            Err(_) => {
                let mut exec =
                    NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
                run_zampling_row_with(
                    &cfg,
                    &mut exec as &mut dyn DenseExecutor as &mut dyn DenseExecutor,
                    &shards,
                    &test,
                    scale,
                    eval_every,
                )
            }
        };
        for r in &row.log.rounds {
            println!(
                "  round {:>3}  sampled {:.4} ± {:.4}  expected {:.4}",
                r.round, r.mean_sampled_acc, r.sampled_acc_std, r.expected_acc
            );
        }
        row.log.save(Path::new("results")).expect("save log");
        rows.push(row);
    }

    print_table1(&rows);
}
