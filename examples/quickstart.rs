//! Quickstart: train Local Zampling on the small architecture in under a
//! minute, through the real three-layer path when artifacts are present
//! (PJRT + AOT HLO) and the pure-Rust oracle otherwise.
//!
//!     cargo run --release --example quickstart
//!
//! What it demonstrates:
//!   * Q generation from a seed (Eq. 1) at compression m/n = 4, d = 5;
//!   * training-by-sampling (z ~ Bern(p), w = Qz, ∇_s = Qᵀ∇_w ⊙ gate);
//!   * the §3 metrics: mean-sampled / expected / discretized accuracy.

use std::path::Path;

use zampling::config::TrainConfig;
use zampling::data::Dataset;
use zampling::nn::ArchSpec;
use zampling::rng::SeedTree;
use zampling::runtime::PjrtRuntime;
use zampling::zampling::{train_local, DenseExecutor, NativeExecutor};

fn main() {
    let mut cfg = TrainConfig::local(ArchSpec::small(), 4, 5, 0);
    // Quickstart budget: a few thousand rows, a dozen epochs.  The larger
    // lr compensates the reduced step count vs the paper's 100 epochs on
    // 60k rows (DESIGN.md §4).
    cfg.train_rows = 4_000;
    cfg.test_rows = 1_000;
    cfg.epochs = 12;
    cfg.lr = 0.05;

    let seeds = SeedTree::new(cfg.seed);
    let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
    println!(
        "zampling quickstart: m={} n={} (m/n={:.0}) d={}",
        cfg.arch.num_params(),
        cfg.n,
        cfg.compression_factor(),
        cfg.d
    );

    // Prefer the real path: PJRT over the AOT artifacts.
    let mut exec: Box<dyn DenseExecutor> = match PjrtRuntime::new(Path::new("artifacts")) {
        Ok(rt) => {
            println!("backend: pjrt ({})", rt.platform());
            Box::new(rt.dense_executor("small").expect("dense executor"))
        }
        Err(e) => {
            println!("backend: native (pjrt unavailable: {e:#})");
            Box::new(NativeExecutor::new(cfg.arch.clone(), cfg.batch, 500))
        }
    };

    let out = train_local(&cfg, exec.as_mut(), &train, &test, 50);
    for e in &out.epochs {
        println!(
            "epoch {:>2}  train_loss {:.4}  val_loss {:.4}  val_acc {:.4}",
            e.epoch, e.train_loss, e.val_loss, e.val_acc
        );
    }
    println!(
        "\nfinal: mean_sampled {:.4} ± {:.4}   expected {:.4}   best {:.4}   discretized {:.4}",
        out.report.mean_sampled_acc,
        out.report.sampled_acc_std,
        out.report.expected_acc,
        out.report.best_sampled_acc,
        out.report.discretized_acc
    );
    let nontrivial = out.probs.iter().filter(|&&p| p > 0.0 && p < 1.0).count();
    println!(
        "p*: {} of {} coordinates non-trivial (dim C_0+), mean {:.3}",
        nontrivial,
        out.probs.len(),
        out.probs.iter().sum::<f32>() / out.probs.len() as f32
    );
    println!(
        "uplink cost if federated: {} bits vs naive {} bits ({}x)",
        cfg.n,
        32 * cfg.arch.num_params(),
        32 * cfg.arch.num_params() / cfg.n
    );
}
