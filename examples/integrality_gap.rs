//! Fig. 5 (Appendix A): the integrality gap — train the expected network
//! WITHOUT sampling from Beta(α,α) initializations and watch the sampled
//! network collapse unless the init is extreme.
//!
//!     cargo run --release --example integrality_gap [-- --scale paper]

use zampling::experiments::{integrality_gap, Scale};
use zampling::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(&args.str_or("scale", "ci")).expect("scale");
    let points = integrality_gap::run(scale);
    integrality_gap::print_figure(&points);
    println!("\n(the gap column is the Fig. 5 blue-vs-red separation; small α pins");
    println!(" p near {{0,1}} and closes it, α → 1 reopens it)");
}
