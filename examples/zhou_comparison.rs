//! Fig. 6 (Appendix B.1): Local Zampling vs the Zhou et al. supermask
//! baseline, best-of-100-masks metric.
//!
//!     cargo run --release --example zhou_comparison [-- --scale paper]

use zampling::experiments::{zhou_comparison, Scale};
use zampling::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(&args.str_or("scale", "ci")).expect("scale");
    let bars = zhou_comparison::run(scale);
    zhou_comparison::print_figure(&bars);
}
