//! Table 4: generalisation via parameter sensitivity — perturb the
//! learned p on its non-trivial C_τ coordinates and compare the sampled
//! vs regular (no-sampling) training regimes.
//!
//!     cargo run --release --example sensitivity [-- --scale paper]

use zampling::experiments::{sensitivity, Scale};
use zampling::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(&args.str_or("scale", "ci")).expect("scale");
    let seed = args.u64_or("seed", 0);
    let rows = sensitivity::run(scale, seed);
    sensitivity::print_table(&rows);

    // The paper's headline: sampled training is orders of magnitude less
    // sensitive than regular training for τ < 0.5.
    let mean = |regime: &str| {
        let xs: Vec<f64> = rows
            .iter()
            .filter(|r| r.regime == regime && r.tau < 0.5)
            .map(|r| r.avg_sensitivity)
            .collect();
        xs.iter().sum::<f64>() / xs.len() as f64
    };
    println!(
        "\nmean sensitivity (τ<0.5): regular {:.4} vs sampled {:.4} ({}x more robust)",
        mean("Regular"),
        mean("Sampled"),
        (mean("Regular") / mean("Sampled").max(1e-9)) as u64
    );
}
