"""L1 Pallas kernels for the Zampling hot-spot (``w = Qz`` / ``g_s = Qᵀg_w``)."""

from .qz_gather import qz_matvec
from .qt_gather import qt_matvec
from . import ref

__all__ = ["qz_matvec", "qt_matvec", "ref"]
