"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest suite checks the Pallas kernels
against (``assert_allclose``).  They are also what the L2 model falls back
to when ``use_pallas=False`` so the dense/fused artifact split can be
validated end-to-end without the kernels in the loop.

Sparse layout
-------------
The influence matrix ``Q ∈ R^{m×n}`` (Eq. 1 of the paper) is carried in two
padded gather layouts, both produced by the Rust ``sparse`` module and fed
to the fused artifact as runtime buffers:

* row layout  (CSR-like): ``rid[m, d]`` int32 column indices and
  ``rv[m, d]`` float32 values — exactly ``d`` non-zeros per row by
  construction, so no padding is needed.
* column layout (padded CSC): ``cid[n, c]`` int32 row indices and
  ``cv[n, c]`` float32 values, padded with ``(0, 0.0)`` up to the max
  column degree ``c``; padding contributes ``0 * g_w[0] = 0``.
"""

from __future__ import annotations

import jax.numpy as jnp


def qz_matvec_ref(rid: jnp.ndarray, rv: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Reference ``w = Q z`` over the row gather layout.

    Args:
      rid: ``[m, d]`` int32 — column index of each stored entry.
      rv:  ``[m, d]`` float32 — value of each stored entry.
      z:   ``[n]`` float32 — mask / probability vector.

    Returns:
      ``[m]`` float32 — ``w_i = sum_k rv[i, k] * z[rid[i, k]]``.
    """
    return jnp.sum(rv * z[rid], axis=1)


def qt_matvec_ref(cid: jnp.ndarray, cv: jnp.ndarray, g_w: jnp.ndarray) -> jnp.ndarray:
    """Reference ``g_s = Qᵀ g_w`` over the padded column gather layout.

    Args:
      cid: ``[n, c]`` int32 — row index of each stored entry (0-padded).
      cv:  ``[n, c]`` float32 — value of each stored entry (0.0-padded).
      g_w: ``[m]`` float32 — upstream gradient w.r.t. the weights.

    Returns:
      ``[n]`` float32 — ``g_s_j = sum_k cv[j, k] * g_w[cid[j, k]]``.
    """
    return jnp.sum(cv * g_w[cid], axis=1)


def dense_q_from_row_layout(rid: jnp.ndarray, rv: jnp.ndarray, n: int) -> jnp.ndarray:
    """Materialize the dense ``[m, n]`` Q from the row gather layout.

    Only used in tests (small shapes) to cross-check both sparse oracles
    against plain dense matmuls.
    """
    m, d = rid.shape
    q = jnp.zeros((m, n), dtype=rv.dtype)
    rows = jnp.repeat(jnp.arange(m), d)
    return q.at[rows, rid.reshape(-1)].add(rv.reshape(-1))
