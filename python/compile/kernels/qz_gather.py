"""L1 Pallas kernel: ``w = Q z`` as a blocked VMEM-resident gather.

The Zampling hot-spot.  ``Q`` is stored row-major as exactly-``d``-entry
gather rows (``rid[m, d]`` indices into ``z``, ``rv[m, d]`` values); the
kernel tiles the ``m`` rows over a 1-D grid and keeps the full mask ``z``
in VMEM (``n ≤ m`` and even the flagship MnistFc ``n = m = 266,610`` is
~1 MiB as f32 — far under the ~16 MiB VMEM budget).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the GPU-native version of
this op stages ``z`` in shared memory per threadblock; on TPU the analogue
is a whole-vector VMEM residency with row tiles streamed HBM→VMEM by the
BlockSpec pipeline.  The gather itself is VPU work (no MXU), so the roof is
memory bandwidth on the ``rid``/``rv`` streams: 8 bytes per stored entry.

Lowered with ``interpret=True`` everywhere in this repo — the CPU PJRT
plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step.  8 sublanes × 128 lanes is the native f32 VPU tile;
# 512 rows keeps the per-step VMEM traffic (512·d·8 B) comfortably inside
# the pipeline's double-buffering budget for every d used in the paper
# (d ≤ 256 → ≤ 1 MiB/step) while amortizing grid overhead.
DEFAULT_TILE_M = 512


def _qz_kernel(z_ref, rid_ref, rv_ref, w_ref):
    """One grid step: rows [i*TILE_M, (i+1)*TILE_M) of ``w = Q z``.

    ``z_ref`` is the full mask in VMEM (index_map pins block 0 for every
    step, so the pipeline loads it once); ``rid_ref``/``rv_ref`` are the
    row tile; the gather+multiply+row-sum is a pure VPU expression.
    """
    z = z_ref[...]
    rid = rid_ref[...]
    rv = rv_ref[...]
    w_ref[...] = jnp.sum(rv * z[rid], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def qz_matvec(
    rid: jnp.ndarray,
    rv: jnp.ndarray,
    z: jnp.ndarray,
    *,
    tile_m: int = DEFAULT_TILE_M,
) -> jnp.ndarray:
    """Compute ``w = Q z`` with the Pallas gather kernel.

    Args:
      rid: ``[m, d]`` int32 column indices (one row of Q per row).
      rv:  ``[m, d]`` float32 values.
      z:   ``[n]`` float32 mask or probability vector.
      tile_m: rows per grid step; ``m`` is padded up to a multiple.

    Returns:
      ``[m]`` float32 weight vector.
    """
    m, d = rid.shape
    (n,) = z.shape
    # Pad the row count so the grid divides evenly; padded rows gather
    # z[0] * 0.0 and are sliced off at the end.
    m_pad = (-m) % tile_m
    if m_pad:
        rid = jnp.concatenate([rid, jnp.zeros((m_pad, d), rid.dtype)], axis=0)
        rv = jnp.concatenate([rv, jnp.zeros((m_pad, d), rv.dtype)], axis=0)
    grid = (rid.shape[0] // tile_m,)

    w = pl.pallas_call(
        _qz_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),          # z: whole vector, every step
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),  # rid: row tile
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),  # rv: row tile
        ],
        out_specs=pl.BlockSpec((tile_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rid.shape[0],), rv.dtype),
        interpret=True,
    )(z, rid, rv)
    return w[:m]


def vmem_bytes_per_step(d: int, n: int, tile_m: int = DEFAULT_TILE_M) -> int:
    """Static VMEM footprint estimate of one grid step (for DESIGN.md §Perf).

    z (n·4) + rid tile (tile_m·d·4) + rv tile (tile_m·d·4) + out (tile_m·4),
    ×2 for the pipeline's double buffering of the streamed operands.
    """
    streamed = 2 * (tile_m * d * 4 * 2 + tile_m * 4)
    resident = n * 4
    return streamed + resident
