"""L1 Pallas kernel: ``g_s = Qᵀ g_w`` as a padded-CSC gather.

The backward half of the Zampling hot-spot: the chain rule through
``w = Q z`` needs the transpose product.  A scatter-add over ``g_w`` would
need atomics (GPU idiom); the TPU idiom is to pre-transpose the layout —
the Rust ``sparse`` module exports a padded CSC (``cid[n, c]`` row indices
and ``cv[n, c]`` values, zero-padded to the max column degree ``c``) — and
run the *same* gather shape as the forward kernel, over ``g_w`` instead of
``z``.  Padding entries contribute ``0.0 * g_w[0] = 0``.

Like the forward kernel, the gradient vector ``g_w`` (m·4 bytes ≈ 1 MiB for
MnistFc) is VMEM-resident across the grid while column tiles stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512


def _qt_kernel(gw_ref, cid_ref, cv_ref, gs_ref):
    """One grid step: entries [i*TILE_N, (i+1)*TILE_N) of ``g_s = Qᵀ g_w``."""
    g_w = gw_ref[...]
    cid = cid_ref[...]
    cv = cv_ref[...]
    gs_ref[...] = jnp.sum(cv * g_w[cid], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def qt_matvec(
    cid: jnp.ndarray,
    cv: jnp.ndarray,
    g_w: jnp.ndarray,
    *,
    tile_n: int = DEFAULT_TILE_N,
) -> jnp.ndarray:
    """Compute ``g_s = Qᵀ g_w`` with the Pallas transpose-gather kernel.

    Args:
      cid: ``[n, c]`` int32 row indices (padded CSC of Q).
      cv:  ``[n, c]`` float32 values (0.0 in padding slots).
      g_w: ``[m]`` float32 upstream weight gradient.
      tile_n: columns per grid step; ``n`` is padded up to a multiple.

    Returns:
      ``[n]`` float32 score gradient.
    """
    n, c = cid.shape
    (m,) = g_w.shape
    n_pad = (-n) % tile_n
    if n_pad:
        cid = jnp.concatenate([cid, jnp.zeros((n_pad, c), cid.dtype)], axis=0)
        cv = jnp.concatenate([cv, jnp.zeros((n_pad, c), cv.dtype)], axis=0)
    grid = (cid.shape[0] // tile_n,)

    g_s = pl.pallas_call(
        _qt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),          # g_w: whole vector
            pl.BlockSpec((tile_n, c), lambda i: (i, 0)),  # cid: column tile
            pl.BlockSpec((tile_n, c), lambda i: (i, 0)),  # cv: column tile
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((cid.shape[0],), cv.dtype),
        interpret=True,
    )(g_w, cid, cv)
    return g_s[:n]
