"""L2: the paper's model (MLP fwd/bwd + softmax-CE) in JAX.

Two architectures, exactly as §3 "Experimental Constant":

* ``SMALL_ARCH``  — 784-20-20-10 feedforward ("two hidden layers and twenty
  neurons per layer"), used for the compression (Fig. 3 / Table 2) and
  sensitivity (Table 4) experiments.
* ``MNISTFC``     — 784-300-100-10 ("exactly as the one in Zhou"), used in
  the federated experiments (Fig. 4 / Table 1) and the Zhou comparison.

Three jitted entry points are AOT-lowered by ``aot.py``:

* ``train_step(w, x, y1h)``      → ``(loss, grad_w, correct)`` — the dense
  path.  Independent of ``(n, d)``: the Rust coordinator owns the sparse
  ``w = Qz`` / ``g_s = Qᵀ g_w ⊙ 1{0<p<1}`` wrapping, so one artifact per
  architecture serves every compression level.
* ``eval_step(w, x, y1h)``       → ``(loss, correct)``.
* ``fused_train_step(z, rid, rv, cid, cv, x, y1h)`` → ``(loss, grad_s,
  correct)`` — the three-layer flagship: the L1 Pallas gather kernels are
  lowered *into* the artifact via a ``jax.custom_vjp`` pair, so the rust
  hot path feeds masks directly.

Weight layout: the flat ``w[m]`` packs each layer as ``W_l`` (row-major,
``[fan_in, fan_out]``) followed by ``b_l``; the Rust ``nn::ArchSpec`` uses
the identical packing so fan-in values for the σ_i of Eq. (1) line up.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import qt_matvec, qz_matvec
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class Arch:
    """Feedforward architecture description (mirrors rust ``nn::ArchSpec``)."""

    name: str
    layers: tuple  # (in, h1, ..., out)

    @property
    def num_params(self) -> int:
        m = 0
        for fi, fo in zip(self.layers[:-1], self.layers[1:]):
            m += fi * fo + fo
        return m

    def slices(self):
        """Yield ``(offset, fan_in, fan_out, w_len, b_len)`` per layer."""
        off = 0
        for fi, fo in zip(self.layers[:-1], self.layers[1:]):
            yield off, fi, fo, fi * fo, fo
            off += fi * fo + fo


SMALL_ARCH = Arch("small", (784, 20, 20, 10))
MNISTFC = Arch("mnistfc", (784, 300, 100, 10))
ARCHS = {a.name: a for a in (SMALL_ARCH, MNISTFC)}

# m = 266,610 for MNISTFC — matches the paper's §3.2 figure exactly.
assert MNISTFC.num_params == 266_610, MNISTFC.num_params
assert SMALL_ARCH.num_params == 784 * 20 + 20 + 20 * 20 + 20 + 20 * 10 + 10


def unflatten(arch: Arch, w: jnp.ndarray):
    """Split the flat parameter vector into per-layer ``(W, b)`` pairs."""
    params = []
    for off, fi, fo, wl, bl in arch.slices():
        W = w[off : off + wl].reshape(fi, fo)
        b = w[off + wl : off + wl + bl]
        params.append((W, b))
    return params


def forward(arch: Arch, w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """MLP forward pass → logits ``[B, 10]`` (ReLU hidden, linear head)."""
    params = unflatten(arch, w)
    h = x
    for i, (W, b) in enumerate(params):
        h = h @ W + b
        if i + 1 < len(params):
            h = jax.nn.relu(h)
    return h


def loss_and_correct(arch: Arch, w: jnp.ndarray, x: jnp.ndarray, y1h: jnp.ndarray):
    """Weighted-mean softmax cross-entropy and number of correct predictions.

    Labels arrive one-hot (``y1h[B, 10]`` f32) so the artifact signature is
    all-float — the rust side one-hots labels when staging batches.
    Rows whose one-hot sums to zero are *padding* (rust zero-pads partial
    batches to the artifact's fixed batch size): they contribute nothing to
    the loss denominator or the correct count.
    """
    logits = forward(arch, w, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    roww = jnp.sum(y1h, axis=-1)  # 1.0 real row, 0.0 padding
    denom = jnp.maximum(jnp.sum(roww), 1.0)
    loss = jnp.sum(-jnp.sum(y1h * logp, axis=-1)) / denom
    match = (jnp.argmax(logits, axis=-1) == jnp.argmax(y1h, axis=-1)).astype(
        jnp.float32
    )
    correct = jnp.sum(match * roww)
    return loss, correct


def make_train_step(arch: Arch) -> Callable:
    """Dense train step: ``(w, x, y1h) → (loss, grad_w, correct)``."""

    def step(w, x, y1h):
        (loss, correct), grad_w = jax.value_and_grad(
            lambda w_: loss_and_correct(arch, w_, x, y1h), has_aux=True
        )(w)
        return loss, grad_w, correct

    return step


def make_eval_step(arch: Arch) -> Callable:
    """Eval step: ``(w, x, y1h) → (loss, correct)``."""

    def step(w, x, y1h):
        return loss_and_correct(arch, w, x, y1h)

    return step


def _sparse_pair(use_pallas: bool):
    if use_pallas:
        return qz_matvec, qt_matvec
    return kref.qz_matvec_ref, kref.qt_matvec_ref


def make_qz_with_vjp(use_pallas: bool = True):
    """``w = Qz`` with a custom VJP routing the cotangent through Qᵀ.

    The sparse layouts (row gather + padded CSC) are non-differentiable
    constants; the VJP w.r.t. ``z`` is exactly the transpose gather kernel,
    so both L1 kernels end up inside the lowered fused artifact.
    """
    fwd_k, bwd_k = _sparse_pair(use_pallas)

    @jax.custom_vjp
    def qz(z, rid, rv, cid, cv):
        return fwd_k(rid, rv, z)

    def qz_fwd(z, rid, rv, cid, cv):
        return fwd_k(rid, rv, z), (cid, cv)

    def qz_bwd(res, g_w):
        cid, cv = res
        g_z = bwd_k(cid, cv, g_w)
        return (g_z, None, None, None, None)

    qz.defvjp(qz_fwd, qz_bwd)
    return qz


def make_fused_train_step(arch: Arch, use_pallas: bool = True) -> Callable:
    """Fused step: mask in, score-gradient out, Pallas kernels inside.

    ``(z, rid, rv, cid, cv, x, y1h) → (loss, grad_s_raw, correct)``.
    The returned gradient is the *raw* ``Qᵀ ∇_w L``; the coordinator applies
    the paper's straight-through indicator ``⊙ 1{0 < p < 1}`` (it owns ``p``).
    """
    qz = make_qz_with_vjp(use_pallas)

    def step(z, rid, rv, cid, cv, x, y1h):
        def loss_fn(z_):
            w = qz(z_, rid, rv, cid, cv)
            return loss_and_correct(arch, w, x, y1h)

        (loss, correct), grad_s = jax.value_and_grad(loss_fn, has_aux=True)(z)
        return loss, grad_s, correct

    return step


def init_weights_kaiming(arch: Arch, key) -> jnp.ndarray:
    """He-normal init of the flat weight vector (baseline/FedAvg paths)."""
    parts = []
    for off, fi, fo, wl, bl in arch.slices():
        key, sub = jax.random.split(key)
        parts.append(jax.random.normal(sub, (wl,)) * jnp.sqrt(2.0 / fi))
        parts.append(jnp.zeros((bl,)))
    return jnp.concatenate(parts)
