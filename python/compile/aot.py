"""AOT compile path: lower every artifact to HLO *text* + a JSON manifest.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the Rust
runtime (`rust/src/runtime/`) loads the text with
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shapes recorded in ``artifacts/manifest.json``):

* ``train_step_{arch}.hlo.txt``  (w, x[B,784], y1h[B,10]) → (loss, grad_w, correct)
* ``eval_step_{arch}.hlo.txt``   (w, x[E,784], y1h[E,10]) → (loss, correct)
* ``fused_step_{arch}_n{n}_d{d}.hlo.txt``
      (z[n], rid[m,d] i32, rv[m,d], cid[n,c] i32, cv[n,c], x, y1h)
      → (loss, grad_s_raw, correct)   — L1 Pallas kernels lowered inside.

The padded-CSC width ``c`` must match between this file and the Rust
``sparse::csc_pad_width`` — both implement the same closed-form bound.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

TRAIN_BATCH = 128  # §3 Experimental Constant: "batches of size 128"
EVAL_BATCH = 500   # divides both 60k and 10k; eval is throughput-bound

# Flagship fused configs: the federated experiment grid of §3.2
# (MnistFc, d = 10, m/n ∈ {1, 8, 32}) plus a small-arch smoke config.
FUSED_CONFIGS = [
    ("small", 8, 4),     # (arch, compression m/n, d) — smoke / tests
    ("mnistfc", 1, 10),
    ("mnistfc", 8, 10),
    ("mnistfc", 32, 10),
]


def csc_pad_width(m: int, n: int, d: int) -> int:
    """Padded CSC width: a high-probability bound on the max column degree.

    Column degrees are Binomial(m, d/n) (d draws/row without replacement,
    uniform columns); mean μ = m·d/n.  μ + 6√μ + 16, rounded up to a
    multiple of 8, bounds the max of n such binomials except with
    negligible probability.  Rust's ``sparse::csc_pad_width`` MUST match.
    """
    mu = m * d / n
    return int(math.ceil((mu + 6.0 * math.sqrt(mu) + 16.0) / 8.0) * 8)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_train_step(arch: M.Arch, batch: int) -> str:
    step = M.make_train_step(arch)
    lowered = jax.jit(step).lower(
        _spec((arch.num_params,)),
        _spec((batch, arch.layers[0])),
        _spec((batch, arch.layers[-1])),
    )
    return to_hlo_text(lowered)


def lower_eval_step(arch: M.Arch, batch: int) -> str:
    step = M.make_eval_step(arch)
    lowered = jax.jit(step).lower(
        _spec((arch.num_params,)),
        _spec((batch, arch.layers[0])),
        _spec((batch, arch.layers[-1])),
    )
    return to_hlo_text(lowered)


def lower_fused_step(arch: M.Arch, n: int, d: int, batch: int, use_pallas: bool) -> str:
    m = arch.num_params
    c = csc_pad_width(m, n, d)
    step = M.make_fused_train_step(arch, use_pallas=use_pallas)
    lowered = jax.jit(step).lower(
        _spec((n,)),
        _spec((m, d), jnp.int32),
        _spec((m, d)),
        _spec((n, c), jnp.int32),
        _spec((n, c)),
        _spec((batch, arch.layers[0])),
        _spec((batch, arch.layers[-1])),
    )
    return to_hlo_text(lowered)


def _write(path: str, text: str) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"path": os.path.basename(path), "sha256_16": digest, "bytes": len(text)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower fused steps through the pure-jnp reference instead of "
        "the Pallas kernels (debug aid; artifacts are numerically identical)",
    )
    ap.add_argument(
        "--skip-fused",
        action="store_true",
        help="only dense train/eval artifacts (fast CI path)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "archs": {},
        "fused": [],
    }

    for arch in M.ARCHS.values():
        print(f"[aot] {arch.name}: m={arch.num_params} layers={arch.layers}")
        t = _write(
            os.path.join(args.out_dir, f"train_step_{arch.name}.hlo.txt"),
            lower_train_step(arch, TRAIN_BATCH),
        )
        e = _write(
            os.path.join(args.out_dir, f"eval_step_{arch.name}.hlo.txt"),
            lower_eval_step(arch, EVAL_BATCH),
        )
        manifest["archs"][arch.name] = {
            "layers": list(arch.layers),
            "num_params": arch.num_params,
            "train": t,
            "eval": e,
        }

    if not args.skip_fused:
        for arch_name, factor, d in FUSED_CONFIGS:
            arch = M.ARCHS[arch_name]
            m = arch.num_params
            n = m // factor
            c = csc_pad_width(m, n, d)
            print(f"[aot] fused {arch_name} n={n} (m/n={factor}) d={d} c={c}")
            f = _write(
                os.path.join(
                    args.out_dir, f"fused_step_{arch_name}_n{n}_d{d}.hlo.txt"
                ),
                lower_fused_step(arch, n, d, TRAIN_BATCH, not args.no_pallas),
            )
            manifest["fused"].append(
                {
                    "arch": arch_name,
                    "n": n,
                    "d": d,
                    "c": c,
                    "compression": factor,
                    "pallas": not args.no_pallas,
                    **f,
                }
            )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
