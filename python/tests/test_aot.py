"""AOT path: lowering produces parseable HLO text with the right signature."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_csc_pad_width_monotone_and_sufficient():
    # larger d → wider pad; pad always exceeds the binomial mean m*d/n
    for m, n in [(16_330, 2041), (266_610, 8331), (1000, 1000)]:
        prev = 0
        for d in (1, 2, 5, 10, 50):
            c = aot.csc_pad_width(m, n, d)
            assert c % 8 == 0
            assert c > m * d / n
            assert c >= prev
            prev = c


def test_lower_train_step_small_produces_hlo():
    text = aot.lower_train_step(M.SMALL_ARCH, batch=8)
    assert "HloModule" in text
    # entry computation carries the three f32 params and tuple of three results
    assert "f32[16330]" in text  # w and grad_w
    assert "f32[8,784]" in text


def test_lower_eval_step_small_produces_hlo():
    text = aot.lower_eval_step(M.SMALL_ARCH, batch=8)
    assert "HloModule" in text
    assert "f32[8,10]" in text


@pytest.mark.parametrize("use_pallas", [False, True])
def test_lower_fused_step_small(use_pallas):
    n, d = M.SMALL_ARCH.num_params // 8, 4
    text = aot.lower_fused_step(M.SMALL_ARCH, n=n, d=d, batch=8, use_pallas=use_pallas)
    assert "HloModule" in text
    assert f"f32[{n}]" in text  # z and grad_s
    assert f"s32[{M.SMALL_ARCH.num_params},{d}]" in text  # rid


def test_manifest_written(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv",
        ["aot", "--out-dir", str(tmp_path), "--skip-fused"],
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest["archs"]) == {"small", "mnistfc"}
    for a in manifest["archs"].values():
        assert (tmp_path / a["train"]["path"]).exists()
        assert (tmp_path / a["eval"]["path"]).exists()
