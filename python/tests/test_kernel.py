"""L1 correctness: Pallas kernels vs the pure-jnp oracle (and dense Q).

This is the core correctness signal for the kernels that end up inside the
fused HLO artifacts.  Hypothesis sweeps shapes (m, n, d, tile sizes) and
mask dtypes; every case asserts allclose against ``ref.py`` and, for small
shapes, against a dense-Q matmul.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qt_gather, qz_gather, ref

jax.config.update("jax_enable_x64", False)


def make_row_layout(rng, m, n, d):
    """Random row gather layout: d distinct column ids per row, N(0,1) vals."""
    rid = np.stack([rng.choice(n, size=d, replace=False) for _ in range(m)]).astype(
        np.int32
    )
    rv = rng.standard_normal((m, d)).astype(np.float32)
    return rid, rv


def row_to_padded_csc(rid, rv, n):
    """Transpose the row layout into the padded CSC the backward kernel uses."""
    m, d = rid.shape
    cols = [[] for _ in range(n)]
    for i in range(m):
        for k in range(d):
            cols[rid[i, k]].append((i, rv[i, k]))
    c = max(1, max(len(col) for col in cols))
    cid = np.zeros((n, c), dtype=np.int32)
    cv = np.zeros((n, c), dtype=np.float32)
    for j, col in enumerate(cols):
        for k, (i, v) in enumerate(col):
            cid[j, k] = i
            cv[j, k] = v
    return cid, cv


# ---------------------------------------------------------------------------
# Forward kernel: w = Q z
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 600),
    n=st.integers(1, 300),
    d=st.integers(1, 8),
    tile_m=st.sampled_from([8, 64, 512]),
    binary=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_qz_matvec_matches_ref(m, n, d, tile_m, binary, seed):
    rng = np.random.default_rng(seed)
    d = min(d, n)
    rid, rv = make_row_layout(rng, m, n, d)
    if binary:
        z = (rng.random(n) < 0.5).astype(np.float32)
    else:
        z = rng.random(n).astype(np.float32)
    got = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z), tile_m=tile_m)
    want = ref.qz_matvec_ref(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_qz_matvec_matches_dense():
    rng = np.random.default_rng(0)
    m, n, d = 64, 32, 4
    rid, rv = make_row_layout(rng, m, n, d)
    z = rng.random(n).astype(np.float32)
    q = ref.dense_q_from_row_layout(jnp.asarray(rid), jnp.asarray(rv), n)
    want = q @ jnp.asarray(z)
    got = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_qz_zero_mask_gives_zero_weights():
    rng = np.random.default_rng(1)
    rid, rv = make_row_layout(rng, 100, 50, 3)
    z = np.zeros(50, dtype=np.float32)
    got = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z))
    np.testing.assert_array_equal(np.asarray(got), np.zeros(100, np.float32))


def test_qz_ones_mask_gives_row_sums():
    rng = np.random.default_rng(2)
    rid, rv = make_row_layout(rng, 100, 50, 3)
    z = np.ones(50, dtype=np.float32)
    got = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(got), rv.sum(axis=1), rtol=1e-6)


def test_qz_m_not_multiple_of_tile():
    """Row padding path: m that is not a multiple of tile_m."""
    rng = np.random.default_rng(3)
    m, n, d = 777, 128, 5
    rid, rv = make_row_layout(rng, m, n, d)
    z = rng.random(n).astype(np.float32)
    got = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z), tile_m=512)
    want = ref.qz_matvec_ref(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(z))
    assert got.shape == (m,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Backward kernel: g_s = Qᵀ g_w
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 400),
    n=st.integers(1, 200),
    d=st.integers(1, 6),
    tile_n=st.sampled_from([8, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qt_matvec_matches_ref(m, n, d, tile_n, seed):
    rng = np.random.default_rng(seed)
    d = min(d, n)
    rid, rv = make_row_layout(rng, m, n, d)
    cid, cv = row_to_padded_csc(rid, rv, n)
    g_w = rng.standard_normal(m).astype(np.float32)
    got = qt_gather.qt_matvec(jnp.asarray(cid), jnp.asarray(cv), jnp.asarray(g_w), tile_n=tile_n)
    want = ref.qt_matvec_ref(jnp.asarray(cid), jnp.asarray(cv), jnp.asarray(g_w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_qt_matches_dense_transpose():
    rng = np.random.default_rng(4)
    m, n, d = 80, 40, 4
    rid, rv = make_row_layout(rng, m, n, d)
    cid, cv = row_to_padded_csc(rid, rv, n)
    g_w = rng.standard_normal(m).astype(np.float32)
    q = ref.dense_q_from_row_layout(jnp.asarray(rid), jnp.asarray(rv), n)
    want = q.T @ jnp.asarray(g_w)
    got = qt_gather.qt_matvec(jnp.asarray(cid), jnp.asarray(cv), jnp.asarray(g_w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_qt_padding_slots_are_inert():
    """Padding (cid=0, cv=0) must not pick up g_w[0]."""
    cid = np.array([[0, 0, 0]], dtype=np.int32)  # all padding except first
    cv = np.array([[2.0, 0.0, 0.0]], dtype=np.float32)
    g_w = np.array([10.0, -1.0], dtype=np.float32)
    got = qt_gather.qt_matvec(jnp.asarray(cid), jnp.asarray(cv), jnp.asarray(g_w))
    np.testing.assert_allclose(np.asarray(got), [20.0])


# ---------------------------------------------------------------------------
# Round trip: forward/backward are mutual transposes  <u, Qv> == <Qᵀu, v>
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 300),
    n=st.integers(2, 150),
    d=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_adjoint_identity(m, n, d, seed):
    rng = np.random.default_rng(seed)
    d = min(d, n)
    rid, rv = make_row_layout(rng, m, n, d)
    cid, cv = row_to_padded_csc(rid, rv, n)
    u = rng.standard_normal(m).astype(np.float32)
    v = rng.standard_normal(n).astype(np.float32)
    qv = qz_gather.qz_matvec(jnp.asarray(rid), jnp.asarray(rv), jnp.asarray(v))
    qtu = qt_gather.qt_matvec(jnp.asarray(cid), jnp.asarray(cv), jnp.asarray(u))
    lhs = float(jnp.dot(jnp.asarray(u), qv))
    rhs = float(jnp.dot(qtu, jnp.asarray(v)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)
