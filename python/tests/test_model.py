"""L2 correctness: model shapes, gradients, fused-vs-dense agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from tests.test_kernel import make_row_layout, row_to_padded_csc


@pytest.fixture(scope="module")
def small_batch():
    rng = np.random.default_rng(7)
    x = rng.random((16, 784)).astype(np.float32)
    y = rng.integers(0, 10, size=16)
    y1h = np.eye(10, dtype=np.float32)[y]
    return jnp.asarray(x), jnp.asarray(y1h)


def test_param_counts():
    assert M.MNISTFC.num_params == 266_610  # paper §3.2
    assert M.SMALL_ARCH.num_params == 16_330


def test_forward_shapes(small_batch):
    x, _ = small_batch
    w = M.init_weights_kaiming(M.SMALL_ARCH, jax.random.PRNGKey(0))
    logits = M.forward(M.SMALL_ARCH, w, x)
    assert logits.shape == (16, 10)


def test_unflatten_roundtrip():
    arch = M.SMALL_ARCH
    w = jnp.arange(arch.num_params, dtype=jnp.float32)
    params = M.unflatten(arch, w)
    flat = jnp.concatenate([jnp.concatenate([W.reshape(-1), b]) for W, b in params])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(w))


def test_train_step_grad_matches_autodiff(small_batch):
    """make_train_step's grad equals direct jax.grad of the loss."""
    x, y1h = small_batch
    arch = M.SMALL_ARCH
    w = M.init_weights_kaiming(arch, jax.random.PRNGKey(1))
    step = M.make_train_step(arch)
    loss, grad_w, correct = step(w, x, y1h)
    direct = jax.grad(lambda w_: M.loss_and_correct(arch, w_, x, y1h)[0])(w)
    np.testing.assert_allclose(np.asarray(grad_w), np.asarray(direct), rtol=1e-5)
    assert 0 <= float(correct) <= 16


def test_train_step_finite_differences(small_batch):
    """Spot-check ∂loss/∂w_i against central finite differences."""
    x, y1h = small_batch
    arch = M.SMALL_ARCH
    w = M.init_weights_kaiming(arch, jax.random.PRNGKey(2))
    step = M.make_train_step(arch)
    _, grad_w, _ = step(w, x, y1h)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for idx in rng.choice(arch.num_params, size=5, replace=False):
        e = jnp.zeros_like(w).at[idx].set(eps)
        lp, _ = M.loss_and_correct(arch, w + e, x, y1h)
        lm, _ = M.loss_and_correct(arch, w - e, x, y1h)
        fd = (float(lp) - float(lm)) / (2 * eps)
        np.testing.assert_allclose(float(grad_w[idx]), fd, rtol=5e-2, atol=1e-4)


def test_padding_rows_are_inert(small_batch):
    """Zero one-hot rows (batch padding) change neither loss nor correct."""
    x, y1h = small_batch
    arch = M.SMALL_ARCH
    w = M.init_weights_kaiming(arch, jax.random.PRNGKey(3))
    loss_a, corr_a = M.loss_and_correct(arch, w, x, y1h)
    x_pad = jnp.concatenate([x, jnp.zeros((8, 784))])
    y_pad = jnp.concatenate([y1h, jnp.zeros((8, 10))])
    loss_b, corr_b = M.loss_and_correct(arch, w, x_pad, y_pad)
    np.testing.assert_allclose(float(loss_a), float(loss_b), rtol=1e-6)
    np.testing.assert_allclose(float(corr_a), float(corr_b))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_step_matches_dense_composition(small_batch, use_pallas):
    """fused(z, Q, batch) == dense(train_step(Qz, batch)) chained through Qᵀ."""
    x, y1h = small_batch
    arch = M.SMALL_ARCH
    m = arch.num_params
    n, d = m // 8, 4
    rng = np.random.default_rng(11)
    rid, rv = make_row_layout(rng, m, n, d)
    cid, cv = row_to_padded_csc(rid, rv, n)
    z = (rng.random(n) < 0.5).astype(np.float32)

    fused = M.make_fused_train_step(arch, use_pallas=use_pallas)
    loss_f, grad_s, corr_f = fused(
        jnp.asarray(z),
        jnp.asarray(rid),
        jnp.asarray(rv),
        jnp.asarray(cid),
        jnp.asarray(cv),
        x,
        y1h,
    )

    # Dense composition: w = Qz, dense grad, then g_s = Qᵀ g_w.
    w = jnp.sum(jnp.asarray(rv) * jnp.asarray(z)[jnp.asarray(rid)], axis=1)
    step = M.make_train_step(arch)
    loss_d, grad_w, corr_d = step(w, x, y1h)
    g_s_ref = jnp.sum(jnp.asarray(cv) * grad_w[jnp.asarray(cid)], axis=1)

    np.testing.assert_allclose(float(loss_f), float(loss_d), rtol=1e-5)
    np.testing.assert_allclose(float(corr_f), float(corr_d))
    np.testing.assert_allclose(
        np.asarray(grad_s), np.asarray(g_s_ref), rtol=1e-4, atol=1e-6
    )


def test_kaiming_init_variance():
    """Lemma 2.1 sanity: He init gives Var(W_l) ≈ 2/fan_in per layer."""
    arch = M.MNISTFC
    w = M.init_weights_kaiming(arch, jax.random.PRNGKey(9))
    params = M.unflatten(arch, w)
    for (W, _), fi in zip(params, arch.layers[:-1]):
        np.testing.assert_allclose(float(jnp.var(W)), 2.0 / fi, rtol=0.15)
