//! End-to-end tests for the `repro testnet` orchestrator: each test
//! launches a real multi-process fleet from a scenario TOML under
//! `configs/testnet/` and asserts on the orchestrator's report, the
//! per-process logs, and the on-disk artifacts.
//!
//! The heavy lifting — spawning, chaos, reaping, and the byte-level
//! comparison against the in-process simulator twin — happens inside
//! `repro testnet` itself; these tests drive it exactly the way CI
//! does and then re-check the load-bearing claims from outside:
//!
//! * depth-2 wire tree ≡ `ShardedSimTransport` byte-for-byte
//!   (`final_probs.bin` and the full `ledger.csv`),
//! * a shard killed mid-run renormalizes and still matches the twin,
//!   with the root's shard table billing zero merge bits for the dead
//!   subtree from the kill round on,
//! * a killed-and-restarted worker rejoins mid-run (probs still match
//!   the drop-schedule twin),
//! * a **root** killed mid-run resumes from its checkpoint —
//!   byte-identical to the *uninterrupted* twin, ledger included, for
//!   both the flat TCP leader and the depth-2 shard tree,
//! * late joiners with fresh ids are admitted at a round boundary and
//!   the elastic twin reproduces the grown run byte-for-byte,
//! * a depth-3 chain bills one same-sized `ShardVotes` merge frame per
//!   hop, with each hop's `merged` count equal to its subtree total,
//! * a deliberately failing scenario leaves **no orphaned processes**
//!   behind (every pid in `pids.txt` is gone).
//!
//! Scenario ports are distinct per file, so the tests are safe to run
//! in parallel under the default libtest harness.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

/// Path to a scenario TOML, resolved from the package root (the cwd
/// of integration tests) so the tests work from any invocation dir.
fn scenario(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../configs/testnet").join(name)
}

/// Per-test output root under cargo's integration-test tmpdir.
fn out_root(test: &str) -> PathBuf {
    Path::new(env!("CARGO_TARGET_TMPDIR")).join("testnet").join(test)
}

/// Run `repro testnet --scenario <name> --out <out>` and return the
/// captured output plus the scenario's own artifact directory
/// (`<out>/<scenario-name>/`).
fn run_testnet(scenario_file: &str, test: &str) -> (Output, PathBuf) {
    let scn = scenario(scenario_file);
    let out = out_root(test);
    let output = Command::new(env!("CARGO_BIN_EXE_repro"))
        .arg("testnet")
        .arg("--scenario")
        .arg(&scn)
        .arg("--out")
        .arg(&out)
        .output()
        .expect("spawn repro testnet");
    let name = scenario_file.trim_end_matches(".toml");
    (output, out.join(name))
}

/// Panic with the orchestrator's full stdout/stderr if the run failed
/// — the report and root-log tail are the only useful diagnostics.
fn assert_pass(output: &Output, what: &str) {
    assert!(
        output.status.success(),
        "{what} failed\n--- stdout ---\n{}\n--- stderr ---\n{}",
        String::from_utf8_lossy(&output.stdout),
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains(": PASS"), "{what}: report missing PASS line\n{stdout}");
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn read_bytes(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Parse the `# shards` section of a `ledger.csv`:
/// `(round, shard, uplink, downlink, merge, received, dropped)` rows.
fn shard_rows(csv: &str) -> Vec<(u32, u32, u64, u64, u64, u64, u64)> {
    let mut rows = Vec::new();
    let mut in_shards = false;
    for line in csv.lines() {
        if line.starts_with("# ") {
            in_shards = line == "# shards";
            continue;
        }
        if !in_shards || line.starts_with("round,") || line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        assert_eq!(f.len(), 7, "malformed shard row: {line}");
        rows.push((
            f[0].parse().unwrap(),
            f[1].parse().unwrap(),
            f[2].parse().unwrap(),
            f[3].parse().unwrap(),
            f[4].parse().unwrap(),
            f[5].parse().unwrap(),
            f[6].parse().unwrap(),
        ));
    }
    rows
}

/// Extract every `merge <N>b up` bit count from a shard leader's log.
fn merge_bits(log: &str) -> Vec<u64> {
    log.lines()
        .filter_map(|l| l.split("merge ").nth(1))
        .filter_map(|rest| rest.strip_suffix("b up"))
        .map(|n| n.parse().expect("merge bit count"))
        .collect()
}

/// The acceptance scenario: root + 2 `serve-shard` processes + 4
/// workers over real sockets must produce `final_probs` and ledgers
/// byte-identical to the in-process `ShardedSimTransport` twin.
#[test]
fn depth2_wire_tree_matches_the_simulator_twin() {
    let (output, dir) = run_testnet("tree-depth2.toml", "depth2");
    assert_pass(&output, "tree-depth2");

    // The orchestrator already diffed these; re-assert from outside so
    // the guarantee doesn't rest on the tool under test alone.
    assert_eq!(
        read_bytes(&dir.join("root/final_probs.bin")),
        read_bytes(&dir.join("twin.final_probs.bin")),
        "wire final_probs differ from the simulator twin"
    );
    assert_eq!(
        read(&dir.join("root/ledger.csv")),
        read(&dir.join("twin.ledger.csv")),
        "wire ledger differs from the simulator twin"
    );

    // Both shard leaders shipped a ShardVotes merge frame every round.
    for s in 0..2 {
        let bits = merge_bits(&read(&dir.join(format!("shard-{s}.log"))));
        assert_eq!(bits.len(), 4, "shard {s}: expected one merge per round");
        assert!(bits.iter().all(|&b| b > 0), "shard {s}: zero-bit merge frame");
    }
}

/// Kill-one-shard chaos: shard 1 exits the moment round 2 arrives.
/// The root must renormalize over the survivor and stay byte-identical
/// to the twin running the same scheduled outage; the shard table must
/// bill the dead subtree zero merge traffic from the kill round on.
#[test]
fn killing_one_shard_renormalizes_and_matches_the_twin() {
    let (output, dir) = run_testnet("tree-depth2-killshard.toml", "killshard");
    assert_pass(&output, "tree-depth2-killshard");

    let root_log = read(&dir.join("root.log"));
    assert!(root_log.contains("dropped clients"), "root never reported the outage");
    let shard1_log = read(&dir.join("shard-1.log"));
    assert!(
        shard1_log.contains("failing at round 2 (chaos schedule)"),
        "shard 1 did not die on schedule:\n{shard1_log}"
    );

    let rows = shard_rows(&read(&dir.join("root/ledger.csv")));
    assert!(!rows.is_empty(), "root ledger has no shard table");
    for &(round, shard, up, _down, merge, received, dropped) in &rows {
        if shard == 0 {
            assert!(merge > 0, "round {round}: live shard billed no merge bits");
        } else if round < 2 {
            assert!(merge > 0 && received > 0, "round {round}: shard 1 alive but idle");
        } else {
            assert_eq!(
                (up, merge, received),
                (0, 0, 0),
                "round {round}: dead shard still billed traffic"
            );
            assert!(dropped > 0, "round {round}: dead shard's clients not dropped");
        }
    }
}

/// Kill-and-restart chaos: worker 3 dies at round 2, the orchestrator
/// respawns it, and the fresh process (state re-derived from the
/// shared seed) rejoins mid-run.  The twin replays the drop schedule
/// observed in the root log, so final probs must still match.
#[test]
fn killed_worker_restarts_and_rejoins_mid_run() {
    let (output, dir) = run_testnet("tcp-worker-restart.toml", "restart");
    assert_pass(&output, "tcp-worker-restart");

    let root_log = read(&dir.join("root.log"));
    assert!(
        root_log.contains("dropped clients [3]"),
        "root never dropped worker 3:\n{root_log}"
    );
    let worker_log = read(&dir.join("worker-3.log"));
    assert!(
        worker_log.contains("failing at round 2 (chaos schedule)"),
        "worker 3 did not die on schedule:\n{worker_log}"
    );
    assert!(
        dir.join("worker-3-restart.log").exists(),
        "orchestrator never respawned worker 3"
    );
}

/// Depth-3 chain root <- shard 0 <- shard 1 <- shard 2: every hop must
/// fold its children's votes into its own and re-emit one ShardVotes
/// frame upward.  With 2 workers per shard at full participation the
/// merged counts are exactly the subtree totals (0, 2, 4 going up),
/// and every hop's merge frame is the same size (same vote vector).
#[test]
fn depth3_chain_merges_and_bills_every_hop() {
    let (output, dir) = run_testnet("tree-depth3.toml", "depth3");
    assert_pass(&output, "tree-depth3");

    let leaf = read(&dir.join("shard-2.log"));
    let mid = read(&dir.join("shard-1.log"));
    let top = read(&dir.join("shard-0.log"));
    assert!(leaf.contains("(own 2, merged 0)"), "leaf merged votes it has no children for");
    assert!(mid.contains("(own 2, merged 2)"), "mid hop did not fold the leaf's votes");
    assert!(top.contains("(own 2, merged 4)"), "top hop did not fold its subtree's votes");

    // Same model size everywhere → one ShardVotes frame size per hop.
    let mut all_bits: Vec<u64> = [&leaf, &mid, &top].iter().flat_map(|l| merge_bits(l)).collect();
    assert_eq!(all_bits.len(), 12, "expected one merge line per hop per round");
    all_bits.dedup();
    assert_eq!(all_bits.len(), 1, "merge frame sizes differ across hops: {all_bits:?}");
    assert!(all_bits[0] > 0);
}

/// Kill-the-root chaos: the flat-TCP leader errors out at the start of
/// round 3, the orchestrator respawns it as `repro resume` from the
/// checkpoint written at the round-2 boundary, and the workers re-dial
/// and re-`Hello`.  Resume must be invisible: the finished artifacts
/// are byte-identical to the **uninterrupted** in-process twin, ledger
/// included (restored rows + the replayed rounds).
#[test]
fn killed_tcp_root_resumes_byte_identical_to_an_uninterrupted_run() {
    let (output, dir) = run_testnet("tcp-resume.toml", "tcp_resume");
    assert_pass(&output, "tcp-resume");

    let root_log = read(&dir.join("root.log"));
    assert!(
        root_log.contains("leader failing at round 3"),
        "root did not die on schedule:\n{root_log}"
    );
    let restart_log = read(&dir.join("root-restart.log"));
    assert!(
        restart_log.contains("resuming from"),
        "respawned root did not resume from the checkpoint:\n{restart_log}"
    );
    assert!(dir.join("root/checkpoint.bin").exists(), "no checkpoint left on disk");

    assert_eq!(
        read_bytes(&dir.join("root/final_probs.bin")),
        read_bytes(&dir.join("twin.final_probs.bin")),
        "resumed final_probs differ from the uninterrupted twin"
    );
    assert_eq!(
        read(&dir.join("root/ledger.csv")),
        read(&dir.join("twin.ledger.csv")),
        "resumed ledger differs from the uninterrupted twin"
    );
}

/// Same contract one layer up: the **depth-2 shard-tree** root dies at
/// round 3 and resumes; shard leaders re-dial the fresh root, workers
/// re-dial their shard leaders, and the whole tree finishes
/// byte-identical to the uninterrupted twin under `compare = "full"`.
#[test]
fn killed_tree_root_resumes_byte_identical_to_an_uninterrupted_run() {
    let (output, dir) = run_testnet("tree-depth2-resume.toml", "tree_resume");
    assert_pass(&output, "tree-depth2-resume");

    assert!(
        read(&dir.join("root.log")).contains("leader failing at round 3"),
        "root did not die on schedule"
    );
    assert!(
        read(&dir.join("root-restart.log")).contains("resuming from"),
        "respawned root did not resume from the checkpoint"
    );
    // Both shard leaders kept merging after the resume.
    for s in 0..2 {
        let bits = merge_bits(&read(&dir.join(format!("shard-{s}.log"))));
        assert!(bits.len() >= 6, "shard {s}: merges missing after resume: {bits:?}");
    }
    assert_eq!(
        read_bytes(&dir.join("root/final_probs.bin")),
        read_bytes(&dir.join("twin.final_probs.bin")),
        "resumed final_probs differ from the uninterrupted twin"
    );
    assert_eq!(
        read(&dir.join("root/ledger.csv")),
        read(&dir.join("twin.ledger.csv")),
        "resumed ledger differs from the uninterrupted twin"
    );
}

/// Elastic membership: two late workers with fresh ids (4 and 5, above
/// the starting roster of 4) are spawned mid-run; the leader admits
/// each at the next round boundary and logs the admission.  The twin
/// replays the *observed* admission rounds through the elastic
/// simulator, so the grown run must still match byte-for-byte.
#[test]
fn late_joiners_grow_the_population_and_match_the_elastic_twin() {
    let (output, dir) = run_testnet("tcp-join.toml", "tcp_join");
    assert_pass(&output, "tcp-join");

    let root_log = read(&dir.join("root.log"));
    assert!(
        root_log.contains("joined clients"),
        "root never admitted a joiner:\n{root_log}"
    );
    for k in [4, 5] {
        assert!(
            dir.join(format!("worker-{k}.log")).exists(),
            "late worker {k} never spawned"
        );
    }
    assert_eq!(
        read_bytes(&dir.join("root/final_probs.bin")),
        read_bytes(&dir.join("twin.final_probs.bin")),
        "elastic final_probs differ from the simulator twin"
    );
    assert_eq!(
        read(&dir.join("root/ledger.csv")),
        read(&dir.join("twin.ledger.csv")),
        "elastic ledger differs from the simulator twin"
    );
}

/// A scenario that blows its 2-second timeout must fail — and must
/// take the whole fleet down with it.  Every pid the orchestrator
/// recorded has to be gone afterwards (or at least no longer a `repro`
/// process, guarding against pid reuse).
#[test]
fn failed_scenario_reaps_every_spawned_process() {
    let (output, dir) = run_testnet("reap.toml", "reap");
    assert!(
        !output.status.success(),
        "reap scenario unexpectedly passed:\n{}",
        String::from_utf8_lossy(&output.stdout)
    );
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("timed out"), "expected a timeout failure, got:\n{stderr}");

    let pids_txt = read(&dir.join("pids.txt"));
    let pids: Vec<u32> = pids_txt
        .lines()
        .map(|l| l.split_whitespace().next().unwrap().parse().expect("pid"))
        .collect();
    assert!(pids.len() >= 3, "expected root + 2 workers in pids.txt:\n{pids_txt}");

    // The orchestrator kills and *waits* before exiting, so the pids
    // are reaped by the time it returns; poll briefly anyway.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    'pids: for pid in pids {
        loop {
            let proc_dir = PathBuf::from(format!("/proc/{pid}"));
            if !proc_dir.exists() {
                continue 'pids;
            }
            // Pid may have been reused by an unrelated process.
            let cmdline =
                std::fs::read(proc_dir.join("cmdline")).unwrap_or_default();
            if !String::from_utf8_lossy(&cmdline).contains("repro") {
                continue 'pids;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "pid {pid} survived the fleet reaping"
            );
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
}
