//! Loom-lane model checks for the two concurrency protocols in the
//! crate: the pool's job-handoff/shutdown (`runtime::pool`) and the
//! sweeper's stop-join-close sequence (`federated::transport::Leader`,
//! modeled here through the shared `StopGate`).
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"`:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test -p zampling --release --test loom_model
//! ```
//!
//! Under that cfg, `runtime::sync` re-exports the `loomlite` primitives,
//! so the *production* pool code runs with schedule perturbation around
//! every lock, wait, notify, and atomic op (see `rust/loomlite` for what
//! that does and does not prove — Miri and TSan cover the gaps).  No
//! defect was surfaced when these models first ran; they are regression
//! assertions pinning the protocols' contracts:
//!
//! * every dispatched shard runs exactly once and `run` does not return
//!   before the last one finishes (the `Job` raw-pointer soundness
//!   argument *is* that blocking wait);
//! * borrowed captures never outlive `run` (use-after-free canary);
//! * `Drop` reaps parked workers — the Exit-sentinel + `notify_all`
//!   handoff must not lose a wakeup, or the join deadlocks;
//! * a panicking shard is reported only after every shard finished;
//! * the Leader teardown order is stop → join → close, so no sweeper
//!   iteration can observe a closed fd.

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use zampling::runtime::pool::{SendPtr, ThreadPool};
use zampling::runtime::sync::StopGate;

#[test]
fn pool_run_completes_every_shard_before_returning() {
    loom::model(|| {
        let pool = ThreadPool::new(2);
        let mut out = vec![0usize; 3];
        let base = SendPtr::new(out.as_mut_ptr());
        pool.run(3, |t| {
            // SAFETY: disjoint one-element chunks, one per shard.
            let cell = unsafe { base.slice(t, 1) };
            cell[0] = t + 1;
        });
        // If `run` returned before a worker shard finished, that slot
        // would still be 0 (or worse, written after `out` moved).
        assert_eq!(out, vec![1, 2, 3]);
    });
}

#[test]
fn job_closure_never_outlives_run() {
    loom::model(|| {
        let pool = ThreadPool::new(1);
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let borrowed: Vec<usize> = vec![10, 20];
            let hits = Arc::clone(&hits);
            // The closure reads `borrowed` through the lifetime-erased
            // `Job` pointer; `borrowed` drops right after `run` returns,
            // so any late worker dereference is a use-after-free (which
            // the Miri lane would flag on this same protocol).
            pool.run(2, move |t| {
                hits.fetch_add(borrowed[t], Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 30);

        // The pool must stay usable after the borrow ended.
        let again = Arc::new(AtomicUsize::new(0));
        let a2 = Arc::clone(&again);
        pool.run(2, move |_| {
            a2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(again.load(Ordering::SeqCst), 2);
    });
}

#[test]
fn pool_drop_reaps_parked_workers() {
    loom::model(|| {
        // Never-used pool: Exit sentinels must wake workers that are
        // parked in `Condvar::wait` (a lost notification deadlocks the
        // join in `Drop`).
        let idle = ThreadPool::new(2);
        drop(idle);

        // Drop racing the tail of a run: workers can be anywhere
        // between `count_down` and re-parking when Exit is queued.
        let busy = ThreadPool::new(2);
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = Arc::clone(&n);
        busy.run(3, move |_| {
            n2.fetch_add(1, Ordering::SeqCst);
        });
        drop(busy);
        assert_eq!(n.load(Ordering::SeqCst), 3);
    });
}

#[test]
fn shard_panic_is_propagated_after_all_shards_finish() {
    loom::model(|| {
        let pool = ThreadPool::new(1);
        let survivors = Arc::new(AtomicUsize::new(0));
        let s2 = Arc::clone(&survivors);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, |t| {
                if t == 1 {
                    panic!("shard 1 dies");
                }
                s2.fetch_add(1, Ordering::SeqCst);
            });
        }));
        // The panic must surface to the caller, and only after shard 0
        // completed — otherwise borrowed captures could be outlived.
        assert!(result.is_err());
        assert_eq!(survivors.load(Ordering::SeqCst), 1);

        // The pool survives a panicked round.
        let ok = Arc::new(AtomicUsize::new(0));
        let ok2 = Arc::clone(&ok);
        pool.run(2, move |_| {
            ok2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    });
}

/// Stand-in for a sweeper-owned connection: drop = close(fd).
struct FakeFd {
    closed: Arc<AtomicUsize>,
}

impl Drop for FakeFd {
    fn drop(&mut self) {
        self.closed.fetch_add(1, Ordering::SeqCst);
    }
}

#[test]
fn leader_drop_sequence_stops_joins_then_closes() {
    loom::model(|| {
        let closed = Arc::new(AtomicUsize::new(0));
        let gate = StopGate::new();
        let ticks = Arc::new(AtomicUsize::new(0));

        // The sweeper loop's shape (transport::sweep_loop): check the
        // gate, poll, repeat; the connections are owned by the loop and
        // closed only after the gate trips.
        let sweeper = {
            let gate = gate.clone();
            let ticks = Arc::clone(&ticks);
            let conns = vec![
                FakeFd { closed: Arc::clone(&closed) },
                FakeFd { closed: Arc::clone(&closed) },
            ];
            loom::thread::spawn(move || {
                while !gate.stop_requested() {
                    ticks.fetch_add(1, Ordering::SeqCst);
                    loom::thread::yield_now();
                }
                drop(conns);
            })
        };

        // `Leader::drop`'s order: request stop, join, then the slots
        // (here: nothing left) — by join time every fd must be closed
        // exactly once, and never before the gate tripped.
        gate.request_stop();
        sweeper.join().unwrap();
        assert_eq!(closed.load(Ordering::SeqCst), 2);
    });
}
