// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Property tests for `ParticipationPolicy` implementations: for ANY
//! (clients, participation, round, history) input, every policy must
//! return a non-empty, in-bounds, duplicate-free ascending subset of the
//! expected size, and identical seeds must yield identical subsets.
//! `proptest` is unavailable offline, so these run over the crate's
//! deterministic `util::prop::for_all` driver.

use zampling::federated::{ParticipationPolicy, RoundHistory, StragglerAware, Uniform};
use zampling::rng::SeedTree;
use zampling::util::prop::for_all;

/// A generated policy-selection input.
#[derive(Debug)]
struct Input {
    seed: u64,
    clients: usize,
    participation: f64,
    round: usize,
    misses: Vec<u32>,
}

fn gen_input(g: &mut zampling::util::prop::Gen) -> Input {
    let clients = g.usize_in(1, 40);
    Input {
        seed: g.seed(),
        clients,
        // strictly inside (0, 1]; includes the no-rng 1.0 fast path
        participation: if g.bool_p(0.2) { 1.0 } else { g.f64_in(0.01, 1.0) },
        round: g.usize_in(0, 500),
        misses: (0..clients).map(|_| g.usize_in(0, 30) as u32).collect(),
    }
}

fn check_plan(
    policy: &mut dyn ParticipationPolicy,
    input: &Input,
) -> Result<Vec<usize>, String> {
    let seeds = SeedTree::new(input.seed);
    let history = RoundHistory { misses: input.misses.clone() };
    let plan =
        policy.select(input.round, input.clients, input.participation, &seeds, &history);
    let p = &plan.participants;
    if p.is_empty() {
        return Err(format!("{}: empty subset", policy.name()));
    }
    if p.iter().any(|&k| k >= input.clients) {
        return Err(format!("{}: out-of-bounds id in {p:?}", policy.name()));
    }
    if p.windows(2).any(|w| w[0] >= w[1]) {
        return Err(format!("{}: not strictly ascending (dups?): {p:?}", policy.name()));
    }
    let want = if input.participation >= 1.0 {
        input.clients
    } else {
        ((input.participation * input.clients as f64).round() as usize).clamp(1, input.clients)
    };
    if p.len() != want {
        return Err(format!("{}: {} selected, want {want}", policy.name(), p.len()));
    }
    // identical seeds + identical history → identical subset
    let again =
        policy.select(input.round, input.clients, input.participation, &seeds, &history);
    if again.participants != *p {
        return Err(format!("{}: not deterministic", policy.name()));
    }
    Ok(p.clone())
}

#[test]
fn every_policy_returns_valid_deterministic_subsets() {
    for_all("policy-subset-validity", 300, 0xFED5, gen_input, |input| {
        check_plan(&mut Uniform, input)?;
        check_plan(&mut StragglerAware, input)?;
        Ok(())
    });
}

#[test]
fn full_participation_selects_everyone_for_every_policy() {
    for_all(
        "full-participation-is-everyone",
        100,
        0xFEED,
        |g| {
            let mut i = gen_input(g);
            i.participation = 1.0;
            i
        },
        |input| {
            let all: Vec<usize> = (0..input.clients).collect();
            if check_plan(&mut Uniform, input)? != all {
                return Err("uniform skipped someone at participation 1.0".into());
            }
            if check_plan(&mut StragglerAware, input)? != all {
                return Err("straggler-aware skipped someone at participation 1.0".into());
            }
            Ok(())
        },
    );
}

#[test]
fn straggler_history_changes_subsets_but_uniform_ignores_it() {
    for_all(
        "history-sensitivity",
        200,
        0xCAFE,
        |g| {
            let mut i = gen_input(g);
            // sub-full participation with room to differ
            i.clients = g.usize_in(4, 40);
            i.participation = 0.5;
            i.misses = (0..i.clients).map(|_| g.usize_in(0, 30) as u32).collect();
            i
        },
        |input| {
            let blank = Input {
                seed: input.seed,
                clients: input.clients,
                participation: input.participation,
                round: input.round,
                misses: vec![0; input.clients],
            };
            // Uniform is history-blind by construction.
            if check_plan(&mut Uniform, input)? != check_plan(&mut Uniform, &blank)? {
                return Err("uniform policy read the history".into());
            }
            // StragglerAware stays valid under any history (already via
            // check_plan); subsets may legitimately differ from blank.
            check_plan(&mut StragglerAware, input)?;
            Ok(())
        },
    );
}
