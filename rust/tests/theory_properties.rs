// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Property-based tests over the coordinator invariants: sparse algebra,
//! protocol encodings, probability-vector dynamics, and the §2 claims —
//! driven by the in-tree `util::prop` harness (proptest is unavailable
//! offline; see Cargo.toml).

use zampling::comm::{arith, pack_bits, rle, unpack_bits, BitPack};
use zampling::federated::protocol::{
    decode_client, decode_server, encode_client, encode_server, ClientMsg, MaskCodec, ServerMsg,
};
use zampling::nn::ArchSpec;
use zampling::rng::{Rng, SeedTree, Xoshiro256pp};
use zampling::sparse::{csc_pad_width, QMatrix};
use zampling::util::prop::for_all;
use zampling::zampling::{clip01, ProbVector};

#[derive(Debug)]
struct QCase {
    n: usize,
    d: usize,
    seed: u64,
}

fn q_case(g: &mut zampling::util::prop::Gen) -> QCase {
    let n = g.usize_in(4, 600);
    QCase { n, d: g.usize_in(1, n.min(8)), seed: g.seed() }
}

fn tiny_arch() -> ArchSpec {
    ArchSpec::new("prop", &[12, 8, 4])
}

/// <u, Qv> == <Qᵀu, v> for every generated Q (adjoint identity).
#[test]
fn prop_spmv_adjoint_identity() {
    for_all("spmv-adjoint", 40, 11, q_case, |c| {
        let arch = tiny_arch();
        let n = c.n.min(arch.num_params());
        let d = c.d.min(n);
        let q = QMatrix::generate(&arch, n, d, &SeedTree::new(c.seed));
        let csc = q.to_csc(None);
        let mut r = Xoshiro256pp::seed_from(c.seed ^ 1);
        let u: Vec<f32> = (0..q.m).map(|_| r.next_f32() - 0.5).collect();
        let v: Vec<f32> = (0..q.n).map(|_| r.next_f32() - 0.5).collect();
        let qv = q.spmv(&v);
        let qtu = csc.spmv_t(&u);
        let lhs: f64 = u.iter().zip(&qv).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = qtu.iter().zip(&v).map(|(&a, &b)| (a * b) as f64).sum();
        if (lhs - rhs).abs() <= 1e-3 * (1.0 + lhs.abs()) {
            Ok(())
        } else {
            Err(format!("<u,Qv>={lhs} != <Qᵀu,v>={rhs}"))
        }
    });
}

/// Bit-mask spmv == float-mask spmv for every Q and mask.
#[test]
fn prop_spmv_bits_equals_float() {
    for_all("spmv-bits", 30, 13, q_case, |c| {
        let arch = tiny_arch();
        let n = c.n.min(arch.num_params());
        let d = c.d.min(n);
        let q = QMatrix::generate(&arch, n, d, &SeedTree::new(c.seed));
        let mut r = Xoshiro256pp::seed_from(c.seed ^ 2);
        let mask: Vec<bool> = (0..n).map(|_| r.bernoulli(0.5)).collect();
        let zf: Vec<f32> = mask.iter().map(|&b| b as u8 as f32).collect();
        let bits = pack_bits(&mask);
        let mut w_bits = vec![0.0f32; q.m];
        q.spmv_bits_into(&bits, &mut w_bits);
        // allclose: the bits kernel reassociates the sum (dual accums).
        if q
            .spmv(&zf)
            .iter()
            .zip(&w_bits)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * (1.0 + a.abs()))
        {
            Ok(())
        } else {
            Err("bitmask spmv diverged from float spmv".into())
        }
    });
}

/// Generated Q always satisfies the structural contract: d distinct
/// in-range indices per row; realized max column degree ≤ csc_pad_width.
#[test]
fn prop_q_structure_and_pad_bound() {
    for_all("q-structure", 40, 17, q_case, |c| {
        let arch = tiny_arch();
        let n = c.n.min(arch.num_params());
        let d = c.d.min(n);
        let q = QMatrix::generate(&arch, n, d, &SeedTree::new(c.seed));
        for i in 0..q.m {
            let (ids, _) = q.row(i);
            let mut sorted: Vec<u32> = ids.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != d {
                return Err(format!("row {i} has duplicate column indices"));
            }
            if sorted.last().map(|&j| j as usize >= n).unwrap_or(false) {
                return Err(format!("row {i} index out of range"));
            }
        }
        let csc = q.to_csc(None);
        let max_deg = *csc.degrees.iter().max().unwrap_or(&0) as usize;
        let pad = csc_pad_width(q.m, n, d);
        if max_deg <= pad {
            Ok(())
        } else {
            Err(format!("max degree {max_deg} exceeds pad bound {pad}"))
        }
    });
}

/// pack/unpack and all three codecs are lossless on arbitrary masks.
#[test]
fn prop_codecs_roundtrip() {
    for_all(
        "codec-roundtrip",
        60,
        19,
        |g| {
            let n = g.usize_in(0, 3000);
            let density = g.f64_in(0.0, 1.0);
            let mut r = Xoshiro256pp::seed_from(g.seed());
            let mask: Vec<bool> = (0..n).map(|_| r.bernoulli(density)).collect();
            mask
        },
        |mask| {
            let n = mask.len();
            if unpack_bits(&pack_bits(mask), n) != *mask {
                return Err("pack_bits roundtrip".into());
            }
            if BitPack::decode(&BitPack::encode(mask), n) != *mask {
                return Err("BitPack roundtrip".into());
            }
            match rle::decode(&rle::encode(mask), n) {
                Ok(dec) if dec == *mask => {}
                Ok(_) => return Err("rle roundtrip".into()),
                Err(e) => return Err(format!("rle decode failed: {e}")),
            }
            match arith::decode(&arith::encode(mask), n) {
                Ok(dec) if dec == *mask => {}
                Ok(_) => return Err("arith roundtrip".into()),
                Err(e) => return Err(format!("arith decode failed: {e}")),
            }
            Ok(())
        },
    );
}

/// Protocol frames roundtrip for random payloads and both codecs.
#[test]
fn prop_protocol_roundtrip() {
    for_all(
        "protocol-roundtrip",
        40,
        23,
        |g| {
            let n = g.usize_in(1, 2000);
            let mut r = Xoshiro256pp::seed_from(g.seed());
            let probs: Vec<f32> = (0..n).map(|_| r.next_f32()).collect();
            let mask: Vec<bool> = (0..n).map(|_| r.bernoulli(0.3)).collect();
            let round = g.usize_in(0, 1000) as u32;
            let client = g.usize_in(0, 64) as u32;
            let arith = g.bool_p(0.5);
            (probs, mask, round, client, arith)
        },
        |(probs, mask, round, client, use_arith)| {
            let smsg = ServerMsg::Round { round: *round, probs: probs.clone() };
            if decode_server(&encode_server(&smsg)).map_err(|e| e.to_string())? != smsg {
                return Err("server msg roundtrip".into());
            }
            let codec = if *use_arith { MaskCodec::Arithmetic } else { MaskCodec::Raw };
            let cmsg = ClientMsg::Mask {
                round: *round,
                client: *client,
                n: mask.len(),
                mask: mask.clone(),
            };
            if decode_client(&encode_client(&cmsg, codec)).map_err(|e| e.to_string())? != cmsg {
                return Err("client msg roundtrip".into());
            }
            Ok(())
        },
    );
}

/// ProbVector dynamics: probabilities remain in [0,1] under arbitrary
/// update sequences, the clip matches f(x), saturated entries gate to 0.
#[test]
fn prop_probvector_invariants() {
    for_all(
        "probvector",
        50,
        29,
        |g| {
            let n = g.usize_in(1, 200);
            let steps = g.usize_in(1, 20);
            (n, steps, g.seed())
        },
        |&(n, steps, seed)| {
            let mut r = Xoshiro256pp::seed_from(seed);
            let mut pv = ProbVector::init_uniform(n, &mut r);
            for _ in 0..steps {
                let delta: Vec<f32> = (0..n).map(|_| (r.next_f32() - 0.5) * 2.0).collect();
                pv.apply_update(&delta);
                if !pv.probs().iter().all(|&p| (0.0..=1.0).contains(&p)) {
                    return Err("p left [0,1]".into());
                }
                // scores were folded back onto probs
                if pv.scores() != pv.probs() {
                    return Err("score/prob identification broken".into());
                }
                let mut g: Vec<f32> = vec![1.0; n];
                pv.gate_gradient(&mut g);
                for (i, (&gi, &pi)) in g.iter().zip(pv.probs()).enumerate() {
                    let saturated = pi <= 0.0 || pi >= 1.0;
                    if saturated && gi != 0.0 {
                        return Err(format!("entry {i} saturated but not gated"));
                    }
                    if !saturated && gi != 1.0 {
                        return Err(format!("entry {i} interior but gated"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// clip01 is the paper's f: idempotent, monotone, identity on [0,1].
#[test]
fn prop_clip_is_papers_f() {
    for_all(
        "clip01",
        100,
        31,
        |g| (g.f64_in(-3.0, 3.0) as f32, g.f64_in(-3.0, 3.0) as f32),
        |&(a, b)| {
            if clip01(clip01(a)) != clip01(a) {
                return Err("not idempotent".into());
            }
            if (a <= b) && clip01(a) > clip01(b) {
                return Err("not monotone".into());
            }
            if (0.0..=1.0).contains(&a) && clip01(a) != a {
                return Err("not identity on [0,1]".into());
            }
            Ok(())
        },
    );
}

/// Server aggregation: p(t+1) is the exact mean of the client masks and
/// therefore in [0,1].
#[test]
fn prop_server_aggregation_mean() {
    for_all(
        "server-mean",
        40,
        37,
        |g| {
            let n = g.usize_in(1, 300);
            let k = g.usize_in(1, 12);
            (n, k, g.seed())
        },
        |&(n, k, seed)| {
            use zampling::federated::Server;
            let mut r = Xoshiro256pp::seed_from(seed);
            let mut server = Server::new(vec![0.5; n]);
            let mut expected = vec![0.0f32; n];
            for _ in 0..k {
                let mask: Vec<bool> = (0..n).map(|_| r.bernoulli(0.5)).collect();
                for (e, &b) in expected.iter_mut().zip(&mask) {
                    *e += b as u8 as f32;
                }
                server.receive_mask(&pack_bits(&mask));
            }
            server.aggregate();
            for (i, (&got, &sum)) in server.probs.iter().zip(&expected).enumerate() {
                let want = sum / k as f32;
                if (got - want).abs() > 1e-6 {
                    return Err(format!("entry {i}: {got} != mean {want}"));
                }
                if !(0.0..=1.0).contains(&got) {
                    return Err("mean left [0,1]".into());
                }
            }
            Ok(())
        },
    );
}

/// Arithmetic coder rate stays near the empirical entropy.
#[test]
fn prop_arith_rate_bounded() {
    for_all(
        "arith-rate",
        25,
        41,
        |g| {
            let n = g.usize_in(2_000, 30_000);
            let q = g.f64_in(0.02, 0.98);
            (n, q, g.seed())
        },
        |&(n, q, seed)| {
            let mut r = Xoshiro256pp::seed_from(seed);
            let mask: Vec<bool> = (0..n).map(|_| r.bernoulli(q)).collect();
            let emp = mask.iter().filter(|&&b| b).count() as f64 / n as f64;
            let rate = arith::bits_per_entry(&mask);
            let h = arith::binary_entropy(emp);
            if rate > h * 1.08 + 64.0 / n as f64 + 0.02 {
                return Err(format!("rate {rate:.4} ≫ H {h:.4} (q={q:.2}, n={n})"));
            }
            Ok(())
        },
    );
}
