// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Property tests for the blocked GEMM kernels: the blocked/tiled
//! implementations must match the retained naive reference within
//! f32-reassociation tolerance across shapes that exercise every
//! partial-tile edge case (b, fan_in, fan_out not multiples of the 8×8
//! tile), and the full MLP step built on them must still pass its
//! finite-difference gradient check at odd batch sizes.

use zampling::nn::{gemm, ArchSpec, MlpRef};
use zampling::rng::{Rng, Xoshiro256pp};

fn randv(len: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from(seed);
    (0..len).map(|_| r.next_f32() - 0.5).collect()
}

/// ReLU-sparse activations (roughly half zeros), like real layer inputs.
fn relu_randv(len: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from(seed);
    (0..len).map(|_| (r.next_f32() - 0.5).max(0.0)).collect()
}

fn assert_close(reference: &[f32], got: &[f32], tag: &str) {
    assert_eq!(reference.len(), got.len(), "{tag}: length");
    for (i, (&x, &y)) in reference.iter().zip(got).enumerate() {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
            "{tag}[{i}]: naive {x} vs blocked {y}"
        );
    }
}

/// Shapes around the 8×8 tile boundary: primes, one-offs, degenerate
/// single-row/column cases, and a tile-aligned control.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 17, 9),
    (3, 5, 7),
    (7, 8, 9),
    (8, 8, 8),
    (9, 7, 23),
    (13, 29, 11),
    (16, 33, 31),
    (31, 784, 10),
    (64, 20, 20),
];

#[test]
fn blocked_gemm_matches_naive_across_odd_shapes() {
    for &(m, k, n) in SHAPES {
        let a = randv(m * k, (m * 1000 + k) as u64);
        let b = randv(k * n, (k * 1000 + n) as u64);
        let mut c_ref = vec![0.0; m * n];
        let mut c = vec![0.0; m * n];
        gemm::naive::gemm(&a, &b, &mut c_ref, m, k, n);
        gemm::gemm(&a, &b, &mut c, m, k, n);
        assert_close(&c_ref, &c, &format!("gemm {m}x{k}x{n}"));
    }
}

#[test]
fn fused_bias_relu_matches_naive_across_odd_shapes() {
    for &(m, k, n) in SHAPES {
        let a = relu_randv(m * k, (m * 31 + n) as u64);
        let b = randv(k * n, (n * 17 + k) as u64);
        let bias = randv(n, (m + k + n) as u64);
        for relu in [false, true] {
            let mut c_ref = vec![0.0; m * n];
            let mut c = vec![0.0; m * n];
            gemm::naive::gemm_bias_act(&a, &b, Some(&bias), &mut c_ref, m, k, n, relu);
            gemm::gemm_bias_act(&a, &b, Some(&bias), &mut c, m, k, n, relu);
            assert_close(&c_ref, &c, &format!("bias_act {m}x{k}x{n} relu={relu}"));
            if relu {
                assert!(c.iter().all(|&v| v >= 0.0), "relu output negative");
            }
        }
    }
}

#[test]
fn weight_gradient_matches_naive_across_odd_shapes() {
    for &(m, k, n) in SHAPES {
        let a = relu_randv(m * k, (k * 7 + m) as u64);
        let d = randv(m * n, (n * 3 + m) as u64);
        // Non-zero initial gradient: both kernels must *accumulate*.
        let mut g_ref = randv(k * n, 99);
        let mut g = g_ref.clone();
        gemm::naive::gemm_at_b_acc(&a, &d, &mut g_ref, m, k, n);
        gemm::gemm_at_b_acc(&a, &d, &mut g, m, k, n);
        assert_close(&g_ref, &g, &format!("at_b {m}x{k}x{n}"));
    }
}

#[test]
fn parallel_wrappers_are_bit_identical_to_serial() {
    // Large enough that the pool heuristic actually engages.
    let (m, k, n) = (256, 300, 100);
    let a = randv(m * k, 1);
    let b = randv(k * n, 2);
    let bias = randv(n, 3);
    let mut c_ser = vec![0.0; m * n];
    let mut c_par = vec![0.0; m * n];
    gemm::gemm_bias_act(&a, &b, Some(&bias), &mut c_ser, m, k, n, true);
    gemm::gemm_bias_act_par(&a, &b, Some(&bias), &mut c_par, m, k, n, true);
    assert_eq!(c_ser, c_par, "forward parallel != serial");

    let d = randv(m * n, 4);
    let mut g_ser = vec![0.0; k * n];
    let mut g_par = vec![0.0; k * n];
    gemm::gemm_at_b_acc(&a, &d, &mut g_ser, m, k, n);
    gemm::gemm_at_b_acc_par(&a, &d, &mut g_par, m, k, n);
    assert_eq!(g_ser, g_par, "grad parallel != serial");
}

#[test]
fn transpose_matches_index_shuffle_on_odd_shapes() {
    for &(r, c) in &[(1usize, 19usize), (19, 1), (31, 33), (100, 7)] {
        let src = randv(r * c, (r * 100 + c) as u64);
        let mut dst = vec![0.0; r * c];
        gemm::transpose(&src, &mut dst, r, c);
        for i in 0..r {
            for j in 0..c {
                assert_eq!(dst[j * r + i], src[i * c + j], "({i},{j})");
            }
        }
    }
}

#[test]
fn mlp_gradient_survives_odd_batch_sizes() {
    // End-to-end: the blocked forward/backward must stay a valid
    // gradient at batch sizes that are not tile multiples.
    let arch = ArchSpec::new("odd", &[11, 9, 5]);
    let mut r = Xoshiro256pp::seed_from(42);
    let w: Vec<f32> = (0..arch.num_params()).map(|_| (r.next_f32() - 0.5) * 0.6).collect();
    for b in [1usize, 3, 5, 13] {
        let x: Vec<f32> = (0..b * 11).map(|_| r.next_f32() - 0.5).collect();
        let mut y = vec![0.0f32; b * 5];
        for row in 0..b {
            y[row * 5 + row % 5] = 1.0;
        }
        let mut mlp = MlpRef::new(arch.clone(), 16);
        let mut g = vec![0.0f32; w.len()];
        mlp.train_step(&w, &x, &y, b, &mut g);
        let mut wp = w.clone();
        let eps = 1e-3f32;
        for idx in [0usize, 7, arch.num_params() - 1] {
            let orig = wp[idx];
            wp[idx] = orig + eps;
            let lp = mlp.eval_step(&wp, &x, &y, b).loss;
            wp[idx] = orig - eps;
            let lm = mlp.eval_step(&wp, &x, &y, b).loss;
            wp[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[idx]).abs() < 3e-2 * (1.0 + fd.abs()),
                "b={b} idx={idx} fd={fd} analytic={}",
                g[idx]
            );
        }
    }
}
