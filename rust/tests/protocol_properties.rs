// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Fuzz-style negative tests for the wire decoders: **no frame
//! constructible from arbitrary bytes may panic** `decode_client` /
//! `decode_server` / `decode_shard` — truncated, oversized,
//! forged-length, bad-tag, all of it must come back as `Err` or a valid
//! message, never a crash or a silently garbage decode.  Driven by the
//! in-tree property harness (`util::prop`), deterministic seeds
//! throughout.  This file is the executable appendix of
//! `docs/PROTOCOL.md` — every rule the spec states about malformed
//! input is asserted here.

use zampling::federated::protocol::{
    decode_client, decode_server, decode_shard, encode_client, encode_server, encode_shard,
    ClientMsg, MaskCodec, ServerMsg, ShardMsg, MAX_MASK_LEN, MAX_PEER_COUNT,
};
use zampling::rng::Rng;
use zampling::util::prop::{for_all, Gen};

fn random_bytes(g: &mut Gen, len: usize) -> Vec<u8> {
    (0..len).map(|_| g.rng.next_u64() as u8).collect()
}

/// A random mask frame, both codecs, valid by construction.
fn random_mask_frame(g: &mut Gen) -> Vec<u8> {
    let n = g.usize_in(0, 800);
    let density = g.f64_in(0.0, 1.0);
    let mask: Vec<bool> = (0..n).map(|_| g.bool_p(density)).collect();
    let codec = if g.bool_p(0.5) { MaskCodec::Raw } else { MaskCodec::Arithmetic };
    let round = g.usize_in(0, 1000) as u32;
    let client = g.usize_in(0, 64) as u32;
    encode_client(&ClientMsg::Mask { round, client, n, mask }, codec)
}

/// Patch a frame's little-endian length field to match `body_len`.
fn set_frame_len(frame: &mut [u8], body_len: usize) {
    frame[1..5].copy_from_slice(&(body_len as u32).to_le_bytes());
}

#[test]
fn arbitrary_bytes_never_panic_either_decoder() {
    for_all(
        "decode(arbitrary bytes) never panics",
        400,
        0xFEED,
        |g| {
            let len = g.usize_in(0, 64);
            let mut buf = random_bytes(g, len);
            // Half the time, plant a plausible tag and a consistent
            // length field so deeper branches are exercised.
            if !buf.is_empty() && g.bool_p(0.5) {
                buf[0] = g.usize_in(0, 11) as u8;
                if buf.len() >= 5 && g.bool_p(0.5) {
                    let body = buf.len() - 5;
                    set_frame_len(&mut buf, body);
                }
            }
            buf
        },
        |buf| {
            // Outcome may be Ok or Err; only a panic is a failure, and
            // the harness turns panics into test failures for us.
            let _ = decode_client(buf);
            let _ = decode_server(buf);
            let _ = decode_shard(buf);
            Ok(())
        },
    );
}

/// A random, valid-by-construction `ShardVotes` merge frame.
fn random_votes_frame(g: &mut Gen) -> Vec<u8> {
    let n = g.usize_in(0, 400);
    let received = g.usize_in(0, 32) as u32;
    let votes: Vec<u32> = (0..n).map(|_| g.usize_in(0, received as usize) as u32).collect();
    encode_shard(&ShardMsg::ShardVotes {
        shard: g.usize_in(0, 16) as u32,
        round: g.usize_in(0, 1000) as u32,
        received,
        n,
        votes,
    })
}

#[test]
fn shard_votes_roundtrip_and_reject_mutation() {
    for_all(
        "ShardVotes roundtrip; truncation and forged sums error",
        150,
        0x5A5A,
        |g| {
            let frame = random_votes_frame(g);
            let cut = g.usize_in(0, frame.len().saturating_sub(1));
            let forged_vote = g.usize_in(33, 1 << 20) as u32; // > any received
            (frame, cut, forged_vote)
        },
        |(frame, cut, forged_vote)| {
            // 1. the untouched frame roundtrips
            match decode_shard(frame) {
                Ok(ShardMsg::ShardVotes { n, votes, received, .. }) => {
                    if votes.len() != n {
                        return Err(format!("votes len {} != n {n}", votes.len()));
                    }
                    if votes.iter().any(|&v| v > received) {
                        return Err("decoded an impossible vote sum".into());
                    }
                }
                Err(e) => return Err(format!("valid merge frame rejected: {e}")),
            }
            // 2. self-consistent truncation always errors
            let mut bad = frame[..*cut].to_vec();
            if bad.len() >= 5 {
                let body = bad.len() - 5;
                set_frame_len(&mut bad, body);
            }
            if decode_shard(&bad).is_ok() {
                return Err(format!("truncated merge frame decoded (cut={cut})"));
            }
            // 3. a vote sum above the declared received count errors
            if frame.len() > 21 {
                let mut bad = frame.clone();
                bad[21..25].copy_from_slice(&forged_vote.to_le_bytes());
                if decode_shard(&bad).is_ok() {
                    return Err(format!("impossible vote sum {forged_vote} decoded"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn truncated_valid_frames_error_never_panic() {
    for_all(
        "truncating a valid Mask frame yields Err",
        120,
        0xBEEF,
        |g| {
            let frame = random_mask_frame(g);
            let cut = g.usize_in(0, frame.len().saturating_sub(1));
            (frame, cut)
        },
        |(frame, cut)| {
            // Truncate and re-declare the length so the frame is
            // self-consistent (read_frame always hands decoders exact
            // frames; a short *declared payload* is the real attack).
            let mut bad = frame[..*cut].to_vec();
            if bad.len() >= 5 {
                let body = bad.len() - 5;
                set_frame_len(&mut bad, body);
            }
            match decode_client(&bad) {
                Err(_) => Ok(()),
                Ok(msg) => Err(format!("truncated frame decoded to {msg:?}")),
            }
        },
    );
}

#[test]
fn oversized_valid_frames_error_never_panic() {
    for_all(
        "padding a valid Mask frame yields Err",
        120,
        0xCAFE,
        |g| {
            let frame = random_mask_frame(g);
            let extra = g.usize_in(1, 32);
            (frame, extra)
        },
        |(frame, extra)| {
            let mut bad = frame.clone();
            bad.resize(frame.len() + extra, 0x5A);
            let body = bad.len() - 5;
            set_frame_len(&mut bad, body);
            match decode_client(&bad) {
                Err(_) => Ok(()),
                Ok(msg) => Err(format!("padded frame decoded to {msg:?}")),
            }
        },
    );
}

#[test]
fn forged_mask_length_fields_error_never_panic() {
    for_all(
        "forging the n field yields Err",
        120,
        0xD00D,
        |g| {
            let frame = random_mask_frame(g);
            // Forge n: sometimes huge (allocation-bomb attempt),
            // sometimes off by a little.
            let forged_n: u32 = if g.bool_p(0.5) {
                (MAX_MASK_LEN as u32).saturating_add(g.usize_in(1, 1 << 20) as u32)
            } else {
                g.usize_in(0, 2000) as u32
            };
            (frame, forged_n)
        },
        |(frame, forged_n)| {
            // The n field sits at payload offset 8 → frame offset 13.
            let mut bad = frame.clone();
            let original = u32::from_le_bytes(bad[13..17].try_into().unwrap());
            if original == *forged_n {
                return Ok(()); // not actually forged; skip
            }
            bad[13..17].copy_from_slice(&forged_n.to_le_bytes());
            match decode_client(&bad) {
                Err(_) => Ok(()),
                // A forged n may still be self-consistent (e.g. a raw
                // mask shortened within the same 64-bit word, or an
                // arithmetic stream that happens to consume exactly).
                // That is acceptable; what is NOT acceptable is a
                // decode whose n exceeds the cap or whose mask length
                // disagrees with its own header — that was the seed's
                // garbage-decode bug.
                Ok(ClientMsg::Mask { n, mask, .. }) => {
                    if *forged_n as usize > MAX_MASK_LEN {
                        Err(format!("over-cap n={forged_n} decoded"))
                    } else if n == *forged_n as usize && mask.len() == n {
                        Ok(())
                    } else {
                        Err(format!("forged n={forged_n} decoded inconsistently (n={n})"))
                    }
                }
                Ok(msg) => Err(format!("forged n decoded to {msg:?}")),
            }
        },
    );
}

#[test]
fn bad_tags_error_never_panic() {
    for_all(
        "unknown tags yield Err",
        100,
        0xABCD,
        |g| {
            let mut frame = random_mask_frame(g);
            // 8 = ShardVotes, 9 = PeerRound, 10 = Report are real tags
            // (for *other* decoders); everything past them is unknown.
            frame[0] = g.usize_in(11, 255) as u8;
            frame
        },
        |frame| {
            if decode_client(frame).is_err() && decode_server(frame).is_err() {
                Ok(())
            } else {
                Err("unknown tag decoded".into())
            }
        },
    );
}

/// A random, valid-by-construction `PeerRound` gossip kick-off frame.
fn random_peer_round_frame(g: &mut Gen) -> Vec<u8> {
    let count = g.usize_in(0, 64);
    // strictly ascending ids with random gaps
    let mut participants = Vec::with_capacity(count);
    let mut next = 0u32;
    for _ in 0..count {
        next += g.usize_in(1, 5) as u32;
        participants.push(next);
    }
    let round = g.usize_in(0, 1000) as u32;
    encode_server(&ServerMsg::PeerRound { round, participants })
}

#[test]
fn peer_round_roundtrip_and_reject_mutation() {
    for_all(
        "PeerRound roundtrip; truncation, forged counts, shuffles error",
        150,
        0x60551,
        |g| {
            let frame = random_peer_round_frame(g);
            let cut = g.usize_in(0, frame.len().saturating_sub(1));
            (frame, cut)
        },
        |(frame, cut)| {
            // 1. the untouched frame roundtrips to a canonical id set
            match decode_server(frame) {
                Ok(ServerMsg::PeerRound { participants, .. }) => {
                    if !participants.windows(2).all(|w| w[0] < w[1]) {
                        return Err("decoded a non-ascending participant set".into());
                    }
                }
                other => return Err(format!("valid PeerRound rejected: {other:?}")),
            }
            // 2. self-consistent truncation always errors (the body is
            // 8 + 4·count, so any cut breaks the length equation)
            let mut bad = frame[..*cut].to_vec();
            if bad.len() >= 5 {
                let body = bad.len() - 5;
                set_frame_len(&mut bad, body);
            }
            if decode_server(&bad).is_ok() {
                return Err(format!("truncated PeerRound decoded (cut={cut})"));
            }
            // 3. a forged over-cap count errors before any allocation
            if frame.len() >= 13 {
                let mut bad = frame.clone();
                let forged = (MAX_PEER_COUNT as u32).saturating_add(1);
                bad[5 + 4..5 + 8].copy_from_slice(&forged.to_le_bytes());
                if decode_server(&bad).is_ok() {
                    return Err("over-cap participant count decoded".into());
                }
                // 4. swapping two ids breaks strict ascent
                if frame.len() >= 5 + 8 + 8 {
                    let mut bad = frame.clone();
                    let (a, b) = (5 + 8, 5 + 12);
                    for i in 0..4 {
                        bad.swap(a + i, b + i);
                    }
                    if decode_server(&bad).is_ok() {
                        return Err("shuffled participant ids decoded".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// A random, valid-by-construction gossip `Report` frame.
fn random_report_frame(g: &mut Gen) -> Vec<u8> {
    let n = g.usize_in(0, 300);
    let probs = g.f32_vec(n, 0.0, 1.0);
    encode_client(
        &ClientMsg::Report {
            round: g.usize_in(0, 1000) as u32,
            client: g.usize_in(0, 64) as u32,
            loss: g.f64_in(0.0, 10.0),
            probs,
        },
        MaskCodec::Raw,
    )
}

#[test]
fn report_roundtrip_and_reject_poison() {
    for_all(
        "Report roundtrip; truncation and poisoned values error",
        150,
        0x8E907,
        |g| {
            let frame = random_report_frame(g);
            let cut = g.usize_in(0, frame.len().saturating_sub(1));
            let poison = [2.0f32, -1.0, f32::NAN, f32::INFINITY][g.usize_in(0, 3)];
            (frame, cut, poison)
        },
        |(frame, cut, poison)| {
            // 1. the untouched frame roundtrips with in-range probs
            match decode_client(frame) {
                Ok(ClientMsg::Report { probs, .. }) => {
                    if probs.iter().any(|p| !(0.0..=1.0).contains(p)) {
                        return Err("decoded an out-of-range report".into());
                    }
                }
                other => return Err(format!("valid Report rejected: {other:?}")),
            }
            // 2. self-consistent truncation always errors
            let mut bad = frame[..*cut].to_vec();
            if bad.len() >= 5 {
                let body = bad.len() - 5;
                set_frame_len(&mut bad, body);
            }
            if decode_client(&bad).is_ok() {
                return Err(format!("truncated Report decoded (cut={cut})"));
            }
            // 3. a poisoned probability (out of range / NaN / inf) errors
            if frame.len() > 5 + 24 {
                let mut bad = frame.clone();
                bad[5 + 20..5 + 24].copy_from_slice(&poison.to_le_bytes());
                if decode_client(&bad).is_ok() {
                    return Err(format!("poisoned prob {poison} decoded"));
                }
            }
            // 4. loss is advisory telemetry: even a NaN loss decodes
            // verbatim (it never feeds model state), with probs intact
            let mut odd = frame.clone();
            odd[5 + 12..5 + 20].copy_from_slice(&f64::NAN.to_le_bytes());
            match decode_client(&odd) {
                Ok(ClientMsg::Report { loss, .. }) if loss.is_nan() => Ok(()),
                other => Err(format!("NaN-loss report mishandled: {other:?}")),
            }
        },
    );
}

#[test]
fn valid_frames_still_roundtrip_under_the_hardening() {
    for_all(
        "hardened decoders accept valid frames",
        120,
        0x1234,
        |g| {
            let n = g.usize_in(0, 500);
            let density = g.f64_in(0.0, 1.0);
            let mask: Vec<bool> = (0..n).map(|_| g.bool_p(density)).collect();
            let codec = if g.bool_p(0.5) { MaskCodec::Raw } else { MaskCodec::Arithmetic };
            (ClientMsg::Mask { round: 3, client: 1, n, mask }, codec)
        },
        |(msg, codec)| {
            let frame = encode_client(msg, *codec);
            match decode_client(&frame) {
                Ok(back) if back == *msg => Ok(()),
                Ok(back) => Err(format!("roundtrip mismatch: {back:?}")),
                Err(e) => Err(format!("valid frame rejected: {e}")),
            }
        },
    );
}

#[test]
fn server_round_frames_roundtrip_and_reject_truncation() {
    for_all(
        "Round frames roundtrip; truncations error",
        120,
        0x9999,
        |g| {
            let n = g.usize_in(0, 300);
            g.f32_vec(n, 0.0, 1.0)
        },
        |probs| {
            let frame = encode_server(&ServerMsg::Round { round: 9, probs: probs.clone() });
            match decode_server(&frame) {
                Ok(ServerMsg::Round { round: 9, probs: back }) if back == *probs => {}
                other => return Err(format!("roundtrip failed: {other:?}")),
            }
            // Chopping one byte misaligns the f32 body (4 + 4n − 1), so
            // the declared-length truncation must always error.
            let mut bad = frame[..frame.len() - 1].to_vec();
            set_frame_len(&mut bad, bad.len() - 5);
            if decode_server(&bad).is_ok() {
                return Err("one-byte-truncated Round frame decoded".into());
            }
            Ok(())
        },
    );
}
