// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Integration: the full federated protocol over the real TCP transport —
//! leader thread + worker threads in one process, real sockets, real
//! frames.  Because the TCP worker drives the *same* `client_round` body
//! as the in-process simulator and the leader runs the *same*
//! `RoundEngine` over a `TcpTransport`, the transport must agree with
//! the simulator **byte-for-byte** (final probabilities and ledger
//! bits), under full and partial participation alike.  A further test
//! pins the engine against a hand-rolled replica of the seed's
//! sequential driver: with `participation = 1.0` and no timeout the new
//! code must be byte-identical to the old behavior.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use zampling::comm::CommLedger;
use zampling::config::FedConfig;
use zampling::data::Dataset;
use zampling::federated::gossip::{run_gossip, run_gossip_wire, run_peer, GossipOutcome, Topology};
use zampling::federated::protocol::{
    decode_client, decode_server, encode_client, encode_server, peek_server_frame, ClientMsg,
    MaskCodec, ServerFrameKind, ServerMsg,
};
use zampling::federated::transport::{Leader, ShardedTransport, TcpTransport, Worker};
use zampling::federated::{
    client_round, make_policy, pack_client_mask, run_federated, RoundEngine, Server, ShardPlan,
};
use zampling::nn::ArchSpec;
use zampling::rng::SeedTree;
use zampling::sparse::QMatrix;
use zampling::zampling::{LocalZampling, NativeExecutor, ProbVector};

fn ci_cfg(clients: usize) -> FedConfig {
    let mut cfg = FedConfig::paper(8);
    cfg.train.arch = ArchSpec::small();
    cfg.train.n = ArchSpec::small().num_params() / 8;
    cfg.train.d = 5;
    cfg.train.lr = 0.1;
    cfg.train.seed = 1;
    cfg.clients = clients;
    cfg.rounds = 4;
    cfg.local_epochs = 1;
    cfg
}

fn ci_data(cfg: &FedConfig) -> (Vec<Dataset>, Dataset) {
    let seeds = SeedTree::new(cfg.train.seed);
    let (train, test) = Dataset::synthetic_pair(1_024, 256, &seeds);
    (train.partition_iid(cfg.clients, &seeds), test)
}

/// A worker thread running the production round body (`client_round`)
/// over the wire — the same code path as `repro serve-client`.
fn spawn_worker(cfg: FedConfig, addr: String, shard: Dataset, k: usize) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        let seeds = SeedTree::new(cfg.train.seed);
        let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
        let csc = Arc::new(q.to_csc(None));
        let sub = seeds.subtree("client", k as u64);
        let mut state = LocalZampling::from_parts(
            &cfg.train,
            q,
            csc,
            ProbVector::from_probs(vec![0.5; cfg.train.n]),
            &sub,
        );
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let codec = if cfg.entropy_code_uplink { MaskCodec::Arithmetic } else { MaskCodec::Raw };
        let mut w = Worker::connect(&addr, k as u32, codec).expect("connect");
        loop {
            let frame = w.recv_raw().expect("recv");
            match peek_server_frame(&frame).expect("server frame") {
                ServerFrameKind::Round => {
                    let out = client_round(
                        &cfg, &mut state, &mut exec, &shard, &seeds, &frame, codec, k, None,
                    )
                    .expect("client round");
                    w.send_frame(&out.frame).expect("send mask");
                }
                ServerFrameKind::Shutdown => return,
                ServerFrameKind::PeerRound => {
                    panic!("client {k}: gossip PeerRound on the centralized wire")
                }
            }
        }
    })
}

/// The production leader orchestration: the `RoundEngine` over a
/// `TcpTransport` — the exact code path `repro train-federated
/// --transport tcp` runs.  Returns the final probs, the engine's
/// ledger, and the total drop count.
fn run_leader(
    listener: TcpListener,
    cfg: &FedConfig,
    test: &Dataset,
) -> (Vec<f32>, CommLedger, u64) {
    let leader = Leader::from_listener(listener, cfg.clients).expect("accept");
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();
    let exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let engine = RoundEngine::new(cfg, cfg.clients, q, p0, test, 2, cfg.rounds, "federated_tcp");
    let mut transport = TcpTransport::new(leader, Box::new(exec));
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut()).expect("leader engine");
    let dropped = out.ledger.total_dropped();
    (out.final_probs, out.ledger, dropped)
}

/// The production sharded-root orchestration: the `RoundEngine` over a
/// `ShardedTransport` — the code path `repro train-federated
/// --transport sharded` runs.
fn run_sharded_leader(
    listeners: Vec<std::net::TcpListener>,
    cfg: &FedConfig,
    test: &Dataset,
) -> (Vec<f32>, CommLedger, u64) {
    let plan = ShardPlan::new(cfg.clients, listeners.len());
    let mut transport = ShardedTransport::from_listeners(
        listeners,
        plan,
        Box::new(NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500)),
    )
    .expect("sharded accept");
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();
    let engine =
        RoundEngine::new(cfg, cfg.clients, q, p0, test, 2, cfg.rounds, "federated_sharded");
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut()).expect("sharded engine");
    let dropped = out.ledger.total_dropped();
    (out.final_probs, out.ledger, dropped)
}

/// Bind one listener per shard and spawn one production worker per
/// client, each dialing its own shard's leader with its global id.
fn launch_sharded(
    cfg: &FedConfig,
    shards: &[Dataset],
    test: &Dataset,
    num_shards: usize,
) -> (Vec<f32>, CommLedger, u64) {
    let plan = ShardPlan::new(cfg.clients, num_shards);
    let listeners: Vec<std::net::TcpListener> = (0..num_shards)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    let leader_cfg = cfg.clone();
    let leader_test = test.clone();
    let leader = thread::spawn(move || run_sharded_leader(listeners, &leader_cfg, &leader_test));
    let workers: Vec<_> = shards
        .iter()
        .enumerate()
        .map(|(k, shard)| {
            spawn_worker(cfg.clone(), addrs[plan.owner(k)].clone(), shard.clone(), k)
        })
        .collect();
    let result = leader.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }
    result
}

/// S = 1 must collapse the sharded topology to the single-leader one:
/// byte-identical final probabilities and ledger vs the `TcpTransport`
/// path over the same workers.
#[test]
fn sharded_transport_with_one_shard_is_byte_identical_to_tcp() {
    let cfg = ci_cfg(3);
    let (shards, test) = ci_data(&cfg);

    // --- reference: the single-leader TCP path ---
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_test = test.clone();
    let leader = thread::spawn(move || run_leader(listener, &leader_cfg, &leader_test));
    let tcp_workers: Vec<_> = shards
        .iter()
        .enumerate()
        .map(|(k, shard)| spawn_worker(cfg.clone(), addr.clone(), shard.clone(), k))
        .collect();
    let (tcp_probs, tcp_ledger, tcp_dropped) = leader.join().unwrap();
    for w in tcp_workers {
        w.join().unwrap();
    }

    // --- sharded root with a single shard ---
    let (probs, ledger, dropped) = launch_sharded(&cfg, &shards, &test, 1);

    assert_eq!(probs, tcp_probs, "S=1 sharded diverged from TcpTransport");
    assert_eq!(dropped, tcp_dropped);
    assert_eq!(ledger.rounds.len(), tcp_ledger.rounds.len());
    for (r, s) in ledger.rounds.iter().zip(&tcp_ledger.rounds) {
        assert_eq!(r.uplink_bits, s.uplink_bits);
        assert_eq!(r.downlink_bits, s.downlink_bits);
        assert_eq!(r.participants, s.participants);
        assert_eq!(r.clients, s.clients);
        assert_eq!(r.dropped, s.dropped);
    }
    // the only sharded-specific addition is the per-shard table
    assert_eq!(ledger.shard_rounds.len(), ledger.rounds.len());
    assert!(ledger.shard_rounds.iter().all(|per| per.len() == 1));
}

/// Multi-shard roots must train the same numbers as the in-process
/// simulator at full participation: the shard merge is exact.
#[test]
fn sharded_transport_matches_simulator_across_shard_counts() {
    let cfg = ci_cfg(3);
    let (shards, test) = ci_data(&cfg);

    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let sim = run_federated(&cfg, &mut exec, &shards, &test, 10, cfg.rounds - 1);

    for num_shards in [2usize, 3] {
        let (probs, ledger, dropped) = launch_sharded(&cfg, &shards, &test, num_shards);
        assert_eq!(probs, sim.final_probs, "S={num_shards} diverged from the simulator");
        assert_eq!(dropped, 0, "S={num_shards}");
        assert_eq!(ledger.rounds.len(), sim.ledger.rounds.len());
        for (r, s) in ledger.rounds.iter().zip(&sim.ledger.rounds) {
            assert_eq!(r.uplink_bits, s.uplink_bits, "S={num_shards}");
            assert_eq!(r.downlink_bits, s.downlink_bits, "S={num_shards}");
            assert_eq!(r.participants, s.participants, "S={num_shards}");
            assert_eq!(r.clients, s.clients, "S={num_shards}");
        }
        // per-shard columns reconcile with the round totals
        for (round, per_shard) in ledger.rounds.iter().zip(&ledger.shard_rounds) {
            assert_eq!(per_shard.len(), num_shards);
            let up: u64 = per_shard.iter().map(|c| c.uplink_bits).sum();
            assert_eq!(up, round.uplink_bits, "S={num_shards}");
            assert!(per_shard.iter().all(|c| c.merge_bits > 0), "S={num_shards}");
        }
    }
}

#[test]
fn tcp_transport_matches_simulator_byte_for_byte() {
    let cfg = ci_cfg(3);
    let (shards, test) = ci_data(&cfg);

    // --- reference: in-process simulator ---
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let sim = run_federated(&cfg, &mut exec, &shards, &test, 10, cfg.rounds - 1);
    assert!(
        sim.log.rounds.last().unwrap().mean_sampled_acc > 0.3,
        "simulator failed to learn"
    );

    // --- real transport: leader + workers on loopback ---
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_test = test.clone();
    let leader = thread::spawn(move || run_leader(listener, &leader_cfg, &leader_test));
    let workers: Vec<_> = shards
        .iter()
        .enumerate()
        .map(|(k, shard)| spawn_worker(cfg.clone(), addr.clone(), shard.clone(), k))
        .collect();
    let (tcp_probs, ledger, dropped) = leader.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    // Same seeds, same round bodies, same engine: byte-identical.
    assert_eq!(tcp_probs, sim.final_probs, "TCP and simulator probabilities diverged");
    assert_eq!(dropped, 0);
    assert_eq!(ledger.rounds.len(), sim.ledger.rounds.len());
    for (r, s) in ledger.rounds.iter().zip(&sim.ledger.rounds) {
        assert_eq!(r.uplink_bits, s.uplink_bits);
        assert_eq!(r.downlink_bits, s.downlink_bits);
        assert_eq!(r.participants, s.participants);
        assert_eq!(r.clients, s.clients);
    }
}

#[test]
fn tcp_partial_participation_matches_simulator() {
    let mut cfg = ci_cfg(4);
    cfg.participation = 0.5;
    let (shards, test) = ci_data(&cfg);

    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let sim = run_federated(&cfg, &mut exec, &shards, &test, 4, cfg.rounds - 1);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let leader_cfg = cfg.clone();
    let leader_test = test.clone();
    let leader = thread::spawn(move || run_leader(listener, &leader_cfg, &leader_test));
    let workers: Vec<_> = shards
        .iter()
        .enumerate()
        .map(|(k, shard)| spawn_worker(cfg.clone(), addr.clone(), shard.clone(), k))
        .collect();
    let (tcp_probs, ledger, dropped) = leader.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    assert_eq!(tcp_probs, sim.final_probs, "partial-participation runs diverged");
    assert_eq!(dropped, 0);
    assert_eq!(ledger.rounds.len(), sim.ledger.rounds.len());
    for (r, s) in ledger.rounds.iter().zip(&sim.ledger.rounds) {
        assert_eq!(r.participants, 2, "0.5 of 4 clients");
        assert_eq!(r.participants, s.participants);
        assert_eq!(r.clients, s.clients);
        assert_eq!(r.uplink_bits, s.uplink_bits);
        assert_eq!(r.downlink_bits, s.downlink_bits);
    }
}

/// Launch a full wire-gossip run on loopback: one coordinator thread
/// (the `RoundEngine` over a `WirePeerTransport` — the exact code path
/// `repro train-federated --transport gossip-tcp` runs) plus one
/// production `run_peer` thread per node (the `repro serve-peer` body).
/// Every listener is bound before any thread starts, so there are no
/// connect races.  `die_after[i]` makes peer `i` exit right after
/// reporting that round — the kill-one-peer chaos knob.
fn launch_gossip_wire(
    cfg: &FedConfig,
    topo: &Topology,
    shards: &[Dataset],
    test: &Dataset,
    die_after: &[Option<u32>],
    eval_samples: usize,
    eval_every: usize,
) -> GossipOutcome {
    let coord = TcpListener::bind("127.0.0.1:0").unwrap();
    let coord_addr = coord.local_addr().unwrap().to_string();
    let listeners: Vec<TcpListener> =
        (0..topo.len()).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();

    let peers: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(i, listener)| {
            let (cfg, topo, addrs, coord_addr) =
                (cfg.clone(), topo.clone(), addrs.clone(), coord_addr.clone());
            let shard = shards[i].clone();
            let die = die_after[i];
            thread::spawn(move || {
                let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
                run_peer(&cfg, &topo, i, listener, &addrs, &coord_addr, &mut exec, &shard, die)
                    .expect("peer");
            })
        })
        .collect();

    let exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let out =
        run_gossip_wire(cfg, topo, coord, test, eval_samples, eval_every, Box::new(exec), false)
            .expect("gossip coordinator");
    for p in peers {
        p.join().unwrap();
    }
    out
}

/// The acceptance bar of the wire-gossip redesign: on every named
/// topology, decentralized rounds over real sockets must produce
/// **byte-identical** consensus probs, node probs, comm ledgers
/// (including the per-directed-edge table), and run logs versus the
/// in-process `PeerTransport` at the same seed.
#[test]
fn wire_gossip_matches_in_process_gossip_byte_for_byte() {
    let cfg = ci_cfg(3);
    let (shards, test) = ci_data(&cfg);

    for topo in [Topology::ring(3), Topology::complete(3), Topology::star(3)] {
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let local = run_gossip(&cfg, &topo, &mut exec, &shards, &test, 3, 2);
        let wire = launch_gossip_wire(&cfg, &topo, &shards, &test, &[None; 3], 3, 2);

        assert_eq!(wire.final_probs, local.final_probs, "consensus diverged on {topo:?}");
        assert_eq!(wire.node_probs, local.node_probs, "node probs diverged on {topo:?}");
        assert_eq!(wire.ledger.rounds.len(), local.ledger.rounds.len());
        for (w, l) in wire.ledger.rounds.iter().zip(&local.ledger.rounds) {
            assert_eq!(w.uplink_bits, l.uplink_bits, "{topo:?}");
            assert_eq!(w.downlink_bits, l.downlink_bits, "{topo:?}");
            assert_eq!(w.clients, l.clients, "{topo:?}");
            assert_eq!(w.participants, l.participants, "{topo:?}");
            assert_eq!(w.dropped, l.dropped, "{topo:?}");
        }
        // the per-directed-edge tables agree row for row
        assert_eq!(wire.ledger.edge_rounds, local.ledger.edge_rounds, "{topo:?}");
        assert_eq!(wire.ledger.total_edge_bits(), wire.ledger.total_uplink_bits());
        // and the run logs (consensus evals + real per-node losses) too
        assert_eq!(wire.log.rounds.len(), local.log.rounds.len());
        for (w, l) in wire.log.rounds.iter().zip(&local.log.rounds) {
            assert_eq!(w.round, l.round);
            assert_eq!(w.mean_sampled_acc, l.mean_sampled_acc, "{topo:?} round {}", w.round);
            assert_eq!(w.sampled_acc_std, l.sampled_acc_std, "{topo:?} round {}", w.round);
            assert_eq!(w.expected_acc, l.expected_acc, "{topo:?} round {}", w.round);
            assert_eq!(w.train_loss, l.train_loss, "{topo:?} round {}", w.round);
            assert_eq!(w.uplink_bits, l.uplink_bits);
            assert_eq!(w.downlink_bits, l.downlink_bits);
        }
    }
}

/// Same byte-identity bar under partial participation: only the
/// round's selected subset trains and gossips (the `PeerRound` frame's
/// participant set), non-participants' vectors are carried by the
/// coordinator's cache exactly like untouched in-process nodes.
#[test]
fn wire_gossip_partial_participation_matches_in_process() {
    let mut cfg = ci_cfg(3);
    cfg.participation = 0.5; // 2 of 3 nodes per round, seeded subsets
    let (shards, test) = ci_data(&cfg);

    for topo in [Topology::ring(3), Topology::star(3)] {
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let local = run_gossip(&cfg, &topo, &mut exec, &shards, &test, 2, 2);
        let wire = launch_gossip_wire(&cfg, &topo, &shards, &test, &[None; 3], 2, 2);

        assert_eq!(wire.final_probs, local.final_probs, "consensus diverged on {topo:?}");
        assert_eq!(wire.node_probs, local.node_probs, "node probs diverged on {topo:?}");
        assert_eq!(wire.ledger.edge_rounds, local.ledger.edge_rounds, "{topo:?}");
        for (w, l) in wire.ledger.rounds.iter().zip(&local.ledger.rounds) {
            assert_eq!(w.participants, 2, "{topo:?}");
            assert_eq!(w.uplink_bits, l.uplink_bits, "{topo:?}");
            assert_eq!(w.clients, l.clients, "{topo:?}");
            assert_eq!(w.dropped, l.dropped, "{topo:?}");
        }
    }
}

/// Kill one peer mid-run: after its round-1 report, ring node 2 exits.
/// The coordinator must drop it from every later round and its
/// surviving neighbours must renormalize their tiny aggregations over
/// whatever masks still arrive — the run completes, keeps learning
/// state sane, and bills only the edges that still carry traffic's
/// senders.
#[test]
fn wire_gossip_survives_a_killed_peer() {
    let mut cfg = ci_cfg(3);
    cfg.rounds = 4;
    // Safety net only: drops are detected via connection loss (Gone
    // events), not by waiting out the deadline.
    cfg.round_timeout_ms = 20_000;
    let (shards, test) = ci_data(&cfg);
    let topo = Topology::ring(3);

    let wire = launch_gossip_wire(&cfg, &topo, &shards, &test, &[None, None, Some(1)], 2, 1);

    assert_eq!(wire.ledger.rounds.len(), 4);
    let n = cfg.train.n as u64;
    for (r, round) in wire.ledger.rounds.iter().enumerate() {
        assert_eq!(round.participants, 3, "round {r}");
        if r <= 1 {
            assert_eq!(round.clients, 3, "round {r}");
            assert_eq!(round.dropped, 0, "round {r}");
            assert_eq!(round.uplink_bits, 6 * n, "round {r}: 6 live directed edges");
        } else {
            assert_eq!(round.clients, 2, "round {r}: survivors only");
            assert_eq!(round.dropped, 1, "round {r}: the dead peer");
            // each survivor still ships to both its ring neighbours
            // (the dead one was selected; delivery is not guaranteed)
            assert_eq!(round.uplink_bits, 4 * n, "round {r}");
        }
        // per-edge rows always reconcile with the round total
        let edges = &wire.ledger.edge_rounds[r];
        assert_eq!(edges.iter().map(|e| e.bits).sum::<u64>(), round.uplink_bits);
        // post-kill, node 2 sends nothing
        if r > 1 {
            assert!(edges.iter().all(|e| e.from != 2), "round {r}");
        }
    }
    // consensus stays a valid probability vector (survivors' tiny
    // servers renormalized over the masks that actually arrived)
    assert!(wire.final_probs.iter().all(|p| (0.0..=1.0).contains(p)));
    assert_eq!(wire.node_probs.len(), 3);
}

/// Replica of the seed's sequential `run_federated` loop (pre-RoundPlan,
/// pre-fault-tolerance), built from public API pieces.  The refactored
/// driver with `participation = 1.0` and no timeout must reproduce it
/// byte-for-byte — the "no behavior change at defaults" guarantee.
fn legacy_sequential_driver(cfg: &FedConfig, shards: &[Dataset]) -> (Vec<f32>, Vec<(u64, u64)>) {
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let mut init_rng = seeds.rng("p-init", 0);
    let mut server =
        Server::new(ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec());
    let mut clients: Vec<LocalZampling> = (0..cfg.clients)
        .map(|k| {
            let sub = seeds.subtree("client", k as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(server.probs.clone()),
                &sub,
            )
        })
        .collect();
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let mut rows = Vec::new();
    for round in 0..cfg.rounds {
        let round_msg =
            encode_server(&ServerMsg::Round { round: round as u32, probs: server.probs.clone() });
        let (mut up_bits, mut down_bits) = (0u64, 0u64);
        for (k, client) in clients.iter_mut().enumerate() {
            let ServerMsg::Round { probs, .. } = decode_server(&round_msg).unwrap() else {
                unreachable!()
            };
            down_bits += round_msg.len() as u64 * 8;
            client.pv.set_probs(&probs);
            client.reset_optimizer(&cfg.train);
            for _ in 0..cfg.local_epochs {
                client.run_epoch(&mut exec, &shards[k], cfg.train.batch);
            }
            let mut mask_rng = seeds.subtree("client", k as u64).rng("uplink-mask", round as u64);
            let mut mask = Vec::new();
            client.pv.sample_mask(&mut mask_rng, &mut mask);
            let frame = encode_client(
                &ClientMsg::Mask { round: round as u32, client: k as u32, n: mask.len(), mask },
                MaskCodec::Raw,
            );
            up_bits += frame.len() as u64 * 8;
            let ClientMsg::Mask { mask, .. } = decode_client(&frame).unwrap() else {
                unreachable!()
            };
            server.receive_mask(&pack_client_mask(&mask));
        }
        server.aggregate();
        rows.push((up_bits, down_bits));
    }
    (server.probs, rows)
}

#[test]
fn default_config_is_byte_identical_to_the_legacy_driver() {
    let mut cfg = ci_cfg(4);
    cfg.rounds = 5;
    cfg.participation = 1.0; // explicit: the legacy regime
    cfg.round_timeout_ms = 0; // ∞ — no deadline semantics in play
    let (shards, test) = ci_data(&cfg);

    let (legacy_probs, legacy_rows) = legacy_sequential_driver(&cfg, &shards);
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let new = run_federated(&cfg, &mut exec, &shards, &test, 2, cfg.rounds);

    assert_eq!(new.final_probs, legacy_probs, "orchestrator changed the numerics");
    assert_eq!(new.ledger.rounds.len(), legacy_rows.len());
    for (s, (up, down)) in new.ledger.rounds.iter().zip(&legacy_rows) {
        assert_eq!(s.uplink_bits, *up);
        assert_eq!(s.downlink_bits, *down);
        assert_eq!(s.participants, cfg.clients as u32);
        assert_eq!(s.dropped, 0);
    }
}
