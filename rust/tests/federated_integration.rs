//! Integration: the full federated protocol over the real TCP transport —
//! leader thread + worker threads in one process, real sockets, real
//! frames — must agree qualitatively with the in-process simulator.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use zampling::config::FedConfig;
use zampling::data::Dataset;
use zampling::federated::protocol::{MaskCodec, ServerMsg};
use zampling::federated::transport::{Leader, Worker};
use zampling::federated::{pack_client_mask, run_federated, Server};
use zampling::nn::{one_hot_into, ArchSpec};
use zampling::rng::SeedTree;
use zampling::sparse::QMatrix;
use zampling::zampling::{evaluate, LocalZampling, NativeExecutor, ProbVector};

fn ci_cfg() -> FedConfig {
    let mut cfg = FedConfig::paper(8);
    cfg.train.arch = ArchSpec::small();
    cfg.train.n = ArchSpec::small().num_params() / 8;
    cfg.train.d = 5;
    cfg.train.lr = 0.1;
    cfg.train.seed = 1;
    cfg.clients = 3;
    cfg.rounds = 4;
    cfg.local_epochs = 1;
    cfg
}

fn free_port() -> String {
    // Bind port 0 to discover a free port, then release it.
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    drop(l);
    addr
}

#[test]
fn tcp_federated_matches_simulator_qualitatively() {
    let cfg = ci_cfg();
    let seeds = SeedTree::new(cfg.train.seed);
    let (train, test) = Dataset::synthetic_pair(1_024, 256, &seeds);
    let shards = train.partition_iid(cfg.clients, &seeds);

    // --- reference: in-process simulator ---
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let sim = run_federated(&cfg, &mut exec, &shards, &test, 10, cfg.rounds - 1);
    let sim_final = sim.log.rounds.last().unwrap().mean_sampled_acc;

    // --- real transport: leader + workers on loopback ---
    let addr = free_port();
    let leader_cfg = cfg.clone();
    let leader_addr = addr.clone();
    let leader = thread::spawn(move || -> Vec<f32> {
        let mut leader = Leader::accept(&leader_addr, leader_cfg.clients).expect("accept");
        let seeds = SeedTree::new(leader_cfg.train.seed);
        let mut init_rng = seeds.rng("p-init", 0);
        let mut server = Server::new(
            ProbVector::init_uniform(leader_cfg.train.n, &mut init_rng).probs().to_vec(),
        );
        for round in 0..leader_cfg.rounds {
            leader
                .broadcast(&ServerMsg::Round {
                    round: round as u32,
                    probs: server.probs.clone(),
                })
                .expect("broadcast");
            let (masks, _) = leader.collect_masks(round as u32).expect("collect");
            for m in &masks {
                server.receive_mask(&pack_client_mask(m));
            }
            server.aggregate();
        }
        leader.shutdown().expect("shutdown");
        server.probs
    });

    std::thread::sleep(std::time::Duration::from_millis(100));
    let mut workers = Vec::new();
    for k in 0..cfg.clients {
        let cfg = cfg.clone();
        let addr = addr.clone();
        let shard = shards[k].clone();
        workers.push(thread::spawn(move || {
            let seeds = SeedTree::new(cfg.train.seed);
            let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
            let csc = Arc::new(q.to_csc(None));
            let sub = seeds.subtree("client", k as u64);
            let mut state = LocalZampling::from_parts(
                &cfg.train,
                q,
                csc,
                ProbVector::from_probs(vec![0.5; cfg.train.n]),
                &sub,
            );
            let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let mut worker = Worker::connect(&addr, k as u32, MaskCodec::Raw).expect("connect");
            loop {
                match worker.recv().expect("recv") {
                    ServerMsg::Round { round, probs } => {
                        state.pv.set_probs(&probs);
                        state.reset_optimizer(&cfg.train);
                        for _ in 0..cfg.local_epochs {
                            state.run_epoch(&mut exec, &shard, cfg.train.batch);
                        }
                        let mut mask_rng = sub.rng("uplink-mask", round as u64);
                        let mut mask = Vec::new();
                        state.pv.sample_mask(&mut mask_rng, &mut mask);
                        worker.send_mask(round, mask).expect("send");
                    }
                    ServerMsg::Shutdown => return,
                }
            }
        }));
    }

    let tcp_probs = leader.join().unwrap();
    for w in workers {
        w.join().unwrap();
    }

    // Evaluate the TCP-trained server p on the same test set.
    let q = QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds);
    let out_dim = cfg.train.arch.output_dim();
    let mut y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut y1h);
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    let mut r = seeds.rng("tcp-eval", 0);
    let rep = evaluate(
        &mut exec,
        &q,
        &ProbVector::from_probs(tcp_probs),
        &test.x,
        &y1h,
        test.len(),
        10,
        &mut r,
    );

    // Same protocol, same data, same seeds for Q/init; the local-epoch rng
    // streams differ (thread scheduling of the sim vs workers is
    // identical here, but mask streams are derived per client+round, so
    // the runs are in fact numerically identical up to executor order).
    assert!(
        (rep.mean_sampled_acc - sim_final).abs() < 0.12,
        "tcp {} vs sim {sim_final}",
        rep.mean_sampled_acc
    );
    assert!(rep.mean_sampled_acc > 0.3, "tcp run failed to learn");
}
