// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! End-to-end: full Local Zampling training on the synthetic task — the
//! native three-layer path in every build, plus (with `--features pjrt`
//! and artifacts) the PJRT path checked against the native-oracle run's
//! trajectory.

#[cfg(feature = "pjrt")]
use std::path::Path;

use zampling::config::TrainConfig;
use zampling::data::Dataset;
use zampling::nn::ArchSpec;
use zampling::rng::SeedTree;
#[cfg(feature = "pjrt")]
use zampling::runtime::PjrtRuntime;
use zampling::zampling::{train_local, NativeExecutor};

fn ci_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::local(ArchSpec::small(), 4, 5, 0);
    cfg.lr = 0.05;
    cfg.epochs = 6;
    cfg.train_rows = 1_024;
    cfg.test_rows = 256;
    cfg
}

#[test]
fn native_training_learns_end_to_end() {
    let cfg = ci_cfg();
    let seeds = SeedTree::new(cfg.seed);
    let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
    let mut native = NativeExecutor::new(cfg.arch.clone(), cfg.batch, 500);
    let out = train_local(&cfg, &mut native, &train, &test, 10);
    assert!(
        out.report.mean_sampled_acc > 0.5,
        "native path failed to learn: {}",
        out.report.mean_sampled_acc
    );
    let first = out.epochs.first().unwrap().val_loss;
    let last = out.epochs.last().unwrap().val_loss;
    assert!(last < first, "val loss {first} → {last}");
}

#[test]
fn native_mnistfc_one_epoch_smoke() {
    // The paper's architecture at m/n = 32, one epoch on a small slice:
    // exercises the 266k-parameter blocked GEMMs + the pool-parallel
    // sparse products at their real sizes (kept tiny: debug-mode CI).
    let mut cfg = TrainConfig::local(ArchSpec::mnistfc(), 32, 10, 1);
    cfg.lr = 0.1;
    cfg.epochs = 1;
    cfg.train_rows = 256;
    cfg.test_rows = 128;
    let seeds = SeedTree::new(cfg.seed);
    let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
    let mut exec = NativeExecutor::new(cfg.arch.clone(), cfg.batch, 500);
    let out = train_local(&cfg, &mut exec, &train, &test, 2);
    // One epoch of two steps cannot gate *learning* at this scale (the
    // small-arch e2e test and the gemm parity/finite-difference tests
    // gate kernel correctness); this guards against crashes, NaN
    // propagation, and runaway outputs in the 266k-parameter products.
    assert_eq!(out.epochs.len(), 1);
    assert!(out.epochs[0].train_loss.is_finite());
    assert!(
        out.epochs[0].train_loss < 2.0 * (10.0f64).ln(),
        "train loss {} blew past the ~ln(10) random-init ceiling",
        out.epochs[0].train_loss
    );
    assert!(out.epochs[0].val_loss.is_finite());
    assert!(out.report.mean_sampled_acc > 0.05); // above random-garbage floor
    assert!(
        out.probs.iter().all(|p| p.is_finite() && (0.0..=1.0).contains(p)),
        "probabilities left the unit interval"
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_training_learns_end_to_end() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = ci_cfg();
    let seeds = SeedTree::new(cfg.seed);
    let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);

    let rt = PjrtRuntime::new(dir).expect("runtime");
    let mut pjrt = rt.dense_executor("small").expect("executor");
    let out = train_local(&cfg, &mut pjrt, &train, &test, 10);
    assert!(
        out.report.mean_sampled_acc > 0.5,
        "pjrt path failed to learn: {}",
        out.report.mean_sampled_acc
    );
    let first = out.epochs.first().unwrap().val_loss;
    let last = out.epochs.last().unwrap().val_loss;
    assert!(last < first, "val loss {first} → {last}");

    // The native oracle must tell the same story (same seeds, same data;
    // trajectories diverge in ulps but the outcome band must agree).
    let mut native = NativeExecutor::new(cfg.arch.clone(), cfg.batch, 500);
    let out_native = train_local(&cfg, &mut native, &train, &test, 10);
    let diff = (out.report.mean_sampled_acc - out_native.report.mean_sampled_acc).abs();
    assert!(
        diff < 0.15,
        "pjrt {} vs native {} differ by {diff}",
        out.report.mean_sampled_acc,
        out_native.report.mean_sampled_acc
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_mnistfc_one_epoch_smoke() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    // The paper's architecture at m/n = 32, one epoch on a small slice:
    // exercises the 266k-parameter artifact + the large sparse products.
    let mut cfg = TrainConfig::local(ArchSpec::mnistfc(), 32, 10, 1);
    cfg.lr = 0.1;
    cfg.epochs = 1;
    cfg.train_rows = 512;
    cfg.test_rows = 256;
    let seeds = SeedTree::new(cfg.seed);
    let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
    let rt = PjrtRuntime::new(dir).expect("runtime");
    let mut exec = rt.dense_executor("mnistfc").expect("executor");
    let out = train_local(&cfg, &mut exec, &train, &test, 5);
    assert_eq!(out.epochs.len(), 1);
    assert!(out.epochs[0].train_loss.is_finite());
    assert!(out.report.mean_sampled_acc > 0.05); // above random-garbage floor
}
