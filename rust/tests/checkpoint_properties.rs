// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Fuzz-style tests for the checkpoint codec: **no buffer constructible
//! from arbitrary bytes may panic** `Checkpoint::from_bytes` —
//! truncated, bit-flipped, forged-length, version-drifted, oversized,
//! all of it must come back as `Err` or a valid snapshot, never a crash
//! or a silently garbage restore.  Driven by the in-tree property
//! harness (`util::prop`), deterministic seeds throughout.  This file is
//! the executable appendix of the checkpoint section of
//! `docs/PROTOCOL.md` — every rule the spec states about malformed
//! checkpoints is asserted here.

use zampling::comm::{CommLedger, EdgeCost, RoundCost, ShardCost};
use zampling::federated::checkpoint::MAX_CHECKPOINT_LEN;
use zampling::federated::protocol::MAX_MASK_LEN;
use zampling::federated::{Checkpoint, CheckpointManifest};
use zampling::metrics::RoundRecord;
use zampling::rng::{Rng, Xoshiro256pp};
use zampling::util::prop::{for_all, Gen};

fn random_bytes(g: &mut Gen, len: usize) -> Vec<u8> {
    (0..len).map(|_| g.rng.next_u64() as u8).collect()
}

/// A random run snapshot, valid by construction: roster invariants
/// hold, the eval-RNG cursor is nonzero, and the misses table matches
/// the population.
fn random_checkpoint(g: &mut Gen) -> Checkpoint {
    let n = g.usize_in(1, 300);
    let clients = g.usize_in(1, 8) as u32;
    let max_clients = clients + g.usize_in(0, 4) as u32;
    let population = g.usize_in(clients as usize, max_clients as usize) as u32;
    let rounds = g.usize_in(1, 40) as u32;
    let next_round = g.usize_in(0, rounds as usize) as u32;
    let mut ledger = CommLedger::default();
    for _ in 0..g.usize_in(0, 5) {
        ledger.record(RoundCost {
            downlink_bits: g.rng.next_u64() >> 40,
            uplink_bits: g.rng.next_u64() >> 40,
            clients: g.usize_in(0, clients as usize) as u32,
            participants: clients,
            dropped: g.usize_in(0, clients as usize) as u32,
            wall_ns: g.rng.next_u64() >> 32,
        });
        if g.bool_p(0.5) {
            ledger.record_shard_costs(vec![ShardCost {
                shard: 0,
                uplink_bits: g.rng.next_u64() >> 48,
                downlink_bits: g.rng.next_u64() >> 48,
                merge_bits: g.rng.next_u64() >> 48,
                received: g.usize_in(0, clients as usize) as u32,
                dropped: 0,
            }]);
        }
        if g.bool_p(0.3) {
            ledger.record_edge_costs(vec![EdgeCost {
                from: 1,
                to: 0,
                bits: g.rng.next_u64() >> 48,
            }]);
        }
    }
    let records = (0..g.usize_in(0, 6))
        .map(|i| RoundRecord {
            round: i,
            mean_sampled_acc: g.f64_in(0.0, 1.0),
            sampled_acc_std: g.f64_in(0.0, 0.1),
            expected_acc: g.f64_in(0.0, 1.0),
            train_loss: g.f64_in(0.0, 3.0),
            uplink_bits: g.rng.next_u64() >> 40,
            downlink_bits: g.rng.next_u64() >> 40,
        })
        .collect();
    Checkpoint {
        manifest: CheckpointManifest {
            seed: g.rng.next_u64(),
            n: n as u32,
            clients,
            max_clients,
            rounds,
            shards: g.usize_in(1, 4) as u32,
            population,
            next_round,
            eval_every: g.usize_in(1, 10) as u32,
            eval_samples: g.usize_in(1, 5) as u32,
            participation_bits: g.f64_in(0.1, 1.0).to_bits(),
        },
        probs: g.f32_vec(n, 0.0, 1.0),
        // `| 1` keeps the cursor off the all-zero xoshiro fixed point.
        eval_rng: [g.rng.next_u64() | 1, g.rng.next_u64(), g.rng.next_u64(), g.rng.next_u64()],
        misses: (0..population).map(|_| g.usize_in(0, 9) as u32).collect(),
        log_name: "federated".to_string(),
        records,
        ledger,
    }
}

#[test]
fn random_checkpoints_roundtrip_to_a_byte_fixed_point() {
    for_all(
        "encode → decode → encode is the identity",
        60,
        0xC4C4,
        random_checkpoint,
        |ckpt| {
            let bytes = ckpt.to_bytes().map_err(|e| format!("encode failed: {e}"))?;
            let back = Checkpoint::from_bytes(&bytes)
                .map_err(|e| format!("valid checkpoint rejected: {e}"))?;
            if back.manifest != ckpt.manifest {
                return Err("manifest drifted through the roundtrip".into());
            }
            if back.probs != ckpt.probs
                || back.eval_rng != ckpt.eval_rng
                || back.misses != ckpt.misses
                || back.log_name != ckpt.log_name
                || back.records != ckpt.records
                || back.ledger.to_csv() != ckpt.ledger.to_csv()
            {
                return Err("run state drifted through the roundtrip".into());
            }
            let again = back.to_bytes().map_err(|e| format!("re-encode failed: {e}"))?;
            if again != bytes {
                return Err("re-encode is not byte-identical".into());
            }
            Ok(())
        },
    );
}

#[test]
fn every_truncation_of_a_random_checkpoint_errors() {
    for_all(
        "from_bytes(prefix) is always Err",
        40,
        0x7C07,
        |g| {
            let bytes = random_checkpoint(g).to_bytes().expect("encode");
            let cut = g.usize_in(0, bytes.len() - 1);
            (bytes, cut)
        },
        |(bytes, cut)| match Checkpoint::from_bytes(&bytes[..*cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("truncation at {cut} of {} decoded", bytes.len())),
        },
    );
}

#[test]
fn bit_flips_error_or_decode_to_the_flipped_canonical_form() {
    for_all(
        "single-byte corruption never panics and never decodes garbage",
        120,
        0xF11B,
        |g| {
            let bytes = random_checkpoint(g).to_bytes().expect("encode");
            let at = g.usize_in(0, bytes.len() - 1);
            let bit = 1u8 << g.usize_in(0, 7);
            (bytes, at, bit)
        },
        |(bytes, at, bit)| {
            let mut bad = bytes.clone();
            bad[*at] ^= bit;
            match Checkpoint::from_bytes(&bad) {
                Err(_) => Ok(()),
                // A flip inside a payload region (a probability, a miss
                // counter, a metric) yields a *different but valid*
                // snapshot.  The encoding is canonical, so the only
                // acceptable Ok is one that re-encodes to exactly the
                // mutated buffer — anything else is a garbage decode.
                Ok(ckpt) => {
                    let again =
                        ckpt.to_bytes().map_err(|e| format!("re-encode failed: {e}"))?;
                    if again == bad {
                        Ok(())
                    } else {
                        Err(format!("byte {at} flip decoded non-canonically"))
                    }
                }
            }
        },
    );
}

#[test]
fn version_drift_is_always_rejected() {
    for_all(
        "any version other than the current one errors",
        60,
        0xD217,
        |g| {
            let bytes = random_checkpoint(g).to_bytes().expect("encode");
            let version = if g.bool_p(0.5) {
                g.usize_in(2, 1000) as u32
            } else {
                0
            };
            (bytes, version)
        },
        |(bytes, version)| {
            let mut bad = bytes.clone();
            bad[4..8].copy_from_slice(&version.to_le_bytes());
            match Checkpoint::from_bytes(&bad) {
                Err(e) if e.to_string().contains("version") => Ok(()),
                Err(e) => Err(format!("wrong error for version drift: {e}")),
                Ok(_) => Err(format!("version {version} decoded")),
            }
        },
    );
}

#[test]
fn forged_length_fields_error_before_allocation() {
    for_all(
        "a forged probs count is rejected, huge or merely wrong",
        80,
        0x10EA,
        |g| {
            let bytes = random_checkpoint(g).to_bytes().expect("encode");
            // Offset 60 is the probs count (16B magic/version/seed/
            // participation + 9 × 4B manifest words).
            let true_n = u32::from_le_bytes(bytes[60..64].try_into().expect("4 bytes"));
            let forged: u32 = if g.bool_p(0.5) {
                u32::MAX - g.usize_in(0, 1 << 16) as u32 // allocation bomb
            } else {
                true_n.wrapping_add(g.usize_in(1, 64) as u32) // off by a little
            };
            (bytes, forged)
        },
        |(bytes, forged)| {
            let mut bad = bytes.clone();
            bad[60..64].copy_from_slice(&forged.to_le_bytes());
            match Checkpoint::from_bytes(&bad) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("forged probs count {forged} decoded")),
            }
        },
    );
}

#[test]
fn arbitrary_bytes_never_panic_the_decoder() {
    for_all(
        "from_bytes(arbitrary bytes) never panics",
        400,
        0xFEED,
        |g| {
            let len = g.usize_in(0, 128);
            let mut buf = random_bytes(g, len);
            // Half the time, plant the real magic + version so the
            // deeper manifest and section branches are exercised.
            if buf.len() >= 8 && g.bool_p(0.5) {
                buf[..4].copy_from_slice(&u32::from_le_bytes(*b"zckp").to_le_bytes());
                buf[4..8].copy_from_slice(&1u32.to_le_bytes());
            }
            buf
        },
        |buf| {
            // Outcome may be Ok or Err; only a panic is a failure, and
            // the harness turns panics into test failures for us.
            let _ = Checkpoint::from_bytes(buf);
            Ok(())
        },
    );
}

#[test]
fn oversized_inputs_and_manifests_are_rejected() {
    // Beyond the file-size cap: rejected before any parsing.
    let huge = vec![0u8; MAX_CHECKPOINT_LEN + 1];
    let err = Checkpoint::from_bytes(&huge).unwrap_err();
    assert!(err.to_string().contains("cap"), "{err}");

    // A manifest claiming n beyond the wire protocol's mask cap is
    // rejected on the bound itself, before the probs section is read.
    let mut g = Gen { rng: Xoshiro256pp::seed_from(0x517E) };
    let mut ckpt = random_checkpoint(&mut g);
    ckpt.manifest.n = (MAX_MASK_LEN as u32).saturating_add(1);
    let bytes = ckpt.to_bytes().expect("encode");
    let err = Checkpoint::from_bytes(&bytes).unwrap_err();
    assert!(err.to_string().contains("oversized manifest"), "{err}");
}
