// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Integration: the PJRT-loaded AOT artifacts must agree with the
//! pure-Rust oracle, and the fused (Pallas-in-HLO) step must agree with
//! the split path (rust spmv + dense artifact + rust spmv_t).
//!
//! Requires `make artifacts` to have run (skips with a message if not —
//! CI always builds artifacts first via the Makefile ordering) and a
//! build with the `pjrt` cargo feature.

#![cfg(feature = "pjrt")]

use std::path::Path;

use zampling::nn::{one_hot_into, ArchSpec};
use zampling::rng::{Rng, SeedTree, Xoshiro256pp};
use zampling::runtime::{fused_buffers, PjrtRuntime};
use zampling::sparse::{csc_pad_width, QMatrix};
use zampling::zampling::{DenseExecutor, NativeExecutor};

fn runtime() -> Option<PjrtRuntime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/manifest.json not found (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::new(dir).expect("pjrt runtime"))
}

fn random_weights(arch: &ArchSpec, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from(seed);
    let mut nrm = zampling::rng::Normal::new();
    let mut w = vec![0.0f32; arch.num_params()];
    for s in arch.slices() {
        let std = (2.0 / s.fan_in as f64).sqrt();
        for i in 0..s.w_len {
            w[s.offset + i] = (nrm.sample(&mut r) * std) as f32;
        }
    }
    w
}

fn random_batch(arch: &ArchSpec, rows: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut r = Xoshiro256pp::seed_from(seed);
    let x: Vec<f32> = (0..rows * arch.input_dim()).map(|_| r.next_f32()).collect();
    let labels: Vec<u8> = (0..rows).map(|_| r.next_below(10) as u8).collect();
    let mut y = vec![0.0f32; rows * arch.output_dim()];
    one_hot_into(&labels, arch.output_dim(), &mut y);
    (x, y)
}

#[test]
fn pjrt_train_step_matches_native_oracle() {
    let Some(rt) = runtime() else { return };
    let arch = ArchSpec::small();
    let mut pjrt = rt.dense_executor("small").expect("dense executor");
    let mut native = NativeExecutor::new(arch.clone(), pjrt.train_batch(), pjrt.eval_batch());

    let w = random_weights(&arch, 1);
    for rows in [pjrt.train_batch(), 17, 1] {
        let (x, y) = random_batch(&arch, rows, 2 + rows as u64);
        let mut g_pjrt = vec![0.0f32; arch.num_params()];
        let mut g_native = vec![0.0f32; arch.num_params()];
        let a = pjrt.train_step(&w, &x, &y, rows, &mut g_pjrt);
        let b = native.train_step(&w, &x, &y, rows, &mut g_native);
        assert!((a.loss - b.loss).abs() < 1e-4 * (1.0 + b.loss.abs()), "rows={rows}: loss {} vs {}", a.loss, b.loss);
        assert_eq!(a.correct, b.correct, "rows={rows}");
        let max_diff = g_pjrt
            .iter()
            .zip(&g_native)
            .map(|(&p, &n)| (p - n).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-4, "rows={rows}: max grad diff {max_diff}");
    }
}

#[test]
fn pjrt_eval_step_matches_native_oracle() {
    let Some(rt) = runtime() else { return };
    let arch = ArchSpec::small();
    let mut pjrt = rt.dense_executor("small").expect("dense executor");
    let mut native = NativeExecutor::new(arch.clone(), pjrt.train_batch(), pjrt.eval_batch());
    let w = random_weights(&arch, 3);
    for rows in [pjrt.eval_batch(), 123, 1] {
        let (x, y) = random_batch(&arch, rows, 40 + rows as u64);
        let a = pjrt.eval_step(&w, &x, &y, rows);
        let b = native.eval_step(&w, &x, &y, rows);
        assert!((a.loss - b.loss).abs() < 1e-4 * (1.0 + b.loss.abs()), "rows={rows}");
        assert_eq!(a.correct, b.correct, "rows={rows}");
    }
}

#[test]
fn fused_step_matches_split_path() {
    let Some(rt) = runtime() else { return };
    let arch = ArchSpec::small();
    let m = arch.num_params();
    let (n, d) = (m / 8, 4);
    let mut fused = rt.fused_executor("small", n, d).expect("fused executor");
    assert_eq!(fused.c, csc_pad_width(m, n, d));

    let seeds = SeedTree::new(77);
    let q = QMatrix::generate(&arch, n, d, &seeds);
    let csc = q.to_csc(Some(fused.c));
    let (rid, rv, cid, cv) = fused_buffers(&q, &csc);

    let mut rng = seeds.rng("mask", 0);
    let z: Vec<f32> = (0..n).map(|_| rng.bernoulli(0.5) as u8 as f32).collect();
    let rows = 32usize;
    let (x, y) = random_batch(&arch, rows, 5);

    let out = fused.step(&z, &rid, &rv, &cid, &cv, &x, &y, rows).expect("fused step");

    // Split path: w = Qz in rust, dense PJRT step, g_s = Qᵀ g_w in rust.
    let mut dense = rt.dense_executor("small").expect("dense executor");
    let w = q.spmv(&z);
    let mut g_w = vec![0.0f32; m];
    let split = dense.train_step(&w, &x, &y, rows, &mut g_w);
    let g_s = csc.spmv_t(&g_w);

    assert!(
        (out.loss - split.loss).abs() < 1e-4 * (1.0 + split.loss.abs()),
        "loss {} vs {}",
        out.loss,
        split.loss
    );
    assert_eq!(out.correct, split.correct);
    let max_diff = out
        .grad_s
        .iter()
        .zip(&g_s)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-4, "max grad_s diff {max_diff}");
}

#[test]
fn fused_resident_matches_literal_path() {
    let Some(rt) = runtime() else { return };
    let arch = ArchSpec::small();
    let m = arch.num_params();
    let (n, d) = (m / 8, 4);
    let mut fused = rt.fused_executor("small", n, d).expect("fused executor");

    let seeds = SeedTree::new(99);
    let q = QMatrix::generate(&arch, n, d, &seeds);
    let csc = q.to_csc(Some(fused.c));
    let (rid, rv, cid, cv) = fused_buffers(&q, &csc);
    let mut rng = seeds.rng("mask", 1);
    let z: Vec<f32> = (0..n).map(|_| rng.bernoulli(0.3) as u8 as f32).collect();
    let (x, y) = random_batch(&arch, 20, 7);

    let lit = fused.step(&z, &rid, &rv, &cid, &cv, &x, &y, 20).expect("literal step");
    fused.load_q(&rid, &rv, &cid, &cv).expect("load_q");
    let res = fused.step_resident(&z, &x, &y, 20).expect("resident step");

    assert_eq!(lit.loss, res.loss);
    assert_eq!(lit.correct, res.correct);
    assert_eq!(lit.grad_s, res.grad_s);
}

#[test]
fn manifest_matches_archspec() {
    let Some(rt) = runtime() else { return };
    for (name, a) in &rt.manifest.archs {
        let spec = ArchSpec::by_name(name).expect("arch known");
        assert_eq!(a.num_params, spec.num_params(), "{name}");
        assert_eq!(a.layers, spec.layers, "{name}");
    }
}
