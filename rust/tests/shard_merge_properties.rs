// Integration tests drive sockets, threads-at-scale, or minutes of
// compute — out of scope for the interpreted Miri lane, which runs the
// unit subset instead (see docs/ANALYSIS.md for what is skipped where).
#![cfg(not(miri))]

//! Property tests for the sharded merge path: for ANY population,
//! shard count, and drop pattern — including whole shards contributing
//! zero clients — merging the S partial vote sums (through the real
//! encoded `ShardVotes` frames) and then renormalizing must equal
//! single-leader aggregation over the union of received participants,
//! bit for bit.  `proptest` is unavailable offline, so these run over
//! the crate's deterministic `util::prop::for_all` driver.

use zampling::comm::pack_bits;
use zampling::federated::protocol::{
    decode_shard, encode_client, encode_shard, ClientMsg, MaskCodec, ShardMsg,
};
use zampling::federated::transport::Leader;
use zampling::federated::{DeadlinePolicy, Server, ShardPlan, ShardTree};
use zampling::rng::{Rng, Xoshiro256pp};
use zampling::util::prop::{for_all, Gen};

/// A generated round: a population partitioned into shards, each client
/// holding either a mask or a drop.
#[derive(Debug)]
struct Input {
    n: usize,
    clients: usize,
    shards: usize,
    /// `masks[k]` is `None` when client `k` dropped this round.
    masks: Vec<Option<Vec<bool>>>,
}

fn gen_input(g: &mut Gen) -> Input {
    let n = g.usize_in(1, 200);
    let clients = g.usize_in(1, 24);
    let shards = g.usize_in(1, clients);
    let mut rng = Xoshiro256pp::seed_from(g.seed());
    let drop_rate = g.f64_in(0.0, 1.0);
    let plan = ShardPlan::new(clients, shards);
    // Sometimes kill a whole shard outright — the scenario the sharded
    // transport must survive — on top of per-client drops.
    let dead_shard = if g.bool_p(0.3) { Some(g.usize_in(0, shards - 1)) } else { None };
    let masks = (0..clients)
        .map(|k| {
            if dead_shard == Some(plan.owner(k)) || rng.bernoulli(drop_rate) {
                None
            } else {
                Some((0..n).map(|_| rng.bernoulli(0.5)).collect())
            }
        })
        .collect();
    Input { n, clients, shards, masks }
}

#[test]
fn merging_partial_vote_sums_equals_single_leader_aggregation() {
    for_all("shard-merge-equals-central", 300, 0x5AD5, gen_input, |input| {
        let plan = ShardPlan::new(input.clients, input.shards);

        // Reference: one leader receives every surviving mask directly.
        let mut central = Server::new(vec![0.5; input.n]);
        for mask in input.masks.iter().flatten() {
            central.receive_mask(&pack_bits(mask));
        }
        let central_received = central.try_aggregate();
        let want: Vec<f32> = central.probs.clone();

        // Sharded: each shard folds its own survivors into a partial
        // vote sum, round-trips it through the wire codec, and the root
        // merges the decoded frames.
        let mut root = Server::new(vec![0.5; input.n]);
        for s in 0..plan.shards() {
            let mut votes = vec![0u32; input.n];
            let mut received = 0u32;
            for k in plan.range(s) {
                if let Some(mask) = &input.masks[k] {
                    for (v, &b) in votes.iter_mut().zip(mask) {
                        *v += b as u32;
                    }
                    received += 1;
                }
            }
            let frame = encode_shard(&ShardMsg::ShardVotes {
                shard: s as u32,
                round: 0,
                received,
                n: input.n,
                votes,
            });
            let ShardMsg::ShardVotes { received, n, votes, .. } =
                decode_shard(&frame).map_err(|e| format!("decode: {e}"))?;
            if n != input.n {
                return Err(format!("wire mangled n: {n} != {}", input.n));
            }
            root.merge_votes(&votes, received as usize);
        }
        let merged_received = root.try_aggregate();

        if merged_received != central_received {
            return Err(format!(
                "received diverged: merged {merged_received} vs central {central_received}"
            ));
        }
        // Bit-identical, not approximately equal: u32 sums are exact and
        // the final division is the same `a as f32 / k as f32` both ways.
        if root.probs != want {
            return Err("merged probabilities != central probabilities".into());
        }
        // A fully-dropped round must leave p untouched, not NaN.
        if central_received == 0 && want != vec![0.5; input.n] {
            return Err("zero-receipt round mutated p".into());
        }
        Ok(())
    });
}

/// What one client does during a streaming round.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Fate {
    /// Delivers its mask (at a permuted position in the arrival order).
    Sends,
    /// Connection dies mid-round without a mask (socket EOF analogue).
    Leaves,
    /// Restarts mid-round: its fresh `Hello` replaces the connection, so
    /// the round must drop it — and a mask sent by the *new* incarnation
    /// (which never saw the broadcast) must be ignored, not folded.
    Reconnects { then_sends: bool },
}

/// A generated streaming round: a population, per-client fates, a codec,
/// and a seed for the arrival-order permutation.
#[derive(Debug)]
struct StreamInput {
    n: usize,
    clients: usize,
    fates: Vec<Fate>,
    masks: Vec<Vec<bool>>,
    codec: MaskCodec,
    order_seed: u64,
}

fn gen_stream_input(g: &mut Gen) -> StreamInput {
    let n = g.usize_in(1, 200);
    let clients = g.usize_in(1, 24);
    let mut rng = Xoshiro256pp::seed_from(g.seed());
    let drop_rate = g.f64_in(0.0, 0.6);
    let fates = (0..clients)
        .map(|_| {
            if rng.bernoulli(drop_rate) {
                if rng.bernoulli(0.5) {
                    Fate::Leaves
                } else {
                    Fate::Reconnects { then_sends: rng.bernoulli(0.5) }
                }
            } else {
                Fate::Sends
            }
        })
        .collect();
    let masks = (0..clients)
        .map(|_| (0..n).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    let codec = if g.bool_p(0.5) { MaskCodec::Raw } else { MaskCodec::Arithmetic };
    StreamInput { n, clients, fates, masks, codec, order_seed: g.seed() }
}

/// Streaming (arrival-order) vote folding through the production
/// collector must be byte-identical to buffered client-order aggregation
/// for ANY arrival permutation, drop pattern, and reconnect-mid-round —
/// the invariant that lets the event-loop leader free each mask frame
/// the moment it arrives.
#[test]
fn streaming_arrival_order_fold_is_byte_identical_to_buffered_aggregation() {
    for_all("streaming-equals-buffered", 200, 0xF01D, gen_stream_input, |input| {
        let (mut leader, mut pop) =
            Leader::simulated(input.clients).map_err(|e| format!("leader: {e}"))?;

        // Per-client event scripts, then a seeded interleave across
        // clients (per-client order preserved, cross-client order
        // permuted) — the sim analogue of racing sockets.
        let mut scripts: Vec<Vec<&str>> = Vec::with_capacity(input.clients);
        for k in 0..input.clients {
            scripts.push(match input.fates[k] {
                Fate::Sends => vec!["send"],
                Fate::Leaves => vec!["leave"],
                Fate::Reconnects { then_sends: false } => vec!["rejoin"],
                Fate::Reconnects { then_sends: true } => vec!["rejoin", "send"],
            });
        }
        let mut order_rng = Xoshiro256pp::seed_from(input.order_seed);
        let mut cursors = vec![0usize; input.clients];
        let mut remaining: usize = scripts.iter().map(|s| s.len()).sum();
        while remaining > 0 {
            let live: Vec<usize> =
                (0..input.clients).filter(|&k| cursors[k] < scripts[k].len()).collect();
            let k = live[(order_rng.next_u64() % live.len() as u64) as usize];
            let step = scripts[k][cursors[k]];
            cursors[k] += 1;
            remaining -= 1;
            let delivered = match step {
                "send" => pop.send_frame(
                    k,
                    encode_client(
                        &ClientMsg::Mask {
                            round: 0,
                            client: k as u32,
                            n: input.n,
                            mask: input.masks[k].clone(),
                        },
                        input.codec,
                    ),
                ),
                "leave" => pop.leave(k),
                "rejoin" => pop.rejoin(k),
                _ => unreachable!(),
            };
            if !delivered {
                return Err("event channel closed early".into());
            }
        }

        let participants: Vec<usize> = (0..input.clients).collect();
        // Unbounded deadline: every pending client resolves through an
        // event (mask, Gone, or mid-round Hello), never a timer.
        let receipt = leader
            .collect_votes(0, &participants, input.n, DeadlinePolicy::unbounded())
            .map_err(|e| format!("collect: {e}"))?;

        let survivors: Vec<usize> =
            (0..input.clients).filter(|&k| input.fates[k] == Fate::Sends).collect();
        if receipt.received != survivors {
            return Err(format!(
                "received {:?} != surviving senders {survivors:?} (fates {:?})",
                receipt.received, input.fates
            ));
        }

        // Buffered reference: every surviving mask, folded in client
        // order through the per-mask server path.
        let mut central = Server::new(vec![0.5; input.n]);
        for &k in &survivors {
            central.receive_mask(&pack_bits(&input.masks[k]));
        }
        let central_received = central.try_aggregate();

        // Streaming: merge the arrival-order vote sums.
        let mut root = Server::new(vec![0.5; input.n]);
        root.merge_votes(&receipt.votes, receipt.received.len());
        if root.try_aggregate() != central_received {
            return Err("received counts diverged".into());
        }
        if root.probs != central.probs {
            return Err("streamed probabilities != buffered probabilities".into());
        }
        Ok(())
    });
}

#[test]
fn empty_shards_never_skew_the_mean() {
    // Deterministic pin of the headline case: S = 3, the middle shard
    // contributes zero clients, and the renormalized mean must divide by
    // the masks that arrived (4), not the population (6).
    let n = 8;
    let plan = ShardPlan::new(6, 3);
    let mut root = Server::new(vec![0.0; n]);
    let mask_a: Vec<bool> = (0..n).map(|i| i % 2 == 0).collect();
    let mask_b = vec![true; n];
    for s in 0..plan.shards() {
        let (votes, received) = if s == 1 {
            (vec![0u32; n], 0u32) // whole-shard dropout
        } else {
            let mut votes = vec![0u32; n];
            for mask in [&mask_a, &mask_b] {
                for (v, &b) in votes.iter_mut().zip(mask) {
                    *v += b as u32;
                }
            }
            (votes, 2)
        };
        let frame = encode_shard(&ShardMsg::ShardVotes {
            shard: s as u32,
            round: 0,
            received,
            n,
            votes,
        });
        let ShardMsg::ShardVotes { received, votes, .. } = decode_shard(&frame).unwrap();
        root.merge_votes(&votes, received as usize);
    }
    assert_eq!(root.try_aggregate(), 4);
    for (i, &p) in root.probs.iter().enumerate() {
        let want = if i % 2 == 0 { 1.0 } else { 0.5 };
        assert_eq!(p, want, "entry {i}");
    }
}

/// A generated multi-hop round: a preorder shard forest (depth ≤ 4,
/// uneven fan-out), a population, per-client drops, and sometimes a
/// whole dead subtree (the kill-shard chaos analogue).
#[derive(Debug)]
struct TreeInput {
    n: usize,
    clients: usize,
    /// Parent table in `ShardTree` form; generated in preorder so every
    /// subtree is a contiguous id interval (the validator's invariant).
    parents: Vec<Option<usize>>,
    /// `masks[k]` is `None` when client `k` dropped this round.
    masks: Vec<Option<Vec<bool>>>,
    /// When set, the entire subtree rooted at this shard contributes
    /// nothing — every one of its clients counts as dropped.
    dead_shard: Option<usize>,
}

fn gen_tree_input(g: &mut Gen) -> TreeInput {
    let n = g.usize_in(1, 200);
    let clients = g.usize_in(1, 24);
    let shards = g.usize_in(1, clients);
    let mut rng = Xoshiro256pp::seed_from(g.seed());
    // Stack-based preorder walk: each new shard either deepens the
    // current chain or pops back toward the root first, so subtrees are
    // contiguous intervals by construction.  The stack is capped at 3
    // open ancestors, bounding merge depth at 4 hops.
    let mut parents: Vec<Option<usize>> = vec![None];
    let mut stack: Vec<usize> = vec![0];
    for s in 1..shards {
        let keep = (rng.next_u64() % (stack.len() as u64 + 1)) as usize;
        stack.truncate(keep.min(3));
        parents.push(stack.last().copied());
        stack.push(s);
    }
    let drop_rate = g.f64_in(0.0, 1.0);
    let masks = (0..clients)
        .map(|_| {
            if rng.bernoulli(drop_rate) {
                None
            } else {
                Some((0..n).map(|_| rng.bernoulli(0.5)).collect())
            }
        })
        .collect();
    let dead_shard = if g.bool_p(0.25) { Some(g.usize_in(0, shards - 1)) } else { None };
    TreeInput { n, clients, parents, masks, dead_shard }
}

/// Folding vote sums hop by hop through ANY valid tree shape — each
/// shard merging its children's decoded `ShardVotes` frames into its
/// own partial sum and re-encoding for its parent — must be
/// byte-identical to flat single-leader folding of the same surviving
/// masks: same `received` count, same renormalized probabilities.
/// This is the algebra `serve-shard` relies on at every depth.
#[test]
fn multi_hop_tree_merge_is_byte_identical_to_flat_folding() {
    for_all("tree-merge-equals-flat", 300, 0x7EE5, gen_tree_input, |input| {
        let shards = input.parents.len();
        let plan = ShardPlan::new(input.clients, shards);
        let tree = ShardTree::from_parents(&input.parents)
            .map_err(|e| format!("generator produced an invalid tree: {e:#}"))?;
        let dead = match input.dead_shard {
            Some(d) => tree.subtree_clients(&plan, d),
            None => 0..0,
        };

        // Reference: one flat leader folds every surviving mask.
        let mut central = Server::new(vec![0.5; input.n]);
        for (k, mask) in input.masks.iter().enumerate() {
            if let Some(mask) = mask {
                if !dead.contains(&k) {
                    central.receive_mask(&pack_bits(mask));
                }
            }
        }
        let central_received = central.try_aggregate();

        // Tree: children carry higher ids than their parent, so a
        // reverse-id sweep visits every child before its parent.  Each
        // hop folds its own survivors, merges the children's frames
        // through the real wire codec, and re-emits one frame upward.
        let mut frames: Vec<Option<Vec<u8>>> = vec![None; shards];
        for s in (0..shards).rev() {
            let mut votes = vec![0u32; input.n];
            let mut received = 0u32;
            for k in plan.range(s) {
                let Some(mask) = &input.masks[k] else { continue };
                if dead.contains(&k) {
                    continue;
                }
                for (v, &b) in votes.iter_mut().zip(mask) {
                    *v += b as u32;
                }
                received += 1;
            }
            for &c in tree.children(s) {
                let frame = frames[c].take().ok_or("child frame missing")?;
                let ShardMsg::ShardVotes { received: cr, n: cn, votes: cv, .. } =
                    decode_shard(&frame).map_err(|e| format!("decode hop: {e}"))?;
                if cn != input.n {
                    return Err(format!("hop mangled n: {cn} != {}", input.n));
                }
                for (v, &cv) in votes.iter_mut().zip(&cv) {
                    *v += cv;
                }
                received += cr;
            }
            frames[s] = Some(encode_shard(&ShardMsg::ShardVotes {
                shard: s as u32,
                round: 0,
                received,
                n: input.n,
                votes,
            }));
        }
        let mut root = Server::new(vec![0.5; input.n]);
        for &c in tree.root_children() {
            let frame = frames[c].take().ok_or("root-child frame missing")?;
            let ShardMsg::ShardVotes { received, votes, .. } =
                decode_shard(&frame).map_err(|e| format!("decode root hop: {e}"))?;
            root.merge_votes(&votes, received as usize);
        }
        let merged_received = root.try_aggregate();

        if merged_received != central_received {
            return Err(format!(
                "received diverged: tree {merged_received} vs flat {central_received} \
                 (parents {:?}, dead {:?})",
                input.parents, input.dead_shard
            ));
        }
        if root.probs != central.probs {
            return Err("tree-merged probabilities != flat probabilities".into());
        }
        Ok(())
    });
}
