//! # zampling
//!
//! Reproduction of *"Trading-off Accuracy and Communication Cost in
//! Federated Learning"* (Villani, Natale, Mallmann-Trenn, 2025): the
//! **Zampling** (Zonotope Sampling) training-by-sampling framework and its
//! federated protocol, plus every substrate and baseline the paper's
//! evaluation needs.
//!
//! The system is a three-layer Rust + JAX + Pallas stack (see DESIGN.md):
//! Python authors and AOT-lowers the dense compute to HLO text at build
//! time (`make artifacts`); this crate is the runtime — it owns the sparse
//! influence matrix `Q`, the probability/score vectors, the federated
//! protocol and its wire encodings, and executes the HLO artifacts through
//! the PJRT CPU client (`runtime`).
//!
//! Quick map (one module per DESIGN.md §2 row; the full crate map with
//! the module-dependency diagram is the repo-root `ARCHITECTURE.md`,
//! and the wire format is specified in `docs/PROTOCOL.md`):
//!
//! * [`rng`] — deterministic PRNGs + the shared-seed derivation tree.
//! * [`sparse`] — `Q` generation (Eq. 1), `w = Qz`, `g_s = Qᵀ g_w`.
//! * [`nn`] — architecture specs, flat weight layout, pure-Rust MLP oracle.
//! * [`data`] — MNIST IDX loader / synthetic fallback, IID partitioner.
//! * [`zampling`] — Local Zampling, ContinuousModel, score optimizers.
//! * [`federated`] — server, clients, round protocol, transports.
//! * [`comm`] — wire codecs (bit-pack, RLE, arithmetic) + cost ledger.
//! * [`runtime`] — the persistent worker pool every hot path shares
//!   (`runtime::pool`, see PERF.md) and, behind the `pjrt` cargo
//!   feature, PJRT executable loading and typed step wrappers.
//! * [`testnet`] — multi-process scenario orchestrator (`repro testnet`):
//!   spawns wire fleets from declarative TOML, applies chaos schedules,
//!   and byte-compares runs against their in-process twins.
//! * [`baselines`] — FedAvg, FedPM (Isik et al.), Zhou supermask.
//! * [`zonotope`] — theory validators for §2 (Lemmas 2.1–2.3, Props 2.4–2.6).
//! * [`metrics`], [`experiments`], [`config`] — measurement + drivers.

// Every unsafe operation inside an `unsafe fn` must sit in an explicit
// `unsafe { }` block with its own `// SAFETY:` justification (the xtask
// `safety-comments` pass warns on undocumented blocks in `runtime/`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod comm;
pub mod config;
pub mod data;
pub mod experiments;
pub mod federated;
pub mod metrics;
pub mod nn;
pub mod rng;
pub mod runtime;
pub mod sparse;
pub mod testnet;
pub mod util;
pub mod zampling;
pub mod zonotope;
