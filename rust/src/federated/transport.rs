//! TCP transport: the same frames as the simulator, over real sockets.
//!
//! Topology: the leader (`repro train-federated --transport tcp`) listens;
//! each worker process (`repro serve-client`) connects, sends `Hello`,
//! and then loops `recv Round → local train → send Mask` until
//! `Shutdown`.  Frames are the exact bytes of `protocol::encode_*`, read
//! with a 5-byte header prefetch.  Blocking std::net I/O with one thread
//! per accepted connection on the leader side (tokio is unavailable
//! offline; for ≤ tens of clients blocking threads are the simpler and
//! equally fast design).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use crate::util::error::{Context, Result};
use crate::{bail, ensure};

use super::protocol::{
    decode_client, decode_server, encode_client, encode_server, ClientMsg, MaskCodec, ServerMsg,
};

/// Read one length-prefixed frame from the stream.
pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header).context("reading frame header")?;
    let len = u32::from_le_bytes(header[1..5].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; 5 + len];
    buf[..5].copy_from_slice(&header);
    stream.read_exact(&mut buf[5..]).context("reading frame payload")?;
    Ok(buf)
}

pub fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    stream.write_all(frame).context("writing frame")?;
    stream.flush().context("flushing frame")
}

/// Leader-side connection registry: accepts `expected` workers and keeps
/// their streams in `Hello`-id order.
pub struct Leader {
    streams: Vec<TcpStream>,
    /// Total bytes sent/received (feeds the comm ledger).
    pub sent_bytes: u64,
    pub recv_bytes: u64,
}

impl Leader {
    /// Bind `addr` and accept exactly `expected` workers.
    pub fn accept(addr: &str, expected: usize) -> Result<Leader> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let mut slots: Vec<Option<TcpStream>> = (0..expected).map(|_| None).collect();
        let mut seen = 0usize;
        while seen < expected {
            let (mut stream, peer) = listener.accept().context("accept")?;
            stream.set_nodelay(true).ok();
            let frame = read_frame(&mut stream)?;
            match decode_client(&frame)? {
                ClientMsg::Hello { client } => {
                    let idx = client as usize;
                    ensure!(idx < expected, "client id {idx} ≥ expected {expected}");
                    ensure!(slots[idx].is_none(), "duplicate client id {idx} from {peer}");
                    slots[idx] = Some(stream);
                    seen += 1;
                }
                other => bail!("expected Hello, got {other:?}"),
            }
        }
        Ok(Leader {
            streams: slots.into_iter().map(|s| s.unwrap()).collect(),
            sent_bytes: 0,
            recv_bytes: 0,
        })
    }

    pub fn num_clients(&self) -> usize {
        self.streams.len()
    }

    /// Broadcast a round start; returns bytes sent per client.
    pub fn broadcast(&mut self, msg: &ServerMsg) -> Result<usize> {
        let frame = encode_server(msg);
        for s in &mut self.streams {
            write_frame(s, &frame)?;
        }
        self.sent_bytes += (frame.len() * self.streams.len()) as u64;
        Ok(frame.len())
    }

    /// Collect one `Mask` from every client (any order); returns them
    /// indexed by client id together with total bytes received.
    pub fn collect_masks(&mut self, round: u32) -> Result<(Vec<Vec<bool>>, u64)> {
        let mut masks: Vec<Option<Vec<bool>>> = (0..self.streams.len()).map(|_| None).collect();
        let mut bytes = 0u64;
        for s in &mut self.streams {
            let frame = read_frame(s)?;
            bytes += frame.len() as u64;
            match decode_client(&frame)? {
                ClientMsg::Mask { round: r, client, mask, .. } => {
                    ensure!(r == round, "mask for round {r}, expected {round}");
                    let idx = client as usize;
                    ensure!(masks[idx].is_none(), "duplicate mask from client {idx}");
                    masks[idx] = Some(mask);
                }
                other => bail!("expected Mask, got {other:?}"),
            }
        }
        self.recv_bytes += bytes;
        Ok((masks.into_iter().map(|m| m.unwrap()).collect(), bytes))
    }

    pub fn shutdown(&mut self) -> Result<()> {
        self.broadcast(&ServerMsg::Shutdown)?;
        Ok(())
    }
}

/// Worker-side connection: `Hello` handshake then a recv/send loop.
pub struct Worker {
    stream: TcpStream,
    pub client_id: u32,
    codec: MaskCodec,
}

impl Worker {
    pub fn connect(addr: &str, client_id: u32, codec: MaskCodec) -> Result<Worker> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, &encode_client(&ClientMsg::Hello { client: client_id }, codec))?;
        Ok(Worker { stream, client_id, codec })
    }

    /// Block for the next server message.
    pub fn recv(&mut self) -> Result<ServerMsg> {
        let frame = read_frame(&mut self.stream)?;
        decode_server(&frame)
    }

    /// Uplink this round's mask.
    pub fn send_mask(&mut self, round: u32, mask: Vec<bool>) -> Result<()> {
        let n = mask.len();
        let frame = encode_client(
            &ClientMsg::Mask { round, client: self.client_id, n, mask },
            self.codec,
        );
        write_frame(&mut self.stream, &frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full wire round-trip: leader thread + two worker threads over
    /// loopback, one protocol round.
    #[test]
    fn tcp_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port for Leader::accept (tiny race, retried below)

        let addr2 = addr.clone();
        let leader = std::thread::spawn(move || -> Result<Vec<Vec<bool>>> {
            let mut leader = Leader::accept(&addr2, 2)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![0.5, 1.0, 0.0] })?;
            let (masks, bytes) = leader.collect_masks(0)?;
            assert!(bytes > 0);
            leader.shutdown()?;
            Ok(masks)
        });

        // Give the leader a moment to bind.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut workers = Vec::new();
        for id in 0..2u32 {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, id, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, probs } => {
                            // Deterministic mask from the received probs.
                            let mask: Vec<bool> = probs.iter().map(|&p| p > 0.25).collect();
                            w.send_mask(round, mask)?;
                        }
                        ServerMsg::Shutdown => return Ok(()),
                    }
                }
            }));
        }

        let masks = leader.join().unwrap().expect("leader");
        for w in workers {
            w.join().unwrap().expect("worker");
        }
        assert_eq!(masks, vec![vec![true, true, false]; 2]);
    }
}
