//! TCP transport: the same frames as the simulator, over real sockets.
//!
//! Topology: the leader (`repro train-federated --transport tcp`) listens;
//! each worker process (`repro serve-client`) connects, sends `Hello`,
//! and then loops `recv Round → local train → send Mask` until
//! `Shutdown`.  Frames are the exact bytes of `protocol::encode_*`, read
//! with a 5-byte header prefetch.
//!
//! ## Sharded topology
//!
//! [`ShardedTransport`] scales the same machinery past one accept loop:
//! a `ShardPlan` partitions the client id space across `S` per-shard
//! [`Leader`]s (each with its own listener, sweeper thread, deadlines,
//! and reconnect-with-`Hello` semantics), `exchange` fans the round
//! frame out to every shard concurrently, and each shard streams its
//! arriving masks into a partial vote sum that the root merges — via
//! the encoded `ShardVotes` frame — before `Server::try_aggregate`
//! renormalizes.  `u32` vote sums merge exactly, so S = 1 is
//! byte-identical to [`TcpTransport`] and any S matches the in-process
//! simulator at full participation (pinned in
//! `tests/federated_integration.rs`).  See `docs/PROTOCOL.md` for the
//! frame layout and `ARCHITECTURE.md` for the topology map.
//!
//! ## Fault model
//!
//! The leader is crash-proof against its workers: a **single
//! non-blocking event loop** (the *sweeper* thread) owns the acceptor
//! and every worker socket, polls the fd set for readiness, reassembles
//! frames incrementally per connection, and feeds a single event
//! channel — so masks are collected in *arrival* order with a per-round
//! deadline instead of blocking in stream order, and leader thread
//! count is O(1) in the connected population.  A worker that
//! disconnects, stalls past the deadline, sends a malformed frame,
//! claims a foreign client id, or ships a wrong-length mask is marked
//! **dropped** for the round — never panics the leader — and a dropped
//! worker may rejoin by reconnecting with a fresh `Hello` (the sweeper
//! keeps accepting for the leader's whole lifetime).  Connections carry
//! a generation number so events from a replaced connection can never
//! corrupt its successor's round state.
//!
//! Aggregation is **streaming**: [`Leader::collect_votes`] folds each
//! arriving mask straight into the per-entry `u32` vote sum and frees
//! the frame, so leader memory is O(n) in the model instead of
//! O(clients × n).  Vote sums commute, so arrival-order folding is
//! byte-identical to buffering every mask and folding in client order
//! (pinned in `tests/shard_merge_properties.rs`).
//!
//! std::net non-blocking I/O over a thin `poll(2)` wrapper (tokio and
//! mio are unavailable offline); see PERF.md §"The event-loop leader".
#![cfg_attr(
    not(test),
    deny(clippy::cast_possible_truncation, clippy::cast_sign_loss, clippy::unwrap_used)
)]

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::sync::StopGate;
use crate::util::error::{Context, Result};
use crate::zampling::DenseExecutor;
use crate::{anyhow, bail, ensure};

use crate::comm::ShardCost;

use super::engine::{Contribution, DeadlinePolicy, RoundCtx, RoundTraffic, ShardPlan, Transport};
use super::protocol::{
    decode_client, decode_server, declared_frame_len, encode_client, encode_server, encode_shard,
    peek_client_frame, wire_u32, ClientFrameKind, ClientMsg, MaskCodec, ServerMsg, ShardMsg,
};
use super::Server;

/// Upper bound on one frame's declared payload length.  `read_frame`
/// allocates the payload before reading it, so a forged 4 GiB length
/// must be rejected up front — 64 MiB is ~60× the largest real frame
/// (the MnistFc float downlink is ~1 MiB).
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Read one length-prefixed frame from the stream.
pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header).context("reading frame header")?;
    let len = declared_frame_len(&header)?;
    ensure!(len <= MAX_FRAME_LEN, "frame length {len} exceeds maximum {MAX_FRAME_LEN}");
    let mut buf = vec![0u8; 5 + len];
    buf[..5].copy_from_slice(&header);
    stream.read_exact(&mut buf[5..]).context("reading frame payload")?;
    Ok(buf)
}

/// Write one already-encoded frame to the stream and flush it.
pub fn write_frame(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    stream.write_all(frame).context("writing frame")?;
    stream.flush().context("flushing frame")
}

/// How long the sweeper parks in `poll(2)` when every socket is idle —
/// the bound on how quickly it notices the leader's shutdown flag.
/// Traffic wakes the poll immediately; this only prices idle sweeps.
const SWEEP_TICK: Duration = Duration::from_millis(25);

/// How long one broadcast write may wait on a full socket send buffer
/// before the slot is declared dead.  Slot streams are non-blocking
/// (they share the sweeper's fd), so drop-instead-of-block applies to
/// writes too: a worker that stops draining its socket costs the leader
/// at most this, never a parked thread.
const WRITE_STALL: Duration = Duration::from_secs(10);

/// Readiness polling over the swept fd set: a thin `poll(2)` wrapper.
/// libc is already linked by std, so the raw syscall binding costs no
/// dependency (mio/tokio are unavailable offline).
#[cfg(unix)]
mod readiness {
    use std::time::Duration;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }
    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    extern "C" {
        fn poll(fds: *mut PollFd, nfds: std::os::raw::c_ulong, timeout: i32) -> i32;
    }

    fn poll_ms(timeout: Duration) -> i32 {
        i32::try_from(timeout.as_millis()).unwrap_or(i32::MAX)
    }

    /// Wait until at least one fd is readable — or has an error/hangup
    /// pending, which must wake the sweep too so the dead connection is
    /// discovered — or `timeout` passes.  Returns one flag per fd, in
    /// order; all-false on timeout or EINTR (treated as an idle sweep).
    pub fn wait_readable(fds: &[i32], timeout: Duration) -> Vec<bool> {
        if fds.is_empty() {
            std::thread::sleep(timeout);
            return Vec::new();
        }
        let mut pfds: Vec<PollFd> =
            fds.iter().map(|&fd| PollFd { fd, events: POLLIN, revents: 0 }).collect();
        let nfds = pfds.len() as std::os::raw::c_ulong;
        // SAFETY: `pfds` is a live, exclusively-borrowed Vec of `repr(C)`
        // PollFd structs and `nfds` is exactly its length, so the kernel
        // reads/writes only within the allocation for the syscall's
        // duration.
        let rc = unsafe { poll(pfds.as_mut_ptr(), nfds, poll_ms(timeout)) };
        if rc <= 0 {
            return vec![false; fds.len()];
        }
        pfds.iter().map(|p| p.revents != 0).collect()
    }

    /// Wait until `fd` is writable (or errored — the retried write then
    /// surfaces the real error), up to `timeout`.
    pub fn wait_writable(fd: i32, timeout: Duration) {
        let mut pfd = PollFd { fd, events: POLLOUT, revents: 0 };
        // SAFETY: a single live `repr(C)` PollFd on the stack, passed
        // with nfds = 1; the kernel touches exactly that struct.
        unsafe { poll(&mut pfd, 1, poll_ms(timeout)) };
    }

    pub fn raw_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> i32 {
        s.as_raw_fd()
    }
}

/// Portability fallback (never exercised on the Linux targets this repo
/// builds for): no `poll(2)`, so a short sleep approximates the tick
/// and every fd is reported maybe-readable — the non-blocking reads
/// then return `WouldBlock` harmlessly.
#[cfg(not(unix))]
mod readiness {
    use std::time::Duration;

    pub fn wait_readable(fds: &[i32], _timeout: Duration) -> Vec<bool> {
        std::thread::sleep(Duration::from_millis(2));
        vec![true; fds.len()]
    }

    pub fn wait_writable(_fd: i32, _timeout: Duration) {
        std::thread::sleep(Duration::from_millis(1));
    }

    pub fn raw_fd<T>(_s: &T) -> i32 {
        -1
    }
}

/// Write one already-encoded frame to a possibly non-blocking stream.
/// `WouldBlock` waits for writability (bounded by [`WRITE_STALL`])
/// instead of spinning; blocking streams never hit that path, so this
/// is safe for both slot writes and plain sockets.
fn write_frame_nb(stream: &mut TcpStream, frame: &[u8]) -> Result<()> {
    let deadline = Instant::now() + WRITE_STALL;
    let mut off = 0;
    while off < frame.len() {
        match stream.write(&frame[off..]) {
            Ok(0) => bail!("connection closed mid-write"),
            Ok(k) => off += k,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                let now = Instant::now();
                ensure!(now < deadline, "worker send buffer full for {WRITE_STALL:?}");
                readiness::wait_writable(
                    readiness::raw_fd(stream),
                    (deadline - now).min(Duration::from_millis(100)),
                );
            }
            Err(e) => return Err(e).context("writing frame"),
        }
    }
    stream.flush().context("flushing frame")
}

/// What the sweeper (or a [`SimPopulation`]) tells the leader.  `conn`
/// is the connection generation: events from a stale (replaced)
/// connection are discarded by comparing it against the slot's current
/// generation.
enum Event {
    /// A worker completed the `Hello` handshake; `link` is the write
    /// half the leader broadcasts on.
    Hello { client: u32, conn: u64, link: SlotLink },
    /// A raw `Mask` frame from a registered worker.  Kept **encoded**
    /// until collection dequeues it: queued memory is bounded by the
    /// bytes the worker actually transmitted, so an arithmetic-coded
    /// frame cannot be amplified into its decoded mask while the leader
    /// is busy between rounds.
    Msg { client: u32, conn: u64, frame: Vec<u8> },
    /// The worker's connection is dead: EOF, I/O error, a malformed or
    /// foreign-id frame, or an explicit `Abort`.
    Gone { client: u32, conn: u64 },
    /// A liveness heartbeat: the worker is slow but alive.  During mask
    /// collection this may extend the round deadline (bounded by the
    /// [`DeadlinePolicy`] cap); outside collection it is ignored.
    Beat { client: u32, conn: u64 },
}

/// One connection in the sweeper's fd set: the socket, its generation,
/// the client it registered as (`None` until its `Hello` lands), and
/// the incremental frame-reassembly buffer.
struct SweptConn {
    stream: TcpStream,
    conn: u64,
    client: Option<u32>,
    buf: Vec<u8>,
}

impl SweptConn {
    /// Cut one complete frame out of the reassembly buffer.  The
    /// declared length is validated against [`MAX_FRAME_LEN`] as soon as
    /// the 5-byte header is in — before the payload has arrived — so a
    /// forged length can never grow the buffer.
    fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        if self.buf.len() < 5 {
            return Ok(None);
        }
        let len = declared_frame_len(&self.buf)?;
        ensure!(len <= MAX_FRAME_LEN, "frame length {len} exceeds maximum {MAX_FRAME_LEN}");
        if self.buf.len() < 5 + len {
            return Ok(None);
        }
        let rest = self.buf.split_off(5 + len);
        Ok(Some(std::mem::replace(&mut self.buf, rest)))
    }
}

/// Forward one complete frame as an [`Event`].  Returns `false` when
/// the connection must be closed: a bad handshake, a protocol
/// violation (Abort, foreign id, mid-stream `Hello`, malformed
/// header), or the leader being gone.
fn sweep_frame(c: &mut SweptConn, frame: Vec<u8>, expected: usize, tx: &Sender<Event>) -> bool {
    match c.client {
        // Handshake: a strict bounds-checked `Hello` registers the
        // worker (initial join or reconnect); anything else just drops
        // the connection, never the leader.
        None => match decode_client(&frame) {
            Ok(ClientMsg::Hello { client }) if (client as usize) < expected => {
                let Ok(write_half) = c.stream.try_clone() else { return false };
                c.client = Some(client);
                tx.send(Event::Hello { client, conn: c.conn, link: SlotLink::Tcp(write_half) })
                    .is_ok()
            }
            _ => false,
        },
        Some(client) => match peek_client_frame(&frame) {
            Ok((ClientFrameKind::Heartbeat, owner)) if owner == client => {
                tx.send(Event::Beat { client, conn: c.conn }).is_ok()
            }
            Ok((ClientFrameKind::Mask | ClientFrameKind::Report, owner)) if owner == client => {
                tx.send(Event::Msg { client, conn: c.conn, frame }).is_ok()
            }
            _ => false,
        },
    }
}

/// Drain one ready connection: read until `WouldBlock`, cut complete
/// frames, forward events.  Returns `false` when the connection is
/// finished (EOF, I/O error, forged length, protocol violation) and
/// must leave the sweep.
fn sweep_conn(c: &mut SweptConn, scratch: &mut [u8], expected: usize, tx: &Sender<Event>) -> bool {
    loop {
        match c.stream.read(scratch) {
            Ok(0) => return false, // EOF
            Ok(k) => {
                c.buf.extend_from_slice(&scratch[..k]);
                loop {
                    match c.next_frame() {
                        Ok(Some(frame)) => {
                            if !sweep_frame(c, frame, expected, tx) {
                                return false;
                            }
                        }
                        Ok(None) => break,
                        Err(_) => return false, // forged frame length
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
}

/// The leader's single sweeper thread: the acceptor and every worker
/// socket are non-blocking and swept together by readiness polling.
/// Complete frames produce exactly the [`Event`]s the old
/// thread-per-connection readers did, so the collection loop upstairs
/// is unchanged — only the threading model is: one thread, O(1) in the
/// connected population.  Exits when `stop` is raised (the leader's
/// `Drop`), the listener dies, or the event channel closes; dropping
/// its connection set closes the swept fds promptly.
fn sweep_loop(listener: TcpListener, expected: usize, tx: Sender<Event>, stop: StopGate) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let mut conns: Vec<SweptConn> = Vec::new();
    let mut next_conn: u64 = 1;
    let mut scratch = vec![0u8; 1 << 16];
    while !stop.stop_requested() {
        let fds: Vec<i32> = std::iter::once(readiness::raw_fd(&listener))
            .chain(conns.iter().map(|c| readiness::raw_fd(&c.stream)))
            .collect();
        let ready = readiness::wait_readable(&fds, SWEEP_TICK);
        if stop.stop_requested() {
            break;
        }
        if ready.first().copied().unwrap_or(false) {
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        stream.set_nodelay(true).ok();
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        conns.push(SweptConn { stream, conn: next_conn, client: None, buf: Vec::new() });
                        next_conn += 1;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => return, // listener died: leader is exiting
                }
            }
        }
        // Service ready connections back-to-front so `swap_remove` can
        // only move an already-visited entry into a vacated index.
        for i in (0..conns.len()).rev() {
            if !ready.get(i + 1).copied().unwrap_or(false) {
                continue;
            }
            if !sweep_conn(&mut conns[i], &mut scratch, expected, &tx) {
                let dead = conns.swap_remove(i);
                if let Some(client) = dead.client {
                    if tx.send(Event::Gone { client, conn: dead.conn }).is_err() {
                        return;
                    }
                }
                dead.stream.shutdown(Shutdown::Both).ok();
            }
        }
    }
    // `conns` drops here: the swept fd set closes with the thread.
}

/// The leader's write half of a registered connection.
enum SlotLink {
    /// A real socket — non-blocking (it shares the sweeper's fd), so
    /// writes go through the `WouldBlock`-aware path.
    Tcp(TcpStream),
    /// A simulated client from [`Leader::simulated`]: writes are
    /// counted, not shipped.
    Sim,
}

impl SlotLink {
    fn close(&self) {
        if let SlotLink::Tcp(stream) = self {
            stream.shutdown(Shutdown::Both).ok();
        }
    }
}

/// A registered worker connection: its write half + generation.
struct Slot {
    conn: u64,
    link: SlotLink,
}

/// What one collection deadline produced.
#[derive(Debug)]
pub struct RoundReceipt {
    /// Masks indexed by client id; `None` for non-participants and drops.
    pub masks: Vec<Option<Vec<bool>>>,
    /// Encoded mask-frame bytes per client id (0 where no mask arrived)
    /// — the per-client uplink cost the ledger attributes.
    pub frame_bytes: Vec<u64>,
    /// Participants whose mask arrived, ascending.
    pub received: Vec<usize>,
    /// Participants whose mask did not arrive, ascending.
    pub dropped: Vec<usize>,
    /// Total mask-frame bytes received.
    pub bytes: u64,
}

/// What one **streaming** mask collection produced: per-entry `u32`
/// vote sums over every accepted mask, with the same received/dropped
/// bookkeeping as [`RoundReceipt`] — but O(n) retained state.  Each
/// mask is folded into `votes` the moment it is judged and its frame
/// freed, never buffered per client, so leader memory is O(n) in the
/// model instead of O(clients × n).  Vote sums commute, so the result
/// is byte-identical to the buffered path for any arrival order.
#[derive(Debug)]
pub struct VoteReceipt {
    /// Per-entry vote sums over the received masks.
    pub votes: Vec<u32>,
    /// Encoded mask-frame bytes per client id (0 where no mask arrived)
    /// — the per-client uplink cost the ledger attributes.
    pub frame_bytes: Vec<u64>,
    /// Participants whose mask arrived, ascending.
    pub received: Vec<usize>,
    /// Participants whose mask did not arrive, ascending.
    pub dropped: Vec<usize>,
    /// Total mask-frame bytes received.
    pub bytes: u64,
    /// Peak bytes of mask state the collector held at any instant: the
    /// `4n`-byte accumulator plus the one frame (and its decoded mask)
    /// in flight.  O(n), independent of the client count — asserted,
    /// not just claimed, in the transport tests.
    pub peak_held_bytes: u64,
}

/// One peer's decoded gossip `Report` (see [`Leader::collect_reports`]).
#[derive(Clone, Debug)]
pub struct PeerReport {
    /// The peer's final local training loss this round.
    pub loss: f64,
    /// The peer's probability vector after neighbour aggregation.
    pub probs: Vec<f32>,
}

/// What one gossip report-collection deadline produced — the
/// coordinator-side analogue of [`RoundReceipt`].
#[derive(Debug)]
pub struct ReportReceipt {
    /// Reports indexed by node id; `None` for non-participants + drops.
    pub reports: Vec<Option<PeerReport>>,
    /// Participants whose report arrived, ascending.
    pub received: Vec<usize>,
    /// Participants whose report did not arrive, ascending.
    pub dropped: Vec<usize>,
}

/// How the collection loop judged one dequeued round frame.
enum Judged<T> {
    /// A valid contribution for the current round.
    Accept(T),
    /// A well-formed frame for some other round (a straggler catching
    /// up): discarded; the sender stays pending.
    Stale,
    /// Malformed or aggregation-corrupting: the sender's connection is
    /// killed and it is dropped for the round.
    Violation,
}

/// What the generic collection loop produced (the shared shape behind
/// [`RoundReceipt`] and [`ReportReceipt`]).
struct Collected<T> {
    /// Accepted items indexed by client id.
    items: Vec<Option<T>>,
    /// Encoded frame bytes per client id (0 where nothing arrived).
    frame_bytes: Vec<u64>,
    /// Participants whose frame never arrived, ascending.
    dropped: Vec<usize>,
    /// Total accepted frame bytes.
    bytes: u64,
}

/// Injects the event stream of a **simulated** population into a
/// [`Leader::simulated`] leader: the broadcast / collection / deadline
/// / generation machinery is the production code, only the socket I/O
/// is bypassed.  This is the population-axis harness behind
/// `bench_perf_population` and `repro experiment --id population` — a
/// 100k-client round exercises the exact streaming-aggregation path
/// without 100k fds.
pub struct SimPopulation {
    tx: Sender<Event>,
    /// Current connection generation per client id.
    conns: Vec<u64>,
    next_conn: u64,
}

impl SimPopulation {
    /// Deliver an already-encoded client frame (e.g. a `Mask`) as
    /// client `k`'s current incarnation.  Returns `false` once the
    /// leader is gone.
    pub fn send_frame(&self, k: usize, frame: Vec<u8>) -> bool {
        self.tx.send(Event::Msg { client: wire_u32(k), conn: self.conns[k], frame }).is_ok()
    }

    /// Deliver a liveness heartbeat from client `k`.
    pub fn beat(&self, k: usize) -> bool {
        self.tx.send(Event::Beat { client: wire_u32(k), conn: self.conns[k] }).is_ok()
    }

    /// Client `k`'s connection dies (mid-round this drops it for the
    /// round, exactly like a socket EOF).
    pub fn leave(&mut self, k: usize) -> bool {
        self.tx.send(Event::Gone { client: wire_u32(k), conn: self.conns[k] }).is_ok()
    }

    /// Client `k` reconnects with a fresh `Hello` under a new
    /// generation (mid-round this drops the old incarnation's pending
    /// contribution, exactly like a socket reconnect).
    pub fn rejoin(&mut self, k: usize) -> bool {
        self.next_conn += 1;
        self.conns[k] = self.next_conn;
        self.tx
            .send(Event::Hello { client: wire_u32(k), conn: self.conns[k], link: SlotLink::Sim })
            .is_ok()
    }
}

/// Leader-side connection registry: accepts `expected` workers, keeps
/// accepting reconnects, and collects masks concurrently.
pub struct Leader {
    expected: usize,
    slots: Vec<Option<Slot>>,
    rx: Receiver<Event>,
    /// Raised by `Drop` so the sweeper exits (and closes the swept fd
    /// set) within one [`SWEEP_TICK`] instead of leaking parked state.
    /// The stop → join → close sequence is model-checked under the loom
    /// lane (`rust/tests/loom_model.rs`) via the shared [`StopGate`].
    stop: StopGate,
    sweeper: Option<JoinHandle<()>>,
    /// Total frame bytes sent to workers (feeds the comm ledger).
    pub sent_bytes: u64,
    /// Total frame bytes received from workers.
    pub recv_bytes: u64,
}

impl Drop for Leader {
    fn drop(&mut self) {
        self.stop.request_stop();
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
        // Slot write halves drop with `self`, closing the leader's
        // remaining fds.
    }
}

impl Leader {
    /// Bind `addr` and accept exactly `expected` workers.
    pub fn accept(addr: &str, expected: usize) -> Result<Leader> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Self::from_listener(listener, expected)
    }

    /// Race-free entry point: the caller binds (e.g. port 0 to let the
    /// OS pick) and hands the listener over, so the address is known
    /// before any worker connects.  Blocks until every one of the
    /// `expected` client ids has completed a `Hello` handshake.
    pub fn from_listener(listener: TcpListener, expected: usize) -> Result<Leader> {
        let all: Vec<usize> = (0..expected).collect();
        Self::from_listener_subset(listener, expected, &all)
    }

    /// [`Self::from_listener`] for a shard leader: slots exist for all
    /// `expected` global client ids (so workers keep their global ids on
    /// the wire), but startup only blocks until the ids in `subset` —
    /// the clients this shard owns — have completed their `Hello`
    /// handshakes.  Everything after startup (broadcast, collection,
    /// reconnects) already takes explicit participant lists, so a shard
    /// leader is just a `Leader` that never gets asked about ids outside
    /// its subset.
    pub fn from_listener_subset(
        listener: TcpListener,
        expected: usize,
        subset: &[usize],
    ) -> Result<Leader> {
        ensure!(expected > 0, "leader needs at least one expected worker");
        ensure!(!subset.is_empty(), "leader needs at least one subset worker");
        for &k in subset {
            ensure!(k < expected, "subset id {k} ≥ expected {expected}");
        }
        let (tx, rx) = channel();
        let stop = StopGate::new();
        let sweeper = {
            let stop = stop.clone();
            std::thread::spawn(move || sweep_loop(listener, expected, tx, stop))
        };
        let mut leader = Leader {
            expected,
            slots: (0..expected).map(|_| None).collect(),
            rx,
            stop,
            sweeper: Some(sweeper),
            sent_bytes: 0,
            recv_bytes: 0,
        };
        while subset.iter().any(|&k| leader.slots[k].is_none()) {
            let ev = leader.rx.recv().map_err(|_| anyhow!("leader event loop died"))?;
            // During startup a Hello for a slot whose connection is
            // still live is a configuration error (two workers launched
            // with the same --client-id): fail fast instead of letting
            // the duplicates churn each other while the missing id
            // blocks this loop forever.  A worker that dies and
            // reconnects during startup normally gets its `Gone`
            // enqueued first and is fine; in the (microsecond) window
            // where the fresh Hello wins the enqueue race this errs on
            // the side of a clean, explained abort over a silent hang.
            if let Event::Hello { client, .. } = &ev {
                ensure!(
                    leader.slots[*client as usize].is_none(),
                    "duplicate client id {client} during leader startup"
                );
            }
            leader.apply_control(ev);
        }
        Ok(leader)
    }

    /// A leader over `expected` **simulated** clients: no listener, no
    /// sweeper, every slot pre-registered — the returned
    /// [`SimPopulation`] injects the same [`Event`] stream real
    /// connections produce.  Broadcast, collection, deadlines,
    /// generations, and streaming aggregation run the production code;
    /// only socket I/O is bypassed, so the population axis can sweep
    /// past the fd limit (100k clients, zero reader threads).
    pub fn simulated(expected: usize) -> Result<(Leader, SimPopulation)> {
        ensure!(expected > 0, "leader needs at least one expected worker");
        let (tx, rx) = channel();
        let mut leader = Leader {
            expected,
            slots: (0..expected).map(|_| None).collect(),
            rx,
            stop: StopGate::new(),
            sweeper: None,
            sent_bytes: 0,
            recv_bytes: 0,
        };
        for (k, slot) in leader.slots.iter_mut().enumerate() {
            *slot = Some(Slot { conn: k as u64 + 1, link: SlotLink::Sim });
        }
        let pop = SimPopulation {
            tx,
            conns: (1..=expected as u64).collect(),
            next_conn: expected as u64,
        };
        Ok((leader, pop))
    }

    /// Handle a connection-lifecycle event outside mask collection
    /// (in-round `Msg` events are handled by the collection loop).
    fn apply_control(&mut self, ev: Event) {
        match ev {
            Event::Hello { client, conn, link } => self.register(client, conn, link),
            Event::Gone { client, conn } => {
                self.clear_if_current(client as usize, conn);
            }
            Event::Msg { .. } => {}  // stale mask between rounds: ignore
            Event::Beat { .. } => {} // liveness only matters mid-collection
        }
    }

    /// Install (or replace, on reconnect) a worker connection.
    fn register(&mut self, client: u32, conn: u64, link: SlotLink) {
        let k = client as usize;
        if let Some(old) = self.slots[k].take() {
            // Shut the replaced socket down; the sweeper's next read on
            // it EOFs and its Gone event carries the old generation, so
            // it is ignored.
            old.link.close();
        }
        self.slots[k] = Some(Slot { conn, link });
    }

    /// Clear slot `k` iff it still holds generation `conn`.
    fn clear_if_current(&mut self, k: usize, conn: u64) -> bool {
        if self.slots[k].as_ref().is_some_and(|s| s.conn == conn) {
            self.slots[k] = None;
            return true;
        }
        false
    }

    /// Drop the connection in slot `k` (protocol violation path).
    fn kill(&mut self, k: usize) {
        if let Some(slot) = self.slots[k].take() {
            slot.link.close();
        }
    }

    /// How many client ids this leader has slots for.
    pub fn num_clients(&self) -> usize {
        self.expected
    }

    /// Workers currently connected.
    pub fn live_clients(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Fold queued connection events (joins, reconnects, deaths) into
    /// the slot table without blocking.  Callers that need the current
    /// roster *outside* a broadcast or collection — e.g. elastic join
    /// admission at a round boundary — drain explicitly; the broadcast
    /// path drains on its own.
    pub fn drain_control_events(&mut self) {
        while let Ok(ev) = self.rx.try_recv() {
            self.apply_control(ev);
        }
    }

    /// Whether client id `k` currently has a live registered connection.
    pub fn is_connected(&self, k: usize) -> bool {
        self.slots.get(k).is_some_and(|s| s.is_some())
    }

    /// Drain queued connection events, then wait up to `timeout` for
    /// client `k` to be connected.  Returns whether it is.
    pub fn wait_for_client(&mut self, k: usize, timeout: Duration) -> Result<bool> {
        ensure!(k < self.expected, "client id {k} ≥ expected {}", self.expected);
        let deadline = Instant::now() + timeout;
        loop {
            while let Ok(ev) = self.rx.try_recv() {
                self.apply_control(ev);
            }
            if self.slots[k].is_some() {
                return Ok(true);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(ev) => self.apply_control(ev),
                Err(RecvTimeoutError::Timeout) => return Ok(false),
                Err(RecvTimeoutError::Disconnected) => bail!("leader event loop died"),
            }
        }
    }

    /// Send `msg` to the given participants (skipping disconnected
    /// slots); returns `(frame_len, receivers)`.  A write failure marks
    /// the slot dead instead of failing the round.
    pub fn broadcast_to(
        &mut self,
        msg: &ServerMsg,
        participants: &[usize],
    ) -> Result<(usize, usize)> {
        let frame = encode_server(msg);
        let receivers = self.broadcast_frame(&frame, participants)?;
        Ok((frame.len(), receivers))
    }

    /// Ship an already-encoded server frame to the given participants
    /// (skipping disconnected slots); returns the receiver count.  A
    /// write failure marks the slot dead instead of failing the round.
    pub fn broadcast_frame(&mut self, frame: &[u8], participants: &[usize]) -> Result<usize> {
        // Fold in queued connection events (reconnects, deaths,
        // straggler frames) so this round starts from the current
        // connection state: anything enqueued before the broadcast is
        // by definition not part of the round about to start.  This is
        // also what keeps the event queue bounded — and reconnects
        // discoverable — when collect_masks has nothing pending (e.g.
        // after a round in which every participant dropped).
        while let Ok(ev) = self.rx.try_recv() {
            self.apply_control(ev);
        }
        let mut receivers = 0usize;
        for &k in participants {
            ensure!(k < self.expected, "participant id {k} ≥ expected {}", self.expected);
            let mut dead = false;
            if let Some(slot) = self.slots[k].as_mut() {
                match &mut slot.link {
                    SlotLink::Tcp(stream) => {
                        if write_frame_nb(stream, frame).is_ok() {
                            receivers += 1;
                            self.sent_bytes += frame.len() as u64;
                        } else {
                            dead = true;
                        }
                    }
                    SlotLink::Sim => {
                        receivers += 1;
                        self.sent_bytes += frame.len() as u64;
                    }
                }
            }
            if dead {
                self.kill(k);
            }
        }
        Ok(receivers)
    }

    /// Broadcast a round start to every slot; returns bytes per frame.
    pub fn broadcast(&mut self, msg: &ServerMsg) -> Result<usize> {
        let all: Vec<usize> = (0..self.expected).collect();
        let (frame_len, _) = self.broadcast_to(msg, &all)?;
        Ok(frame_len)
    }

    /// Collect one `Mask` of length `n` from each of `participants` for
    /// `round`, in arrival order, until all arrive or the deadline
    /// passes (`deadline.timeout = None` = wait as long as at least the
    /// event channel lives).
    ///
    /// Clients that disconnect, violate the protocol, or miss the
    /// deadline are reported in `dropped` — the round completes with
    /// whatever arrived.  Masks for other rounds (stragglers catching
    /// up) are discarded.  Reconnecting workers are registered as they
    /// appear and join from the next round on.
    ///
    /// With `deadline.cap` set, a heartbeat from a still-pending
    /// participant proves "slow but alive" and pushes the deadline out
    /// to `now + timeout`, never past `start + cap` — so one slow
    /// worker can buy itself time without letting a dead one stall the
    /// round forever.
    pub fn collect_masks(
        &mut self,
        round: u32,
        participants: &[usize],
        n: usize,
        deadline: DeadlinePolicy,
    ) -> Result<RoundReceipt> {
        let mut judge = |frame: &[u8]| match decode_client(frame) {
            Ok(ClientMsg::Mask { round: r, mask, .. }) if r == round && mask.len() == n => {
                Judged::Accept(mask)
            }
            // straggler mask for a finished round: discard
            Ok(ClientMsg::Mask { round: r, .. }) if r != round => Judged::Stale,
            // Malformed body or wrong-length mask would corrupt
            // aggregation: protocol violation, connection dropped.
            _ => Judged::Violation,
        };
        let c = self.collect_round(participants, deadline, &mut judge)?;
        let received: Vec<usize> =
            participants.iter().copied().filter(|&k| c.items[k].is_some()).collect();
        Ok(RoundReceipt {
            masks: c.items,
            frame_bytes: c.frame_bytes,
            received,
            dropped: c.dropped,
            bytes: c.bytes,
        })
    }

    /// Streaming [`Self::collect_masks`]: identical arrival-order /
    /// deadline / heartbeat / reconnect semantics, but each accepted
    /// mask is folded straight into the per-entry vote sum and both the
    /// frame and the decoded mask are freed before the next event is
    /// dequeued — the collector retains O(n) mask state no matter how
    /// many clients contribute.  `u32` vote sums commute, so the result
    /// is byte-identical to buffering all masks and folding them in
    /// client order (`tests/shard_merge_properties.rs` pins this under
    /// permuted arrivals, drops, and reconnect-mid-round).
    pub fn collect_votes(
        &mut self,
        round: u32,
        participants: &[usize],
        n: usize,
        deadline: DeadlinePolicy,
    ) -> Result<VoteReceipt> {
        let mut votes = vec![0u32; n];
        let base = 4 * n as u64;
        let mut peak = base;
        let mut judge = |frame: &[u8]| match decode_client(frame) {
            Ok(ClientMsg::Mask { round: r, mask, .. }) if r == round && mask.len() == n => {
                peak = peak.max(base + frame.len() as u64 + mask.len() as u64);
                super::fold_mask_votes(&mut votes, &mask);
                Judged::Accept(())
            }
            // straggler mask for a finished round: discard
            Ok(ClientMsg::Mask { round: r, .. }) if r != round => Judged::Stale,
            // Malformed body or wrong-length mask would corrupt
            // aggregation: protocol violation, connection dropped.
            _ => Judged::Violation,
        };
        let c = self.collect_round(participants, deadline, &mut judge)?;
        let received: Vec<usize> =
            participants.iter().copied().filter(|&k| c.items[k].is_some()).collect();
        Ok(VoteReceipt {
            votes,
            frame_bytes: c.frame_bytes,
            received,
            dropped: c.dropped,
            bytes: c.bytes,
            peak_held_bytes: peak,
        })
    }

    /// Collect one gossip `Report` carrying an `n`-entry probability
    /// vector from each of `participants` for `round` — the coordinator
    /// side of the wire-gossip round, with exactly the semantics of
    /// [`Self::collect_masks`] (arrival order, deadline + heartbeat
    /// extension, drop-instead-of-block, stale-round discard).
    pub fn collect_reports(
        &mut self,
        round: u32,
        participants: &[usize],
        n: usize,
        deadline: DeadlinePolicy,
    ) -> Result<ReportReceipt> {
        let mut judge = |frame: &[u8]| match decode_client(frame) {
            Ok(ClientMsg::Report { round: r, loss, probs, .. })
                if r == round && probs.len() == n =>
            {
                Judged::Accept(PeerReport { loss, probs })
            }
            // straggler report for a finished round: discard
            Ok(ClientMsg::Report { round: r, .. }) if r != round => Judged::Stale,
            // Malformed body or wrong-length probs would corrupt the
            // consensus: protocol violation, connection dropped.
            _ => Judged::Violation,
        };
        let c = self.collect_round(participants, deadline, &mut judge)?;
        let received: Vec<usize> =
            participants.iter().copied().filter(|&k| c.items[k].is_some()).collect();
        Ok(ReportReceipt { reports: c.items, received, dropped: c.dropped })
    }

    /// The one collection event loop behind [`Self::collect_masks`] and
    /// [`Self::collect_reports`]: dequeue events until every pending
    /// participant contributed a `judge`-accepted frame or the deadline
    /// passes, handling reconnects, disconnects, heartbeat extension,
    /// and stale-generation leftovers identically for every frame kind.
    fn collect_round<T>(
        &mut self,
        participants: &[usize],
        deadline: DeadlinePolicy,
        judge: &mut dyn FnMut(&[u8]) -> Judged<T>,
    ) -> Result<Collected<T>> {
        for &k in participants {
            ensure!(k < self.expected, "participant id {k} ≥ expected {}", self.expected);
        }
        let start = Instant::now();
        let mut deadline_at = deadline.timeout.map(|t| start + t);
        let mut items: Vec<Option<T>> = (0..self.expected).map(|_| None).collect();
        let mut frame_bytes = vec![0u64; self.expected];
        let mut dropped: Vec<usize> =
            participants.iter().copied().filter(|&k| self.slots[k].is_none()).collect();
        let mut pending: Vec<usize> =
            participants.iter().copied().filter(|&k| self.slots[k].is_some()).collect();
        let mut bytes = 0u64;

        while !pending.is_empty() {
            let ev = match deadline_at {
                None => match self.rx.recv() {
                    Ok(ev) => ev,
                    Err(_) => bail!("leader event channel closed"),
                },
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        break;
                    }
                    match self.rx.recv_timeout(d - now) {
                        Ok(ev) => ev,
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            bail!("leader event channel closed")
                        }
                    }
                }
            };
            match ev {
                Event::Hello { client, conn, link } => {
                    let k = client as usize;
                    self.register(client, conn, link);
                    // A mid-round Hello for a still-pending participant
                    // means the worker restarted: the replacement never
                    // saw this round's broadcast (and register() killed
                    // whatever was left of the old connection), so its
                    // mask can never arrive — drop it now rather than
                    // hang on it until the deadline (or forever, at
                    // timeout = ∞, if the old connection's Gone lost
                    // the enqueue race to this Hello).
                    if let Some(i) = pending.iter().position(|&p| p == k) {
                        pending.remove(i);
                        dropped.push(k);
                    }
                }
                Event::Gone { client, conn } => {
                    let k = client as usize;
                    if self.clear_if_current(k, conn) {
                        if let Some(i) = pending.iter().position(|&p| p == k) {
                            pending.remove(i);
                            dropped.push(k);
                        }
                    }
                }
                Event::Beat { client, conn } => {
                    let k = client as usize;
                    if !self.slots[k].as_ref().is_some_and(|s| s.conn == conn) {
                        continue; // stale connection's leftovers
                    }
                    if !pending.contains(&k) {
                        continue; // non-participant liveness: ignore
                    }
                    // Slow but alive: extend the deadline, bounded by
                    // the cap (extension is monotone — a late heartbeat
                    // never *shortens* the current deadline).
                    if let (Some(t), Some(cap), Some(d)) =
                        (deadline.timeout, deadline.cap, deadline_at)
                    {
                        let extended = (Instant::now() + t).min(start + cap);
                        if extended > d {
                            deadline_at = Some(extended);
                        }
                    }
                }
                Event::Msg { client, conn, frame } => {
                    let k = client as usize;
                    if !self.slots[k].as_ref().is_some_and(|s| s.conn == conn) {
                        continue; // stale connection's leftovers
                    }
                    let Some(i) = pending.iter().position(|&p| p == k) else {
                        continue; // duplicate or unsolicited: ignore
                    };
                    // Decode at dequeue time — the frame was only
                    // header-peeked by the sweeper.
                    let frame_len = frame.len();
                    match judge(&frame) {
                        Judged::Accept(item) => {
                            pending.remove(i);
                            items[k] = Some(item);
                            frame_bytes[k] = frame_len as u64;
                            bytes += frame_len as u64;
                        }
                        Judged::Stale => {}
                        Judged::Violation => {
                            self.kill(k);
                            pending.remove(i);
                            dropped.push(k);
                        }
                    }
                }
            }
        }

        // Anything still pending at the deadline is dropped this round
        // (the connection stays; a late frame is discarded next round).
        dropped.extend(pending);
        dropped.sort_unstable();
        self.recv_bytes += bytes;
        Ok(Collected { items, frame_bytes, dropped, bytes })
    }

    /// Broadcast `Shutdown` to every connected worker.
    pub fn shutdown(&mut self) -> Result<()> {
        self.broadcast(&ServerMsg::Shutdown)?;
        Ok(())
    }
}

/// The TCP [`Transport`]: the engine's round loop over a fault-tolerant
/// [`Leader`].  Broadcast ships the engine's encoded round frame to the
/// participants' live connections; collection honors the engine's
/// [`DeadlinePolicy`] (including heartbeat extension); disconnects,
/// deadline misses, and protocol violations surface as `dropped` so the
/// engine renormalizes instead of crashing.  Worker losses stay local,
/// so contributions carry `loss = 0.0`.
///
/// Collection is **streaming** ([`Leader::collect_votes`]): masks fold
/// into the vote sum on arrival, so `packed_mask` stays empty and
/// `aggregate` merges the accumulated votes — byte-identical to the
/// engine's default per-mask aggregation because `u32` sums commute.
pub struct TcpTransport {
    /// The fault-tolerant connection registry the rounds run over.
    pub leader: Leader,
    exec: Box<dyn DenseExecutor>,
    /// This round's streamed vote sums + received count, produced by
    /// `exchange` and consumed by `aggregate`.
    pending: Option<(Vec<u32>, usize)>,
}

impl TcpTransport {
    /// Wrap an accepted [`Leader`] and the executor the engine should
    /// evaluate the global model on.
    pub fn new(leader: Leader, exec: Box<dyn DenseExecutor>) -> Self {
        Self { leader, exec, pending: None }
    }
}

impl Transport for TcpTransport {
    /// Elastic membership: report every client id at or beyond the
    /// current population whose `Hello` has landed — the leader's slot
    /// table already admits any id below its `expected` bound
    /// (`cfg.max_clients` for elastic runs), so a late worker dialing in
    /// mid-run surfaces here and the engine grows the roster at the next
    /// round boundary.
    fn poll_joins(&mut self, _round: u32, population: usize) -> Vec<usize> {
        self.leader.drain_control_events();
        (population..self.leader.num_clients())
            .filter(|&k| self.leader.is_connected(k))
            .collect()
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let receivers = self.leader.broadcast_frame(ctx.frame, ctx.participants)?;
        let receipt =
            self.leader.collect_votes(ctx.round, ctx.participants, ctx.n, ctx.deadline)?;
        let mut contributions = Vec::with_capacity(receipt.received.len());
        for &k in &receipt.received {
            // `packed_mask` stays empty: the mask was already folded
            // into the streamed vote sum at arrival, and this transport
            // overrides `aggregate` to merge that sum.
            contributions.push(Contribution {
                client: k,
                loss: 0.0,
                up_bits: receipt.frame_bytes[k] * 8,
                packed_mask: Vec::new(),
            });
        }
        self.pending = Some((receipt.votes, receipt.received.len()));
        Ok(RoundTraffic {
            contributions,
            dropped: receipt.dropped,
            down_bits: (ctx.frame.len() * receivers) as u64 * 8,
            ..Default::default()
        })
    }

    /// Merge the vote sums streamed during `exchange` and renormalize —
    /// the same `merge_votes` + `try_aggregate` body as the sharded
    /// root, with S = 1.
    fn aggregate(&mut self, server: &mut Server, _traffic: &RoundTraffic) -> usize {
        // lint: allow(panic) — engine-sequencing invariant, not wire data:
        // `RoundEngine` calls `aggregate` exactly once after a successful
        // `exchange` stored the streamed votes; no peer input reaches this.
        let (votes, received) = self.pending.take().expect("aggregate follows exchange");
        server.merge_votes(&votes, received);
        server.try_aggregate()
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.exec.as_mut()
    }

    fn finish(&mut self) -> Result<()> {
        self.leader.shutdown()
    }
}

/// What one shard leader's slice of a round produced.
struct ShardExchange {
    receipt: VoteReceipt,
    /// Broadcast bits this shard's leader delivered.
    down_bits: u64,
    /// The shard's encoded `ShardVotes` merge frame (partial vote sums
    /// over its received masks).
    votes_frame: Vec<u8>,
}

/// The multi-leader [`Transport`]: a root/leader/worker aggregation
/// tree instead of a star.
///
/// A [`ShardPlan`] partitions the client id space across `S` per-shard
/// [`Leader`]s — each with its own listener and the full concurrent
/// fault model (one sweeper thread, event channel, deadlines, heartbeat
/// extension, reconnect-with-`Hello`).  `exchange` fans the engine's
/// round frame out to every shard on its own thread; each shard
/// broadcasts to its participants, collects their masks under the
/// engine's [`DeadlinePolicy`], and folds them into a partial vote sum
/// shipped root-ward as one encoded `ShardVotes` frame.  `aggregate`
/// decodes and merges the S frames into the global [`Server`] before
/// `try_aggregate` renormalizes by the total received count.
///
/// Because `u32` vote sums add exactly, the merge is **bit-identical**
/// to a single leader receiving every mask: S = 1 reproduces
/// [`TcpTransport`] byte-for-byte, and any S matches the in-process
/// simulator at full participation (pinned in
/// `tests/federated_integration.rs`).  A shard whose workers all die is
/// a dropped-participants event for that shard only — the merge
/// proceeds with whatever the surviving shards voted.
///
/// # Example
///
/// Two shard leaders on loopback, one trivially-masked worker each,
/// driven through one manual round:
///
/// ```
/// use std::net::TcpListener;
/// use zampling::federated::protocol::{encode_server, MaskCodec, ServerMsg};
/// use zampling::federated::transport::{ShardedTransport, Worker};
/// use zampling::federated::{DeadlinePolicy, RoundCtx, ShardPlan, Transport};
/// use zampling::nn::ArchSpec;
/// use zampling::zampling::NativeExecutor;
///
/// let listeners: Vec<TcpListener> =
///     (0..2).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
/// let addrs: Vec<String> =
///     listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
/// // Client k belongs to shard ShardPlan::new(2, 2).owner(k) = k here.
/// let workers: Vec<_> = addrs
///     .iter()
///     .enumerate()
///     .map(|(k, addr)| {
///         let addr = addr.clone();
///         std::thread::spawn(move || {
///             let mut w = Worker::connect(&addr, k as u32, MaskCodec::Raw).unwrap();
///             while let Ok(msg) = w.recv() {
///                 match msg {
///                     ServerMsg::Round { round, probs } => {
///                         let mask = probs.iter().map(|&p| p > 0.5).collect();
///                         w.send_mask(round, mask).unwrap();
///                     }
///                     _ => break,
///                 }
///             }
///         })
///     })
///     .collect();
///
/// let plan = ShardPlan::new(2, 2);
/// let exec = NativeExecutor::new(ArchSpec::small(), 1, 1);
/// let mut t = ShardedTransport::from_listeners(listeners, plan, Box::new(exec)).unwrap();
/// let frame = encode_server(&ServerMsg::Round { round: 0, probs: vec![0.0, 1.0, 1.0] });
/// let ctx = RoundCtx {
///     round: 0,
///     frame: &frame,
///     participants: &[0, 1],
///     n: 3,
///     deadline: DeadlinePolicy::unbounded(),
/// };
/// let traffic = t.exchange(&ctx).unwrap();
/// assert_eq!(traffic.contributions.len(), 2);
/// assert_eq!(traffic.shard_costs.len(), 2);
/// t.finish().unwrap();
/// for w in workers {
///     w.join().unwrap();
/// }
/// ```
pub struct ShardedTransport {
    plan: ShardPlan,
    shards: Vec<Leader>,
    exec: Box<dyn DenseExecutor>,
    /// This round's encoded `ShardVotes` frames, produced by the shard
    /// collectors in `exchange` and consumed by `aggregate`.
    pending_votes: Vec<Vec<u8>>,
}

impl ShardedTransport {
    /// Bind every shard's listener (all before any accept, so a fast
    /// worker of a later shard never sees connection-refused), then
    /// block until each shard's own clients have joined.
    pub fn accept(addrs: &[String], plan: ShardPlan, exec: Box<dyn DenseExecutor>) -> Result<Self> {
        ensure!(
            addrs.len() == plan.shards(),
            "{} shard addresses for {} shards",
            addrs.len(),
            plan.shards()
        );
        let mut listeners = Vec::with_capacity(addrs.len());
        for addr in addrs {
            listeners.push(TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?);
        }
        Self::from_listeners(listeners, plan, exec)
    }

    /// Race-free entry point over pre-bound listeners, one per shard in
    /// shard order.  Shard `s`'s leader waits for the global client ids
    /// in `plan.range(s)`; workers keep their **global** ids on the
    /// wire, so the same `serve-client` binary serves both topologies.
    pub fn from_listeners(
        listeners: Vec<TcpListener>,
        plan: ShardPlan,
        exec: Box<dyn DenseExecutor>,
    ) -> Result<Self> {
        ensure!(
            listeners.len() == plan.shards(),
            "{} listeners for {} shards",
            listeners.len(),
            plan.shards()
        );
        let mut shards = Vec::with_capacity(listeners.len());
        for (s, listener) in listeners.into_iter().enumerate() {
            let subset: Vec<usize> = plan.range(s).collect();
            shards.push(Leader::from_listener_subset(listener, plan.clients(), &subset)?);
        }
        Ok(Self { plan, shards, exec, pending_votes: Vec::new() })
    }

    /// The client-space partition this transport runs.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The per-shard leaders, in shard order (byte counters live here).
    pub fn leaders(&self) -> &[Leader] {
        &self.shards
    }
}

impl Transport for ShardedTransport {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let groups = self.plan.split(ctx.participants);
        // Fan out: one thread per shard leader runs the whole
        // broadcast → collect → partial-sum slice, so a slow shard
        // overlaps the others and the round's wall clock is the max
        // shard deadline, not the sum.
        let results: Vec<Result<ShardExchange>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .zip(groups.iter().copied())
                .enumerate()
                .map(|(sid, (leader, parts))| {
                    scope.spawn(move || -> Result<ShardExchange> {
                        let receivers = leader.broadcast_frame(ctx.frame, parts)?;
                        // Streaming: each arriving mask folds straight
                        // into this shard's partial vote sum, so shard
                        // memory is O(n), not O(shard clients × n).
                        let mut receipt =
                            leader.collect_votes(ctx.round, parts, ctx.n, ctx.deadline)?;
                        let votes_frame = encode_shard(&ShardMsg::ShardVotes {
                            shard: wire_u32(sid),
                            round: ctx.round,
                            received: wire_u32(receipt.received.len()),
                            n: ctx.n,
                            votes: std::mem::take(&mut receipt.votes),
                        });
                        Ok(ShardExchange {
                            receipt,
                            down_bits: (ctx.frame.len() * receivers) as u64 * 8,
                            votes_frame,
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                // A panicked shard thread becomes that shard's `Err`, so
                // the round fails with a diagnosis instead of poisoning
                // the root — the `result?` below surfaces it.
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err(anyhow!("shard leader thread panicked")))
                })
                .collect()
        });

        // Merge at the root.  Shards own ascending contiguous id ranges
        // and each receipt is ascending within its shard, so chaining
        // the shard slices in shard order keeps the engine's global
        // ascending-contribution invariant.
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut dropped = Vec::new();
        let mut down_bits = 0u64;
        let mut shard_costs = Vec::with_capacity(self.plan.shards());
        self.pending_votes.clear();
        for (sid, result) in results.into_iter().enumerate() {
            let ex = result?;
            for &k in &ex.receipt.received {
                // `packed_mask` stays empty: only the engine's default
                // central aggregation reads it, and this transport
                // overrides `aggregate` to merge the shard vote sums —
                // the masks were already folded in the shard threads.
                contributions.push(Contribution {
                    client: k,
                    loss: 0.0,
                    up_bits: ex.receipt.frame_bytes[k] * 8,
                    packed_mask: Vec::new(),
                });
            }
            dropped.extend_from_slice(&ex.receipt.dropped);
            down_bits += ex.down_bits;
            shard_costs.push(ShardCost {
                shard: wire_u32(sid),
                uplink_bits: ex.receipt.bytes * 8,
                downlink_bits: ex.down_bits,
                merge_bits: ex.votes_frame.len() as u64 * 8,
                received: wire_u32(ex.receipt.received.len()),
                dropped: wire_u32(ex.receipt.dropped.len()),
            });
            self.pending_votes.push(ex.votes_frame);
        }
        dropped.sort_unstable();
        Ok(RoundTraffic { contributions, dropped, down_bits, shard_costs, ..Default::default() })
    }

    /// Root-side merge: decode each shard's `ShardVotes` frame and fold
    /// the partial sums into the global accumulator, then renormalize —
    /// the sharded replacement for receiving every mask individually
    /// (one shared body with the sim twin: `merge_vote_frames`).
    fn aggregate(&mut self, server: &mut Server, _traffic: &RoundTraffic) -> usize {
        super::merge_vote_frames(server, &self.plan, &mut self.pending_votes)
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.exec.as_mut()
    }

    fn finish(&mut self) -> Result<()> {
        for leader in &mut self.shards {
            leader.shutdown()?;
        }
        Ok(())
    }
}

/// Worker-side connection: `Hello` handshake then a recv/send loop.
pub struct Worker {
    stream: TcpStream,
    /// This worker's global client id (the `Hello` it registered with).
    pub client_id: u32,
    codec: MaskCodec,
}

impl Worker {
    /// Connect to a leader (or shard leader) at `addr` and complete the
    /// `Hello` handshake as `client_id`.
    pub fn connect(addr: &str, client_id: u32, codec: MaskCodec) -> Result<Worker> {
        let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        write_frame(&mut stream, &encode_client(&ClientMsg::Hello { client: client_id }, codec))?;
        Ok(Worker { stream, client_id, codec })
    }

    /// [`Self::connect`], retrying **any** dial failure (50 ms
    /// backoff) until `timeout` elapses, then surfacing the last
    /// error.  Each attempt uses `TcpStream::connect_timeout` with the
    /// remaining budget, so even a blackholed address (SYNs silently
    /// dropped — the OS-level connect would otherwise block for
    /// minutes) respects the overall bound.  Gossip peers bind their
    /// own listener first and then dial every neighbour, so at startup
    /// a peer routinely dials a neighbour whose process hasn't bound
    /// its port yet — retrying instead of erroring makes peer launch
    /// order irrelevant.  The kind-blind retry is deliberate: the
    /// crate's string-backed error type erases `io::ErrorKind`, and a
    /// permanently-bad address just costs the bounded timeout before
    /// the underlying error (with the dialed address attached) reaches
    /// the operator.  The `Hello` itself needs no retry: once the
    /// remote listener is bound, the OS backlog accepts the connection
    /// even before the remote `Leader` starts draining it.
    pub fn connect_retry(
        addr: &str,
        client_id: u32,
        codec: MaskCodec,
        timeout: Duration,
    ) -> Result<Worker> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(1));
            let attempt = (|| -> Result<Worker> {
                let sock = addr
                    .to_socket_addrs()
                    .with_context(|| format!("resolving {addr}"))?
                    .next()
                    .ok_or_else(|| anyhow!("{addr} resolved to no address"))?;
                let mut stream = TcpStream::connect_timeout(&sock, remaining)
                    .with_context(|| format!("connecting {addr}"))?;
                stream.set_nodelay(true).ok();
                let hello = encode_client(&ClientMsg::Hello { client: client_id }, codec);
                write_frame(&mut stream, &hello)?;
                Ok(Worker { stream, client_id, codec })
            })();
            match attempt {
                Ok(w) => return Ok(w),
                Err(e) if Instant::now() >= deadline => {
                    return Err(e).with_context(|| format!("dialing {addr} for {timeout:?}"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// Block for the next server frame's raw bytes (the exact input
    /// `client_round` consumes, so TCP workers and the simulator share
    /// one round body).
    pub fn recv_raw(&mut self) -> Result<Vec<u8>> {
        read_frame(&mut self.stream)
    }

    /// Block for the next server message.
    pub fn recv(&mut self) -> Result<ServerMsg> {
        let frame = self.recv_raw()?;
        decode_server(&frame)
    }

    /// Ship an already-encoded client frame (e.g. `ClientRound::frame`).
    pub fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, frame)
    }

    /// Uplink this round's mask.
    pub fn send_mask(&mut self, round: u32, mask: Vec<bool>) -> Result<()> {
        let n = mask.len();
        let frame = encode_client(
            &ClientMsg::Mask { round, client: self.client_id, n, mask },
            self.codec,
        );
        self.send_frame(&frame)
    }

    /// Tell the leader this worker is leaving for good.
    pub fn send_abort(&mut self) -> Result<()> {
        let frame = encode_client(&ClientMsg::Abort { client: self.client_id }, self.codec);
        self.send_frame(&frame)
    }

    /// Liveness ping (consumed silently by the leader).
    pub fn send_heartbeat(&mut self) -> Result<()> {
        let frame = encode_client(&ClientMsg::Heartbeat { client: self.client_id }, self.codec);
        self.send_frame(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bound_listener() -> (TcpListener, String) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        (listener, addr)
    }

    /// Full wire round-trip: leader thread + two worker threads over
    /// loopback, one protocol round.  The listener is bound *before* the
    /// leader thread starts, so there is no bind/connect race (the seed
    /// dropped and rebound the port, and flaked).
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn tcp_round_trip() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<RoundReceipt> {
            let mut leader = Leader::from_listener(listener, 2)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![0.5, 1.0, 0.0] })?;
            let receipt = leader.collect_masks(0, &[0, 1], 3, DeadlinePolicy::unbounded())?;
            assert!(receipt.bytes > 0);
            leader.shutdown()?;
            Ok(receipt)
        });

        // A rogue connection with an out-of-range id must be ignored,
        // not panic the leader or occupy a slot.
        {
            let mut rogue = TcpStream::connect(&addr).unwrap();
            let hello = encode_client(&ClientMsg::Hello { client: 99 }, MaskCodec::Raw);
            write_frame(&mut rogue, &hello).unwrap();
        }

        let mut workers = Vec::new();
        for id in 0..2u32 {
            let addr = addr.clone();
            workers.push(std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, id, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, probs } => {
                            // Deterministic mask from the received probs.
                            let mask: Vec<bool> = probs.iter().map(|&p| p > 0.25).collect();
                            w.send_mask(round, mask)?;
                        }
                        _ => return Ok(()),
                    }
                }
            }));
        }

        let receipt = leader.join().unwrap().expect("leader");
        for w in workers {
            w.join().unwrap().expect("worker");
        }
        assert_eq!(receipt.received, vec![0, 1]);
        assert!(receipt.dropped.is_empty());
        // per-client byte attribution sums to the round total
        assert_eq!(receipt.frame_bytes.iter().sum::<u64>(), receipt.bytes);
        assert!(receipt.frame_bytes[0] > 0 && receipt.frame_bytes[1] > 0);
        let masks: Vec<Vec<bool>> = receipt.masks.into_iter().map(|m| m.unwrap()).collect();
        assert_eq!(masks, vec![vec![true, true, false]; 2]);
    }

    /// A worker that is slower than the base deadline but heartbeats
    /// while it works must NOT be dropped when the policy allows
    /// extension: each beat pushes the deadline out to `now + timeout`,
    /// bounded by the cap.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn heartbeats_extend_the_deadline_for_slow_but_alive_workers() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<RoundReceipt> {
            let mut leader = Leader::from_listener(listener, 1)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0] })?;
            let policy = DeadlinePolicy {
                timeout: Some(Duration::from_secs(2)),
                cap: Some(Duration::from_secs(60)),
            };
            let receipt = leader.collect_masks(0, &[0], 1, policy)?;
            leader.shutdown()?;
            Ok(receipt)
        });

        // Takes ~3s (beyond the 2s base deadline) but beats every 500ms.
        let worker = std::thread::spawn(move || {
            let mut w = Worker::connect(&addr, 0, MaskCodec::Raw).expect("connect");
            let _ = w.recv().expect("round");
            for _ in 0..6 {
                std::thread::sleep(Duration::from_millis(500));
                w.send_heartbeat().expect("heartbeat");
            }
            w.send_mask(0, vec![true]).expect("mask");
            let _ = w.recv(); // drain the shutdown
        });

        let receipt = leader.join().unwrap().expect("leader");
        worker.join().unwrap();
        assert_eq!(receipt.received, vec![0], "slow-but-alive worker was dropped");
        assert!(receipt.dropped.is_empty());
    }

    /// Heartbeats can only stretch the deadline up to the cap: a worker
    /// that beats forever without ever delivering its mask is still
    /// dropped once `start + cap` passes.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn heartbeats_cannot_extend_past_the_cap() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<(RoundReceipt, Duration)> {
            let mut leader = Leader::from_listener(listener, 1)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0] })?;
            let policy = DeadlinePolicy {
                timeout: Some(Duration::from_millis(400)),
                cap: Some(Duration::from_millis(1200)),
            };
            let start = Instant::now();
            let receipt = leader.collect_masks(0, &[0], 1, policy)?;
            let elapsed = start.elapsed();
            leader.shutdown()?;
            Ok((receipt, elapsed))
        });

        // Beats every 100ms for ~3s and never sends a mask.
        let worker = std::thread::spawn(move || {
            let mut w = Worker::connect(&addr, 0, MaskCodec::Raw).expect("connect");
            let _ = w.recv().expect("round");
            for _ in 0..30 {
                std::thread::sleep(Duration::from_millis(100));
                if w.send_heartbeat().is_err() {
                    break; // leader moved on and dropped us
                }
            }
        });

        let (receipt, elapsed) = leader.join().unwrap().expect("leader");
        worker.join().unwrap();
        assert_eq!(receipt.received, Vec::<usize>::new());
        assert_eq!(receipt.dropped, vec![0], "immortal heartbeater must still be dropped");
        assert!(
            elapsed < Duration::from_secs(30),
            "cap did not bound the collection: {elapsed:?}"
        );
    }

    /// Three workers; one disconnects mid-round without sending its mask.
    /// The leader must finish the round with the other two, record the
    /// drop, and keep running a second round.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn leader_survives_mid_round_disconnect() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<(RoundReceipt, RoundReceipt)> {
            let mut leader = Leader::from_listener(listener, 3)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0, 0.0] })?;
            let r0 = leader
                .collect_masks(0, &[0, 1, 2], 2, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            // Round 1 proceeds with the survivors only.
            let survivors: Vec<usize> = r0.received.clone();
            let msg = ServerMsg::Round { round: 1, probs: vec![0.0, 1.0] };
            leader.broadcast_to(&msg, &survivors)?;
            let r1 = leader
                .collect_masks(1, &survivors, 2, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            leader.shutdown()?;
            Ok((r0, r1))
        });

        let mut steady = Vec::new();
        for id in [0u32, 1] {
            let addr = addr.clone();
            steady.push(std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, id, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, probs } => {
                            let mask: Vec<bool> = probs.iter().map(|&p| p > 0.5).collect();
                            w.send_mask(round, mask)?;
                        }
                        _ => return Ok(()),
                    }
                }
            }));
        }
        // Worker 2 receives the round and vanishes without replying.
        let quitter = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut w = Worker::connect(&addr, 2, MaskCodec::Raw).expect("connect");
                let _ = w.recv().expect("round 0");
                // drop the connection here
            })
        };

        let (r0, r1) = leader.join().unwrap().expect("leader");
        for w in steady {
            w.join().unwrap().expect("worker");
        }
        quitter.join().unwrap();

        assert_eq!(r0.received, vec![0, 1]);
        assert_eq!(r0.dropped, vec![2]);
        assert_eq!(r1.received, vec![0, 1]);
        assert!(r1.dropped.is_empty());
    }

    /// A worker that forges a foreign client id on its mask is dropped —
    /// the seed indexed `masks[idx]` with the wire-supplied id and
    /// panicked on ids ≥ `num_clients`.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn forged_client_id_drops_the_worker_not_the_leader() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<RoundReceipt> {
            let mut leader = Leader::from_listener(listener, 2)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0] })?;
            let receipt = leader
                .collect_masks(0, &[0, 1], 1, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            leader.shutdown()?;
            Ok(receipt)
        });

        // Worker 0 lies about who it is (id 7 ≥ expected would have
        // panicked the seed's `masks[idx]`).
        let liar = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut w = Worker::connect(&addr, 0, MaskCodec::Raw).expect("connect");
                let _ = w.recv().expect("round");
                let forged = encode_client(
                    &ClientMsg::Mask { round: 0, client: 7, n: 1, mask: vec![true] },
                    MaskCodec::Raw,
                );
                let _ = w.send_frame(&forged);
            })
        };
        let honest = {
            let addr = addr.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, 1, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, .. } => w.send_mask(round, vec![true])?,
                        _ => return Ok(()),
                    }
                }
            })
        };

        let receipt = leader.join().unwrap().expect("leader");
        liar.join().unwrap();
        honest.join().unwrap().expect("honest worker");
        assert_eq!(receipt.received, vec![1]);
        assert_eq!(receipt.dropped, vec![0]);
    }

    /// A wrong-length mask (which would corrupt `Server::receive_mask`)
    /// is a protocol violation: dropped, never aggregated.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn wrong_length_mask_is_dropped() {
        let (listener, addr) = bound_listener();

        let leader = std::thread::spawn(move || -> Result<RoundReceipt> {
            let mut leader = Leader::from_listener(listener, 1)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0, 1.0, 1.0] })?;
            let receipt = leader
                .collect_masks(0, &[0], 3, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            leader.shutdown()?;
            Ok(receipt)
        });

        let worker = std::thread::spawn(move || {
            let mut w = Worker::connect(&addr, 0, MaskCodec::Raw).expect("connect");
            let _ = w.recv().expect("round");
            let _ = w.send_mask(0, vec![true; 5]); // n = 3 expected
        });

        let receipt = leader.join().unwrap().expect("leader");
        worker.join().unwrap();
        assert_eq!(receipt.received, Vec::<usize>::new());
        assert_eq!(receipt.dropped, vec![0]);
        assert!(receipt.masks.iter().all(|m| m.is_none()));
    }

    /// Two workers launched with the same `--client-id` while both are
    /// live is a configuration error: the leader must fail fast, not
    /// hang forever waiting for the never-arriving missing id.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn duplicate_client_id_at_startup_fails_fast() {
        let (listener, addr) = bound_listener();
        let leader = std::thread::spawn(move || Leader::from_listener(listener, 2));
        let hello0 = encode_client(&ClientMsg::Hello { client: 0 }, MaskCodec::Raw);
        // Two live connections both claiming id 0 (order irrelevant —
        // whichever registers second trips the guard).
        let mut a = TcpStream::connect(&addr).unwrap();
        write_frame(&mut a, &hello0).unwrap();
        let mut b = TcpStream::connect(&addr).unwrap();
        write_frame(&mut b, &hello0).unwrap();
        let result = leader.join().unwrap();
        assert!(result.is_err(), "duplicate client id must error at startup");
        drop((a, b));
    }

    /// A sharded exchange over real sockets: two shard leaders, three
    /// workers with **global** ids, one manual round.  The merged
    /// traffic must keep the ascending-contribution invariant, the vote
    /// merge must equal per-mask receipt, and a whole shard whose
    /// worker vanished must surface as that shard's drops only.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn sharded_exchange_merges_vote_sums_and_survives_a_dead_shard() {
        use crate::zampling::NativeExecutor;
        use crate::nn::ArchSpec;

        let plan = ShardPlan::new(3, 2); // shard 0 = {0, 1}, shard 1 = {2}
        let listeners: Vec<TcpListener> =
            (0..2).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        let addrs: Vec<String> =
            listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();

        let leader = std::thread::spawn(move || -> Result<(RoundTraffic, RoundTraffic, Vec<f32>)> {
            let exec = NativeExecutor::new(ArchSpec::small(), 1, 1);
            let mut t = ShardedTransport::from_listeners(listeners, plan, Box::new(exec))?;
            let frame = encode_server(&ServerMsg::Round { round: 0, probs: vec![1.0, 0.0] });
            let ctx = RoundCtx {
                round: 0,
                frame: &frame,
                participants: &[0, 1, 2],
                n: 2,
                deadline: DeadlinePolicy::fixed(Duration::from_secs(20)),
            };
            let t0 = t.exchange(&ctx)?;
            let mut server = Server::new(vec![0.5; 2]);
            let received = t.aggregate(&mut server, &t0);
            assert_eq!(received, 3);
            let probs = server.probs.clone();
            // Round 1: worker 2 is gone (it aborted after round 0), so
            // shard 1 contributes zero clients and the merge proceeds.
            let frame = encode_server(&ServerMsg::Round { round: 1, probs: vec![0.0, 1.0] });
            let ctx = RoundCtx {
                round: 1,
                frame: &frame,
                participants: &[0, 1, 2],
                n: 2,
                deadline: DeadlinePolicy::fixed(Duration::from_secs(20)),
            };
            let t1 = t.exchange(&ctx)?;
            let received = t.aggregate(&mut server, &t1);
            assert_eq!(received, 2);
            t.finish()?;
            Ok((t0, t1, probs))
        });

        // Shard-0 workers answer every round with mask = (p > 0.5).
        let mut steady = Vec::new();
        for k in [0u32, 1] {
            let addr = addrs[plan.owner(k as usize)].clone();
            steady.push(std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, k, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, probs } => {
                            w.send_mask(round, probs.iter().map(|&p| p > 0.5).collect())?
                        }
                        _ => return Ok(()),
                    }
                }
            }));
        }
        // Shard-1's only worker answers round 0 then aborts.
        let quitter = {
            let addr = addrs[plan.owner(2)].clone();
            std::thread::spawn(move || {
                let mut w = Worker::connect(&addr, 2, MaskCodec::Raw).expect("connect");
                let ServerMsg::Round { round, probs } = w.recv().expect("round 0") else {
                    panic!("expected round 0");
                };
                w.send_mask(round, probs.iter().map(|&p| p > 0.5).collect()).expect("mask");
                w.send_abort().expect("abort");
            })
        };

        let (t0, t1, probs) = leader.join().unwrap().expect("sharded leader");
        for w in steady {
            w.join().unwrap().expect("steady worker");
        }
        quitter.join().unwrap();

        // Round 0: everyone voted [true, false] → p = [1, 0].
        let ids: Vec<usize> = t0.contributions.iter().map(|c| c.client).collect();
        assert_eq!(ids, vec![0, 1, 2], "merged contributions must stay ascending");
        assert!(t0.dropped.is_empty());
        assert_eq!(t0.shard_costs.len(), 2);
        assert_eq!(t0.shard_costs[0].received, 2);
        assert_eq!(t0.shard_costs[1].received, 1);
        assert!(t0.shard_costs.iter().all(|c| c.merge_bits > 0));
        assert_eq!(probs, vec![1.0, 0.0]);
        // Round 1: shard 1 contributed nothing; shard 0 carried the round.
        let ids: Vec<usize> = t1.contributions.iter().map(|c| c.client).collect();
        assert_eq!(ids, vec![0, 1]);
        assert_eq!(t1.dropped, vec![2]);
        assert_eq!(t1.shard_costs[1].received, 0);
        assert_eq!(t1.shard_costs[1].dropped, 1);
    }

    /// `from_listener_subset` must only wait for its own subset: a shard
    /// leader for {1} comes up with one worker even though `expected`
    /// covers three global ids.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn subset_leader_starts_without_foreign_clients() {
        let (listener, addr) = bound_listener();
        let leader = std::thread::spawn(move || -> Result<usize> {
            let leader = Leader::from_listener_subset(listener, 3, &[1])?;
            Ok(leader.live_clients())
        });
        let mut w = Worker::connect(&addr, 1, MaskCodec::Raw).expect("connect");
        assert_eq!(leader.join().unwrap().expect("leader"), 1);
        let _ = w.send_abort();
    }

    /// Streaming collection over real sockets with a deliberately
    /// reversed arrival order: `collect_votes` must produce exactly the
    /// vote sums of a buffered client-order fold (u32 sums commute), and
    /// its byte bookkeeping must match the buffered receipt's.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn streaming_vote_collection_matches_buffered_fold_under_reversed_arrival() {
        const WORKERS: usize = 6;
        const N: usize = 33;
        let mask_of = |k: usize| -> Vec<bool> { (0..N).map(|i| (i * 7 + k) % 3 == 0).collect() };

        let (listener, addr) = bound_listener();
        let leader = std::thread::spawn(move || -> Result<VoteReceipt> {
            let mut leader = Leader::from_listener(listener, WORKERS)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![0.5; N] })?;
            let participants: Vec<usize> = (0..WORKERS).collect();
            let receipt =
                leader.collect_votes(0, &participants, N, DeadlinePolicy::unbounded())?;
            leader.shutdown()?;
            Ok(receipt)
        });

        let workers: Vec<_> = (0..WORKERS)
            .map(|k| {
                let addr = addr.clone();
                std::thread::spawn(move || -> Result<()> {
                    let mut w = Worker::connect(&addr, k as u32, MaskCodec::Raw)?;
                    let _ = w.recv()?;
                    // Higher ids send first: arrival order is the
                    // reverse of client order.
                    std::thread::sleep(Duration::from_millis(30 * (WORKERS - k) as u64));
                    w.send_mask(0, mask_of(k))?;
                    let _ = w.recv(); // drain the shutdown
                    Ok(())
                })
            })
            .collect();

        let receipt = leader.join().unwrap().expect("leader");
        for w in workers {
            w.join().unwrap().expect("worker");
        }

        // Buffered reference: fold every mask in *client* order.
        let mut want = vec![0u32; N];
        for k in 0..WORKERS {
            super::super::fold_mask_votes(&mut want, &mask_of(k));
        }
        assert_eq!(receipt.votes, want, "arrival-order fold diverged from client-order fold");
        assert_eq!(receipt.received, (0..WORKERS).collect::<Vec<_>>());
        assert!(receipt.dropped.is_empty());
        assert_eq!(receipt.frame_bytes.iter().sum::<u64>(), receipt.bytes);
        // O(n) collector state: the 4n accumulator plus one in-flight
        // frame and its decoded mask — never all six.
        assert!(receipt.peak_held_bytes >= 4 * N as u64);
        assert!(
            receipt.peak_held_bytes < (6 * WORKERS * N + 64) as u64 / 2,
            "peak {} suggests frames were buffered, not streamed",
            receipt.peak_held_bytes
        );
    }

    #[cfg(target_os = "linux")]
    fn thread_count() -> usize {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .expect("Threads: line in /proc/self/status")
            .trim()
            .parse()
            .unwrap()
    }

    #[cfg(target_os = "linux")]
    fn fd_count() -> usize {
        std::fs::read_dir("/proc/self/fd").unwrap().count()
    }

    /// The C10K fix, asserted rather than claimed: 100 sequential rounds
    /// — with a connect/abort churner reconnecting throughout — must not
    /// grow the process's thread or fd count past its steady state, and
    /// dropping the leader must join the sweeper and close the swept fd
    /// set, returning both counters to their pre-leader baselines.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    #[cfg(target_os = "linux")]
    fn hundred_rounds_grow_no_threads_or_fds_and_drop_closes_the_fd_set() {
        let base_threads = thread_count();
        let base_fds = fd_count();

        let (listener, addr) = bound_listener();
        let workers: Vec<_> = (0..2u32)
            .map(|id| {
                let addr = addr.clone();
                std::thread::spawn(move || -> Result<()> {
                    let mut w = Worker::connect(&addr, id, MaskCodec::Raw)?;
                    loop {
                        match w.recv()? {
                            ServerMsg::Round { round, .. } => w.send_mask(round, vec![true])?,
                            _ => return Ok(()),
                        }
                    }
                })
            })
            .collect();
        // Expect three ids but only block startup on the two steady
        // workers; id 2 is the churner's.
        let mut leader = Leader::from_listener_subset(listener, 3, &[0, 1]).expect("leader");

        let mut run_round = |leader: &mut Leader, round: u32| {
            let msg = ServerMsg::Round { round, probs: vec![1.0] };
            leader.broadcast_to(&msg, &[0, 1]).expect("broadcast");
            let receipt = leader
                .collect_votes(round, &[0, 1], 1, DeadlinePolicy::fixed(Duration::from_secs(20)))
                .expect("collect");
            assert_eq!(receipt.received, vec![0, 1], "round {round}");
        };

        for round in 0..10 {
            run_round(&mut leader, round);
        }
        let steady_threads = thread_count();
        let steady_fds = fd_count();

        for round in 10..100 {
            if round % 10 == 0 {
                // A short-lived extra connection each decade: Hello,
                // Abort, gone.  Its socket must leave the swept set (and
                // its slot's write half must drop) without residue.
                let mut churn = Worker::connect(&addr, 2, MaskCodec::Raw).expect("churner");
                churn.send_abort().expect("abort");
            }
            run_round(&mut leader, round);
        }
        // Let the sweeper notice the last churner's EOF, then drain the
        // resulting events through one more round.
        std::thread::sleep(4 * SWEEP_TICK);
        run_round(&mut leader, 100);

        assert_eq!(thread_count(), steady_threads, "reader threads grew with rounds");
        assert_eq!(fd_count(), steady_fds, "fds leaked across rounds/reconnects");

        leader.shutdown().expect("shutdown");
        for w in workers {
            w.join().unwrap().expect("worker");
        }
        drop(leader); // joins the sweeper, closes the swept fd set
        assert_eq!(thread_count(), base_threads, "leader drop leaked its sweeper");
        assert_eq!(fd_count(), base_fds, "leader drop leaked fds");
    }

    /// The population-axis claim, asserted: a 10k-client simulated round
    /// completes with zero extra leader threads (O(1) in the client
    /// count) and the streaming collector's peak held mask state is
    /// *identical* at 1k and 10k clients — O(n) in the model, not
    /// O(clients × n).
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn ten_thousand_simulated_clients_need_o1_threads_and_on_mask_memory() {
        const N: usize = 256;
        let round_peak = |clients: usize| -> u64 {
            let (mut leader, pop) = Leader::simulated(clients).expect("simulated leader");
            assert!(leader.sweeper.is_none(), "simulated leader must not spawn threads");
            let participants: Vec<usize> = (0..clients).collect();
            let msg = ServerMsg::Round { round: 0, probs: vec![0.5; N] };
            leader.broadcast_to(&msg, &participants).expect("broadcast");
            let mut want = vec![0u32; N];
            for k in 0..clients {
                let mask: Vec<bool> = (0..N).map(|i| (i + k) % 3 == 0).collect();
                super::super::fold_mask_votes(&mut want, &mask);
                let frame = encode_client(
                    &ClientMsg::Mask { round: 0, client: k as u32, n: N, mask },
                    MaskCodec::Raw,
                );
                assert!(pop.send_frame(k, frame));
            }
            let receipt = leader
                .collect_votes(0, &participants, N, DeadlinePolicy::unbounded())
                .expect("collect");
            assert_eq!(receipt.received.len(), clients);
            assert!(receipt.dropped.is_empty());
            assert_eq!(receipt.votes, want);
            receipt.peak_held_bytes
        };

        #[cfg(target_os = "linux")]
        let base_threads = thread_count();
        let peak_1k = round_peak(1_000);
        let peak_10k = round_peak(10_000);
        #[cfg(target_os = "linux")]
        assert_eq!(thread_count(), base_threads, "simulated rounds grew the thread count");

        assert_eq!(
            peak_1k, peak_10k,
            "collector peak grew with the population: memory is not O(n)"
        );
        // And the absolute bound: 4n accumulator + one frame + one
        // decoded mask, far below even two buffered frames.
        assert!(peak_10k < (8 * N + 128) as u64, "peak {peak_10k} too high for O(n)");
    }

    /// A worker that aborts after round 0 can reconnect with a fresh
    /// `Hello` and rejoin from the next round.
    #[test]
    #[cfg_attr(miri, ignore = "drives real sockets / poll(2), or is too slow under Miri")]
    fn worker_reconnects_with_hello() {
        let (listener, addr) = bound_listener();
        let (notify_tx, notify_rx) = std::sync::mpsc::channel::<()>();

        let leader = std::thread::spawn(move || -> Result<(RoundReceipt, RoundReceipt)> {
            let mut leader = Leader::from_listener(listener, 2)?;
            leader.broadcast(&ServerMsg::Round { round: 0, probs: vec![1.0] })?;
            let r0 = leader
                .collect_masks(0, &[0, 1], 1, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            // Ask the test to spawn the reconnecting worker, then wait
            // for its Hello before round 1.
            notify_tx.send(()).ok();
            assert!(leader.wait_for_client(0, Duration::from_secs(20))?, "no reconnect");
            leader.broadcast(&ServerMsg::Round { round: 1, probs: vec![1.0] })?;
            let r1 = leader
                .collect_masks(1, &[0, 1], 1, DeadlinePolicy::fixed(Duration::from_secs(20)))?;
            leader.shutdown()?;
            Ok((r0, r1))
        });

        let steady = {
            let addr = addr.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut w = Worker::connect(&addr, 1, MaskCodec::Raw)?;
                loop {
                    match w.recv()? {
                        ServerMsg::Round { round, .. } => w.send_mask(round, vec![true])?,
                        _ => return Ok(()),
                    }
                }
            })
        };
        // First incarnation of worker 0: receives round 0 but sends an
        // explicit Abort instead of its mask, so the drop is observed
        // *inside* the leader's collect (no Gone-vs-Hello event race by
        // the time the replacement connects).
        {
            let mut w = Worker::connect(&addr, 0, MaskCodec::Raw).expect("connect");
            let _ = w.recv().expect("round 0");
            w.send_abort().expect("abort");
        }
        notify_rx.recv().unwrap();
        // Second incarnation rejoins for round 1.
        let revenant = std::thread::spawn(move || -> Result<()> {
            let mut w = Worker::connect(&addr, 0, MaskCodec::Raw)?;
            loop {
                match w.recv()? {
                    ServerMsg::Round { round, .. } => w.send_mask(round, vec![true])?,
                    _ => return Ok(()),
                }
            }
        });

        let (r0, r1) = leader.join().unwrap().expect("leader");
        steady.join().unwrap().expect("steady");
        revenant.join().unwrap().expect("revenant");
        assert_eq!(r0.received, vec![1]);
        assert_eq!(r0.dropped, vec![0], "Abort must drop the worker for the round");
        assert_eq!(r1.received, vec![0, 1], "reconnected worker missing from round 1");
        assert_eq!(r1.masks[0], Some(vec![true]));
    }
}
