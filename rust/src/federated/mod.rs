//! Federated Zampling (§1.3, Fig. 1): server, clients, round protocol,
//! transports, and the in-process simulator behind §3.2.
//!
//! Per round `t`:
//! 1. server → clients: `p(t)` (floats — `32n` bits downlink per client);
//! 2. each client `k`: `s = p(t)`; local training-by-sampling on its
//!    shard (`z ~ Bern(p)` per batch, `w = Qz`, dense step, chain through
//!    `Qᵀ`, score update, clip);
//! 3. client samples `z_new ~ Bern(f(s))` and uplinks the **mask** —
//!    `n` bits (or fewer with the arithmetic coder);
//! 4. server: `p(t+1) = (1/R) Σ_{k ∈ received} z_new^{(k)}` — the mean is
//!    renormalized by the `R` masks that actually arrived, so partial
//!    participation ([`RoundPlan`]) and dropped/late clients shrink the
//!    average instead of corrupting it.
//!
//! The wire is real even in the in-process simulator: every message is
//! serialized through [`protocol`], the ledger records the actual encoded
//! byte counts, and the TCP transport ships the same frames.  The TCP
//! leader ([`transport::Leader`]) is fault-tolerant: per-round deadlines,
//! drop accounting, and reconnect-with-`Hello` (see `transport`'s module
//! docs for the fault model).
//!
//! Since the `RoundEngine` redesign there is exactly **one** round loop
//! ([`engine::RoundEngine`]), generic over [`engine::Transport`]
//! (in-process sequential, pool-parallel, TCP leader, sharded
//! multi-leader, gossip peers — in-process and over real sockets) and
//! [`engine::ParticipationPolicy`]
//! (uniform, straggler-aware); the historical drivers are thin
//! constructors over it.  See the repo-root `ARCHITECTURE.md` for the
//! full module map and `docs/PROTOCOL.md` for the wire format.
#![deny(missing_docs)]

pub mod checkpoint;
pub mod engine;
pub mod gossip;
pub mod protocol;
pub mod transport;
pub mod tree;

mod sim;

pub use checkpoint::{Checkpoint, CheckpointManifest};
pub use engine::{
    make_policy, Contribution, DeadlinePolicy, FedOutcome, Flaky, ParticipationPolicy, RoundCtx,
    RoundEngine, RoundHistory, RoundOutcome, RoundPlan, RoundTraffic, ShardPlan, StragglerAware,
    Transport, Uniform,
};
pub use sim::{
    client_round, resume_federated, run_federated, run_federated_custom, run_federated_elastic,
    run_federated_parallel, run_federated_sharded, run_federated_sharded_outages,
    run_federated_with_drop_schedule, ClientRound, InProcessTransport, PoolTransport,
    ScheduledDropTransport, ShardedSimTransport,
};
pub use tree::{mask_frame_bits, serve_shard, ShardTree, WireTreeTransport};

use crate::comm::{pack_bits, unpack_bits};

/// Server state: the global probability vector.
#[derive(Clone, Debug)]
pub struct Server {
    /// The global probability vector `p` the clients train against.
    pub probs: Vec<f32>,
    /// Accumulator for the current round's mask sum.
    acc: Vec<u32>,
    received: usize,
}

impl Server {
    /// Start from the shared-seed `p(0)`.
    pub fn new(init_probs: Vec<f32>) -> Self {
        let n = init_probs.len();
        Self { probs: init_probs, acc: vec![0; n], received: 0 }
    }

    /// Model size `n` (mask length).
    pub fn n(&self) -> usize {
        self.probs.len()
    }

    /// Fold in one client's uplinked mask.
    pub fn receive_mask(&mut self, mask_bits: &[u64]) {
        let mask = unpack_bits(mask_bits, self.n());
        for (a, b) in self.acc.iter_mut().zip(&mask) {
            *a += *b as u32;
        }
        self.received += 1;
    }

    /// Fold in one shard's partial vote sums — `received` masks already
    /// summed per entry by a shard leader (the `ShardVotes` merge frame).
    ///
    /// `u32` additions are exact, so merging S partial sums and then
    /// aggregating is **bit-identical** to receiving every underlying
    /// mask at one leader (property-tested in
    /// `tests/shard_merge_properties.rs`).  A shard that lost all its
    /// clients contributes `(zeros, 0)` and leaves the state untouched.
    pub fn merge_votes(&mut self, votes: &[u32], received: usize) {
        assert_eq!(votes.len(), self.n(), "vote sum length != model size");
        for (a, v) in self.acc.iter_mut().zip(votes) {
            *a += *v;
        }
        self.received += received;
    }

    /// How many masks arrived since the last aggregation.
    pub fn received(&self) -> usize {
        self.received
    }

    /// Close the round over whichever masks actually arrived:
    /// `p ← mean of received masks`, renormalized by the received count.
    /// Returns that count; with zero receipts the probabilities are left
    /// unchanged — the round is a no-op, not a crash — so a fully
    /// dropped round keeps the run alive.
    pub fn try_aggregate(&mut self) -> usize {
        let k = self.received;
        if k == 0 {
            return 0;
        }
        let kf = k as f32;
        for (p, &a) in self.probs.iter_mut().zip(&self.acc) {
            *p = a as f32 / kf;
        }
        self.acc.fill(0);
        self.received = 0;
        k
    }

    /// Close the round: `p ← mean of received masks`.  Panics if no mask
    /// arrived — for call sites where an empty round is a logic error;
    /// fault-tolerant paths use [`Self::try_aggregate`].
    pub fn aggregate(&mut self) {
        assert!(self.received > 0, "aggregate() with no client masks");
        self.try_aggregate();
    }
}

/// Re-export for client mask packing (used by sim and the TCP worker).
pub fn pack_client_mask(mask: &[bool]) -> Vec<u64> {
    pack_bits(mask)
}

/// Fold one mask into a shard's per-entry vote sums (the shard-leader
/// side of the sharded merge; one definition so the TCP and sim shard
/// collectors can never disagree).
pub(crate) fn fold_mask_votes(votes: &mut [u32], mask: &[bool]) {
    for (v, &b) in votes.iter_mut().zip(mask) {
        *v += b as u32;
    }
}

/// Root-side merge shared by [`transport::ShardedTransport`] and
/// [`ShardedSimTransport`]: decode each pending `ShardVotes` frame (an
/// empty slot means that shard failed and no frame ever arrived), fold
/// the partial sums into `server`, and close the round renormalized by
/// the total received count.  One body, so the real-socket and
/// simulator merge paths cannot silently diverge.
///
/// Beyond the wire-level checks in `protocol::decode_shard`, the root
/// enforces what only it can know from `plan`: the claimed shard id
/// must exist and the claimed `received` count cannot exceed the
/// number of clients that shard owns — otherwise a forged count would
/// inflate the renormalization divisor and collapse `p` toward zero
/// while passing every per-frame check.
pub(crate) fn merge_vote_frames(
    server: &mut Server,
    plan: &engine::ShardPlan,
    frames: &mut Vec<Vec<u8>>,
) -> usize {
    for frame in frames.drain(..) {
        if frame.is_empty() {
            continue; // failed shard: no merge frame arrived
        }
        let protocol::ShardMsg::ShardVotes { shard, received, n, votes, .. } =
            protocol::decode_shard(&frame).expect("root-encoded merge frame is valid");
        assert_eq!(n, server.n(), "shard votes length != model size");
        let shard = shard as usize;
        assert!(shard < plan.shards(), "shard id {shard} ≥ {}", plan.shards());
        assert!(
            received as usize <= plan.range(shard).len(),
            "shard {shard} claims {received} received masks but owns only {} clients",
            plan.range(shard).len()
        );
        server.merge_votes(&votes, received as usize);
    }
    server.try_aggregate()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_averages_masks() {
        let mut s = Server::new(vec![0.5; 4]);
        s.receive_mask(&pack_bits(&[true, false, true, false]));
        s.receive_mask(&pack_bits(&[true, true, false, false]));
        s.aggregate();
        assert_eq!(s.probs, vec![1.0, 0.5, 0.5, 0.0]);
        // next round starts fresh
        s.receive_mask(&pack_bits(&[false, false, false, true]));
        s.aggregate();
        assert_eq!(s.probs, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "no client masks")]
    fn aggregate_without_masks_panics() {
        Server::new(vec![0.5; 2]).aggregate();
    }

    #[test]
    fn try_aggregate_with_no_masks_is_a_noop() {
        let mut s = Server::new(vec![0.25, 0.75]);
        assert_eq!(s.try_aggregate(), 0);
        assert_eq!(s.probs, vec![0.25, 0.75]);
        // and the server still works on the next round
        s.receive_mask(&pack_bits(&[true, false]));
        assert_eq!(s.received(), 1);
        assert_eq!(s.try_aggregate(), 1);
        assert_eq!(s.probs, vec![1.0, 0.0]);
    }

    #[test]
    fn try_aggregate_renormalizes_by_received_count() {
        // 3 of 4 expected clients report: mean over the 3 that arrived.
        let mut s = Server::new(vec![0.5; 2]);
        s.receive_mask(&pack_bits(&[true, true]));
        s.receive_mask(&pack_bits(&[true, false]));
        s.receive_mask(&pack_bits(&[true, false]));
        assert_eq!(s.try_aggregate(), 3);
        assert_eq!(s.probs[0], 1.0);
        assert!((s.probs[1] - 1.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn merged_vote_sums_equal_per_mask_receipt() {
        let masks = [
            [true, false, true, false],
            [true, true, false, false],
            [false, true, true, true],
        ];
        // Reference: one server receives every mask.
        let mut single = Server::new(vec![0.5; 4]);
        for m in &masks {
            single.receive_mask(&pack_bits(m));
        }
        assert_eq!(single.try_aggregate(), 3);
        // Sharded: shard A sums masks 0-1, shard B sums mask 2, shard C
        // is empty; the root merges the partial sums.
        let mut root = Server::new(vec![0.5; 4]);
        root.merge_votes(&[2, 1, 1, 0], 2);
        root.merge_votes(&[0, 1, 1, 1], 1);
        root.merge_votes(&[0, 0, 0, 0], 0);
        assert_eq!(root.received(), 3);
        assert_eq!(root.try_aggregate(), 3);
        assert_eq!(root.probs, single.probs);
    }

    #[test]
    #[should_panic(expected = "claims 3 received masks")]
    fn merge_rejects_received_counts_beyond_the_shard_population() {
        // A forged `received` with all-zero votes passes every per-frame
        // decoder check but would inflate the renormalization divisor;
        // the root knows the shard plan and must refuse it.
        let plan = ShardPlan::new(4, 2); // each shard owns 2 clients
        let frame = protocol::encode_shard(&protocol::ShardMsg::ShardVotes {
            shard: 0,
            round: 0,
            received: 3, // > the 2 clients shard 0 owns
            n: 2,
            votes: vec![1, 0],
        });
        let mut server = Server::new(vec![0.5; 2]);
        merge_vote_frames(&mut server, &plan, &mut vec![frame]);
    }

    #[test]
    fn averaging_preserves_unit_interval() {
        let mut s = Server::new(vec![0.0; 3]);
        for _ in 0..7 {
            s.receive_mask(&pack_bits(&[true, false, true]));
        }
        s.aggregate();
        assert!(s.probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
