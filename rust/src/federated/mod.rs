//! Federated Zampling (§1.3, Fig. 1): server, clients, round protocol,
//! transports, and the in-process simulator behind §3.2.
//!
//! Per round `t`:
//! 1. server → clients: `p(t)` (floats — `32n` bits downlink per client);
//! 2. each client `k`: `s = p(t)`; local training-by-sampling on its
//!    shard (`z ~ Bern(p)` per batch, `w = Qz`, dense step, chain through
//!    `Qᵀ`, score update, clip);
//! 3. client samples `z_new ~ Bern(f(s))` and uplinks the **mask** —
//!    `n` bits (or fewer with the arithmetic coder);
//! 4. server: `p(t+1) = (1/R) Σ_{k ∈ received} z_new^{(k)}` — the mean is
//!    renormalized by the `R` masks that actually arrived, so partial
//!    participation ([`RoundPlan`]) and dropped/late clients shrink the
//!    average instead of corrupting it.
//!
//! The wire is real even in the in-process simulator: every message is
//! serialized through [`protocol`], the ledger records the actual encoded
//! byte counts, and the TCP transport ships the same frames.  The TCP
//! leader ([`transport::Leader`]) is fault-tolerant: per-round deadlines,
//! drop accounting, and reconnect-with-`Hello` (see `transport`'s module
//! docs for the fault model).
//!
//! Since the `RoundEngine` redesign there is exactly **one** round loop
//! ([`engine::RoundEngine`]), generic over [`engine::Transport`]
//! (in-process sequential, pool-parallel, TCP leader, gossip peers) and
//! [`engine::ParticipationPolicy`] (uniform, straggler-aware); the
//! historical drivers are thin constructors over it.

pub mod engine;
pub mod gossip;
pub mod protocol;
pub mod transport;

mod sim;

pub use engine::{
    make_policy, Contribution, DeadlinePolicy, FedOutcome, Flaky, ParticipationPolicy, RoundCtx,
    RoundEngine, RoundHistory, RoundOutcome, RoundPlan, RoundTraffic, StragglerAware, Transport,
    Uniform,
};
pub use sim::{
    client_round, run_federated, run_federated_custom, run_federated_parallel, ClientRound,
    InProcessTransport, PoolTransport,
};

use crate::comm::{pack_bits, unpack_bits};

/// Server state: the global probability vector.
#[derive(Clone, Debug)]
pub struct Server {
    pub probs: Vec<f32>,
    /// Accumulator for the current round's mask sum.
    acc: Vec<u32>,
    received: usize,
}

impl Server {
    pub fn new(init_probs: Vec<f32>) -> Self {
        let n = init_probs.len();
        Self { probs: init_probs, acc: vec![0; n], received: 0 }
    }

    pub fn n(&self) -> usize {
        self.probs.len()
    }

    /// Fold in one client's uplinked mask.
    pub fn receive_mask(&mut self, mask_bits: &[u64]) {
        let mask = unpack_bits(mask_bits, self.n());
        for (a, b) in self.acc.iter_mut().zip(&mask) {
            *a += *b as u32;
        }
        self.received += 1;
    }

    /// How many masks arrived since the last aggregation.
    pub fn received(&self) -> usize {
        self.received
    }

    /// Close the round over whichever masks actually arrived:
    /// `p ← mean of received masks`, renormalized by the received count.
    /// Returns that count; with zero receipts the probabilities are left
    /// unchanged — the round is a no-op, not a crash — so a fully
    /// dropped round keeps the run alive.
    pub fn try_aggregate(&mut self) -> usize {
        let k = self.received;
        if k == 0 {
            return 0;
        }
        let kf = k as f32;
        for (p, &a) in self.probs.iter_mut().zip(&self.acc) {
            *p = a as f32 / kf;
        }
        self.acc.fill(0);
        self.received = 0;
        k
    }

    /// Close the round: `p ← mean of received masks`.  Panics if no mask
    /// arrived — for call sites where an empty round is a logic error;
    /// fault-tolerant paths use [`Self::try_aggregate`].
    pub fn aggregate(&mut self) {
        assert!(self.received > 0, "aggregate() with no client masks");
        self.try_aggregate();
    }
}

/// Re-export for client mask packing (used by sim and the TCP worker).
pub fn pack_client_mask(mask: &[bool]) -> Vec<u64> {
    pack_bits(mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_averages_masks() {
        let mut s = Server::new(vec![0.5; 4]);
        s.receive_mask(&pack_bits(&[true, false, true, false]));
        s.receive_mask(&pack_bits(&[true, true, false, false]));
        s.aggregate();
        assert_eq!(s.probs, vec![1.0, 0.5, 0.5, 0.0]);
        // next round starts fresh
        s.receive_mask(&pack_bits(&[false, false, false, true]));
        s.aggregate();
        assert_eq!(s.probs, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "no client masks")]
    fn aggregate_without_masks_panics() {
        Server::new(vec![0.5; 2]).aggregate();
    }

    #[test]
    fn try_aggregate_with_no_masks_is_a_noop() {
        let mut s = Server::new(vec![0.25, 0.75]);
        assert_eq!(s.try_aggregate(), 0);
        assert_eq!(s.probs, vec![0.25, 0.75]);
        // and the server still works on the next round
        s.receive_mask(&pack_bits(&[true, false]));
        assert_eq!(s.received(), 1);
        assert_eq!(s.try_aggregate(), 1);
        assert_eq!(s.probs, vec![1.0, 0.0]);
    }

    #[test]
    fn try_aggregate_renormalizes_by_received_count() {
        // 3 of 4 expected clients report: mean over the 3 that arrived.
        let mut s = Server::new(vec![0.5; 2]);
        s.receive_mask(&pack_bits(&[true, true]));
        s.receive_mask(&pack_bits(&[true, false]));
        s.receive_mask(&pack_bits(&[true, false]));
        assert_eq!(s.try_aggregate(), 3);
        assert_eq!(s.probs[0], 1.0);
        assert!((s.probs[1] - 1.0 / 3.0).abs() < 1e-7);
    }

    #[test]
    fn averaging_preserves_unit_interval() {
        let mut s = Server::new(vec![0.0; 3]);
        for _ in 0..7 {
            s.receive_mask(&pack_bits(&[true, false, true]));
        }
        s.aggregate();
        assert!(s.probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
