//! The transport-agnostic round engine: **one** federated round loop
//! (plan → broadcast probs → collect masks → renormalized aggregate →
//! ledger row → eval) shared by every driver in the repo.
//!
//! The paper's protocol is transport-independent — server and clients
//! only exchange the Bernoulli mask over `p` — so the round state
//! machine lives here once, generic over two traits:
//!
//! * [`Transport`] — how the round frame reaches the participants and
//!   how their mask contributions come back.  Implementations:
//!   [`InProcessTransport`](super::sim::InProcessTransport) (sequential
//!   clients through one executor), [`PoolTransport`](super::sim::PoolTransport)
//!   (clients sharded across `runtime::pool`),
//!   [`TcpTransport`](super::transport::TcpTransport) (real sockets via
//!   the fault-tolerant [`Leader`](super::transport::Leader)), and
//!   [`PeerTransport`](super::gossip::PeerTransport) (decentralized
//!   gossip — each node runs a tiny aggregation engine for its
//!   neighbours).
//! * [`ParticipationPolicy`] — who participates each round.
//!   [`Uniform`] reproduces the seeded `RoundPlan` sampling;
//!   [`StragglerAware`] feeds the per-round `participants`/`dropped`
//!   ledger history back into the draw, deprioritizing clients that
//!   keep missing the deadline.
//!
//! At `participation = 1.0` with the [`Uniform`] policy the engine is
//! **byte-identical** to the four pre-refactor drivers
//! (`run_federated`, `run_federated_parallel`, the TCP leader loop,
//! `run_gossip`) — pinned by the legacy-replica and cross-transport
//! tests in `federated::sim`, `federated::gossip`, and
//! `tests/federated_integration.rs`.

use std::sync::Arc;
use std::time::Duration;

use crate::comm::{CommLedger, RoundCost};
use crate::config::{FedConfig, PolicyKind};
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::one_hot_into;
use crate::rng::{sample_distinct, Rng, SeedTree, Xoshiro256pp};
use crate::sparse::QMatrix;
use crate::util::error::Result;
use crate::zampling::{evaluate, DenseExecutor, ProbVector};

use super::protocol::{encode_server, ServerMsg};
use super::Server;

/// Result of a federated run.
pub struct FedOutcome {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub final_probs: Vec<f32>,
}

/// Which clients a round selects (sorted client ids).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    pub round: usize,
    pub participants: Vec<usize>,
}

/// Shared subset-sizing rule for every policy: `None` means "everyone,
/// no rng stream consumed" (the byte-identical legacy regime); `Some(k)`
/// is `max(1, round(participation·clients))`.  One definition, so no
/// two policies can ever disagree on the subset size for a config.
fn plan_size(clients: usize, participation: f64) -> Option<usize> {
    assert!(clients > 0, "round plan needs at least one client");
    assert!(
        participation > 0.0 && participation <= 1.0,
        "participation {participation} must be in (0, 1]"
    );
    if participation >= 1.0 {
        return None;
    }
    Some(((participation * clients as f64).round() as usize).clamp(1, clients))
}

impl RoundPlan {
    /// Select the round's participants uniformly.  `participation = 1.0`
    /// selects everyone without touching any rng stream; below that,
    /// `max(1, round(participation·clients))` distinct clients are drawn
    /// from the shared seed tree so leader and simulator agree on the
    /// subset without communicating it.
    pub fn for_round(
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        round: usize,
    ) -> RoundPlan {
        let Some(k) = plan_size(clients, participation) else {
            return RoundPlan { round, participants: (0..clients).collect() };
        };
        let mut rng = seeds.rng("round-participants", round as u64);
        let mut picks: Vec<u32> = Vec::with_capacity(k);
        sample_distinct(&mut rng, clients, k, &mut picks);
        let mut participants: Vec<usize> = picks.into_iter().map(|i| i as usize).collect();
        participants.sort_unstable();
        RoundPlan { round, participants }
    }
}

/// What actually happened in a round, after aggregation.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub plan: RoundPlan,
    /// Masks folded into the global mean (the renormalization count).
    pub received: usize,
    /// Selected clients whose mask never arrived.
    pub dropped: Vec<usize>,
    pub up_bits: u64,
    pub down_bits: u64,
    pub round_loss: f64,
}

/// One client's contribution to a round, as the transport saw it.
#[derive(Clone, Debug)]
pub struct Contribution {
    pub client: usize,
    /// Local training loss (0.0 for remote transports — workers keep
    /// their losses local).
    pub loss: f64,
    /// Encoded uplink bits this mask actually cost on the wire.
    pub up_bits: u64,
    /// The mask, bit-packed for aggregation.
    pub packed_mask: Vec<u64>,
}

/// Everything a transport's round exchange produced.  `contributions`
/// MUST be in ascending client order — every driver reduces in client
/// order so f64 summation and mask-fold order never change.
#[derive(Clone, Debug, Default)]
pub struct RoundTraffic {
    pub contributions: Vec<Contribution>,
    /// Selected clients whose mask did not arrive, ascending.
    pub dropped: Vec<usize>,
    /// Broadcast bits actually delivered this round.
    pub down_bits: u64,
}

/// Mask-collection deadline semantics, owned by the engine and handed to
/// the transport each round.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeadlinePolicy {
    /// Base per-round deadline (`None` = wait forever).
    pub timeout: Option<Duration>,
    /// Heartbeat extension cap, measured from collection start: a
    /// heartbeat from a pending participant pushes the deadline out to
    /// `now + timeout`, but never past `start + cap`.  `None` disables
    /// extension, so "slow but alive" and "dead" are treated alike.
    pub cap: Option<Duration>,
}

impl DeadlinePolicy {
    /// Wait forever (the in-process semantics).
    pub fn unbounded() -> Self {
        Self { timeout: None, cap: None }
    }

    /// A fixed deadline with no heartbeat extension.
    pub fn fixed(timeout: Duration) -> Self {
        Self { timeout: Some(timeout), cap: None }
    }

    /// Derive from config: `round_timeout_ms` (0 = ∞) as the base and
    /// `round_timeout_max_ms` (0 = no extension) as the heartbeat cap,
    /// clamped so the cap is never shorter than the base deadline.
    pub fn from_cfg(cfg: &FedConfig) -> Self {
        let timeout =
            (cfg.round_timeout_ms > 0).then(|| Duration::from_millis(cfg.round_timeout_ms));
        let cap = (cfg.round_timeout_max_ms > 0 && cfg.round_timeout_ms > 0)
            .then(|| Duration::from_millis(cfg.round_timeout_max_ms.max(cfg.round_timeout_ms)));
        Self { timeout, cap }
    }
}

/// Everything a transport needs to run one round's exchange.
pub struct RoundCtx<'a> {
    pub round: u32,
    /// The encoded `ServerMsg::Round` frame — exactly the bytes a TCP
    /// leader ships; in-process transports feed it to `client_round` so
    /// the ledger counts real protocol bytes everywhere.
    pub frame: &'a [u8],
    /// This round's participants, ascending.
    pub participants: &'a [usize],
    /// Model size (mask length) — remote transports validate against it.
    pub n: usize,
    pub deadline: DeadlinePolicy,
}

/// How masks move: broadcast the round frame, return what came back.
pub trait Transport {
    /// Whether this transport consumes the engine's encoded broadcast
    /// frame.  Peer-to-peer transports (gossip) return `false`, letting
    /// the engine skip the per-round probs clone + wire encode they
    /// would ignore; `ctx.frame` is then empty.
    fn wants_broadcast(&self) -> bool {
        true
    }

    /// Execute one round's communication: deliver `ctx.frame` to the
    /// participants, gather their mask contributions (deadline-bounded
    /// for remote implementations), and report drops + traffic.
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic>;

    /// Fold the round's masks into the global model state.  The default
    /// is the paper's central aggregation — mean over received masks,
    /// renormalized by the received count.  [`PeerTransport`]
    /// (decentralized gossip) overrides it with per-node neighbour means
    /// and writes the consensus vector into `server.probs` so the
    /// engine's evaluation path stays uniform.
    ///
    /// [`PeerTransport`]: super::gossip::PeerTransport
    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        for c in &traffic.contributions {
            server.receive_mask(&c.packed_mask);
        }
        server.try_aggregate()
    }

    /// The executor the engine evaluates the global model on.
    fn eval_executor(&mut self) -> &mut dyn DenseExecutor;

    /// Called once after the last round (e.g. broadcast `Shutdown`).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Per-client participation history the engine accumulates and feeds
/// back into the policy: how often each client recently missed a round
/// it was selected for.
#[derive(Clone, Debug)]
pub struct RoundHistory {
    /// Consecutive-miss pressure per client: +1 on every drop, halved on
    /// every successful receipt — a client that recovers sheds its
    /// penalty geometrically.
    pub misses: Vec<u32>,
}

impl RoundHistory {
    pub fn new(clients: usize) -> Self {
        Self { misses: vec![0; clients] }
    }

    pub fn miss_count(&self, client: usize) -> u32 {
        self.misses.get(client).copied().unwrap_or(0)
    }

    /// Fold one round's outcome in.
    pub fn note_round(&mut self, traffic: &RoundTraffic) {
        for c in &traffic.contributions {
            if let Some(m) = self.misses.get_mut(c.client) {
                *m /= 2;
            }
        }
        for &k in &traffic.dropped {
            if let Some(m) = self.misses.get_mut(k) {
                *m = m.saturating_add(1);
            }
        }
    }
}

/// Who participates each round.  Implementations must be deterministic
/// functions of `(seeds, round, history)` and must return a non-empty,
/// in-bounds, duplicate-free ascending subset (property-tested in
/// `tests/policy_properties.rs`).
pub trait ParticipationPolicy {
    fn name(&self) -> &'static str;

    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        history: &RoundHistory,
    ) -> RoundPlan;
}

/// The paper's policy: uniform seeded sampling, history-blind.  At
/// `participation = 1.0` no rng stream is consumed, which is what keeps
/// the engine byte-identical to the pre-refactor drivers.
pub struct Uniform;

impl ParticipationPolicy for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        _history: &RoundHistory,
    ) -> RoundPlan {
        RoundPlan::for_round(clients, participation, seeds, round)
    }
}

/// Straggler-aware participation: clients are drawn **without
/// replacement** with weight `1 / (1 + misses)` (Efraimidis–Spirakis
/// keys over a dedicated seed stream), so clients that repeatedly miss
/// `round_timeout_ms` are geometrically deprioritized while they keep a
/// nonzero chance to rejoin and shed their penalty.  Deterministic for
/// identical `(seed, round, history)`.
pub struct StragglerAware;

impl ParticipationPolicy for StragglerAware {
    fn name(&self) -> &'static str {
        "straggler-aware"
    }

    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        history: &RoundHistory,
    ) -> RoundPlan {
        let Some(k) = plan_size(clients, participation) else {
            return RoundPlan { round, participants: (0..clients).collect() };
        };
        let mut rng = seeds.rng("straggler-participants", round as u64);
        // Weighted sampling without replacement: key_i = ln(u_i) / w_i
        // (u in (0,1], so keys are ≤ 0); the k largest keys win.  Ties
        // break by client id, so the draw is a pure function of the
        // stream + history.
        let mut keyed: Vec<(f64, usize)> = (0..clients)
            .map(|i| {
                let u = rng.next_f64().max(f64::MIN_POSITIVE);
                let w = 1.0 / (1.0 + history.miss_count(i) as f64);
                (u.ln() / w, i)
            })
            .collect();
        keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut participants: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
        participants.sort_unstable();
        RoundPlan { round, participants }
    }
}

/// Build the configured policy.
pub fn make_policy(kind: PolicyKind) -> Box<dyn ParticipationPolicy> {
    match kind {
        PolicyKind::Uniform => Box::new(Uniform),
        PolicyKind::StragglerAware => Box::new(StragglerAware),
    }
}

/// Chaos decorator for tests and the dropout experiment: after the inner
/// transport's exchange, deterministically drop each client's
/// contribution with its per-client rate (seed stream `"chaos-drop"`),
/// simulating a straggler that received the broadcast and trained but
/// missed the collection deadline.  Downlink bits are unaffected (the
/// broadcast was delivered); the dropped mask's uplink bits never hit
/// the ledger — exactly the TCP leader's deadline semantics.
pub struct Flaky<T: Transport> {
    pub inner: T,
    seeds: SeedTree,
    rates: Vec<f64>,
}

impl<T: Transport> Flaky<T> {
    pub fn new(inner: T, seeds: SeedTree, rates: Vec<f64>) -> Self {
        Self { inner, seeds, rates }
    }
}

impl<T: Transport> Transport for Flaky<T> {
    fn wants_broadcast(&self) -> bool {
        self.inner.wants_broadcast()
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mut traffic = self.inner.exchange(ctx)?;
        let mut rng = self.seeds.rng("chaos-drop", ctx.round as u64);
        // One draw per population slot, so a client's fate this round is
        // independent of who else was selected.
        let fates: Vec<bool> = self.rates.iter().map(|&r| rng.bernoulli(r)).collect();
        let mut kept = Vec::with_capacity(traffic.contributions.len());
        for c in traffic.contributions.drain(..) {
            if fates.get(c.client).copied().unwrap_or(false) {
                traffic.dropped.push(c.client);
            } else {
                kept.push(c);
            }
        }
        traffic.contributions = kept;
        traffic.dropped.sort_unstable();
        Ok(traffic)
    }

    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        self.inner.aggregate(server, traffic)
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.inner.eval_executor()
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

/// The one round loop.  Owns the global server state, the savings
/// ledger, the run log, the eval machinery, and the participation
/// history; everything transport-specific lives behind the traits.
pub struct RoundEngine<'a> {
    cfg: &'a FedConfig,
    /// Client population (usually `cfg.clients`; the gossip transport
    /// passes its topology size).
    population: usize,
    seeds: SeedTree,
    server: Server,
    q: Arc<QMatrix>,
    test: &'a Dataset,
    test_y1h: Vec<f32>,
    eval_rng: Xoshiro256pp,
    eval_samples: usize,
    eval_every: usize,
    history: RoundHistory,
    log: RunLog,
    ledger: CommLedger,
    verbose: bool,
}

impl<'a> RoundEngine<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &'a FedConfig,
        population: usize,
        q: Arc<QMatrix>,
        init_probs: Vec<f32>,
        test: &'a Dataset,
        eval_samples: usize,
        eval_every: usize,
        log_name: &str,
    ) -> Self {
        assert!(population > 0, "engine needs at least one client");
        let seeds = SeedTree::new(cfg.train.seed);
        let out_dim = cfg.train.arch.output_dim();
        let mut test_y1h = vec![0.0f32; test.len() * out_dim];
        one_hot_into(&test.y, out_dim, &mut test_y1h);
        let eval_rng = seeds.rng("eval-sampler", 0);
        Self {
            cfg,
            population,
            seeds,
            server: Server::new(init_probs),
            q,
            test,
            test_y1h,
            eval_rng,
            eval_samples,
            eval_every,
            history: RoundHistory::new(population),
            log: RunLog::new(log_name),
            ledger: CommLedger::default(),
            verbose: false,
        }
    }

    /// Print per-round progress (drop reports + eval lines) as rounds
    /// complete — the TCP leader's live output.
    pub fn verbose(mut self, on: bool) -> Self {
        self.verbose = on;
        self
    }

    /// Drive `cfg.rounds` rounds over `transport` with `policy`.
    pub fn run(
        mut self,
        transport: &mut dyn Transport,
        policy: &mut dyn ParticipationPolicy,
    ) -> Result<FedOutcome> {
        let deadline = DeadlinePolicy::from_cfg(self.cfg);
        for round in 0..self.cfg.rounds {
            let plan = policy.select(
                round,
                self.population,
                self.cfg.participation,
                &self.seeds,
                &self.history,
            );
            // Broadcast p(t) — one encoded frame, shipped (or counted)
            // per participant by the transport.
            let frame = if transport.wants_broadcast() {
                encode_server(&ServerMsg::Round {
                    round: round as u32,
                    probs: self.server.probs.clone(),
                })
            } else {
                Vec::new()
            };
            let ctx = RoundCtx {
                round: round as u32,
                frame: &frame,
                participants: &plan.participants,
                n: self.cfg.train.n,
                deadline,
            };
            let traffic = transport.exchange(&ctx)?;

            // Reduce in client order (f64 summation order fixed), close
            // the aggregation renormalized by the received count, and
            // record the ledger row.
            let (mut up_bits, mut round_loss) = (0u64, 0.0f64);
            for c in &traffic.contributions {
                up_bits += c.up_bits;
                round_loss += c.loss;
            }
            let received = transport.aggregate(&mut self.server, &traffic);
            self.history.note_round(&traffic);
            self.ledger.record(RoundCost {
                uplink_bits: up_bits,
                downlink_bits: traffic.down_bits,
                clients: received as u32,
                participants: plan.participants.len() as u32,
                dropped: traffic.dropped.len() as u32,
            });
            if self.verbose && !traffic.dropped.is_empty() {
                println!("round {round:>3}  dropped clients {:?}", traffic.dropped);
            }
            let outcome = RoundOutcome {
                plan,
                received,
                dropped: traffic.dropped,
                up_bits,
                down_bits: traffic.down_bits,
                round_loss,
            };
            self.eval_and_log(transport, &outcome);
        }
        transport.finish()?;
        Ok(FedOutcome { log: self.log, ledger: self.ledger, final_probs: self.server.probs })
    }

    /// Evaluate the global `p` and push the round record when the
    /// cadence (or the final round) says so.  One body for all
    /// transports is what makes the drivers' logs identical by
    /// construction.
    fn eval_and_log(&mut self, transport: &mut dyn Transport, outcome: &RoundOutcome) {
        let round = outcome.plan.round;
        if round % self.eval_every != 0 && round + 1 != self.cfg.rounds {
            return;
        }
        let pv = ProbVector::from_probs(self.server.probs.clone());
        let rep = evaluate(
            transport.eval_executor(),
            &self.q,
            &pv,
            &self.test.x,
            &self.test_y1h,
            self.test.len(),
            self.eval_samples,
            &mut self.eval_rng,
        );
        if self.verbose {
            println!(
                "round {:>3}  sampled {:.4} ± {:.4}  expected {:.4}  ({} of {} masks)",
                round,
                rep.mean_sampled_acc,
                rep.sampled_acc_std,
                rep.expected_acc,
                outcome.received,
                outcome.plan.participants.len()
            );
        }
        self.log.push(RoundRecord {
            round,
            mean_sampled_acc: rep.mean_sampled_acc,
            sampled_acc_std: rep.sampled_acc_std,
            expected_acc: rep.expected_acc,
            train_loss: outcome.round_loss / outcome.received.max(1) as f64,
            uplink_bits: outcome.up_bits,
            downlink_bits: outcome.down_bits,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_plan_is_deterministic_and_sized() {
        let seeds = SeedTree::new(9);
        for round in 0..20 {
            let a = RoundPlan::for_round(10, 0.5, &seeds, round);
            let b = RoundPlan::for_round(10, 0.5, &seeds, round);
            assert_eq!(a, b);
            assert_eq!(a.participants.len(), 5);
            let mut sorted = a.participants.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "duplicate participant in {a:?}");
            assert!(a.participants.iter().all(|&k| k < 10));
        }
        // subsets vary across rounds
        let p0 = RoundPlan::for_round(10, 0.5, &seeds, 0);
        assert!((1..20).any(|r| RoundPlan::for_round(10, 0.5, &seeds, r) != p0));
        // full participation selects everyone, tiny rates select at least one
        assert_eq!(RoundPlan::for_round(4, 1.0, &seeds, 3).participants, vec![0, 1, 2, 3]);
        assert_eq!(RoundPlan::for_round(4, 0.01, &seeds, 3).participants.len(), 1);
    }

    #[test]
    fn straggler_aware_deprioritizes_repeat_missers() {
        let seeds = SeedTree::new(3);
        let clean = RoundHistory::new(8);
        let mut dirty = RoundHistory::new(8);
        dirty.misses[2] = 9; // chronic straggler: weight 1/10
        let mut policy = StragglerAware;
        let (mut with2_clean, mut with2_dirty) = (0usize, 0usize);
        for round in 0..200 {
            if policy.select(round, 8, 0.5, &seeds, &clean).participants.contains(&2) {
                with2_clean += 1;
            }
            if policy.select(round, 8, 0.5, &seeds, &dirty).participants.contains(&2) {
                with2_dirty += 1;
            }
        }
        // Expected ≈ 100 clean vs ≈ 15 dirty selections over 200 rounds.
        assert!(
            with2_dirty * 2 < with2_clean,
            "straggler not deprioritized: {with2_dirty} vs {with2_clean}"
        );
        // ... but never permanently excluded: weights stay positive.
        assert!(with2_dirty > 0, "straggler must keep a rejoin chance");
    }

    #[test]
    fn history_decays_on_receipt_and_grows_on_drop() {
        let mut h = RoundHistory::new(3);
        let drop_round = RoundTraffic {
            contributions: vec![],
            dropped: vec![1],
            down_bits: 0,
        };
        for _ in 0..4 {
            h.note_round(&drop_round);
        }
        assert_eq!(h.miss_count(1), 4);
        let ok_round = RoundTraffic {
            contributions: vec![Contribution {
                client: 1,
                loss: 0.0,
                up_bits: 0,
                packed_mask: vec![],
            }],
            dropped: vec![],
            down_bits: 0,
        };
        h.note_round(&ok_round);
        assert_eq!(h.miss_count(1), 2, "receipt halves the penalty");
        h.note_round(&ok_round);
        h.note_round(&ok_round);
        assert_eq!(h.miss_count(1), 0);
        // out-of-range ids are ignored, never panic
        h.note_round(&RoundTraffic { contributions: vec![], dropped: vec![99], down_bits: 0 });
    }

    #[test]
    fn deadline_policy_from_cfg() {
        let mut cfg = FedConfig::paper(8);
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert!(d.timeout.is_none() && d.cap.is_none(), "defaults wait forever");
        cfg.round_timeout_ms = 100;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.timeout, Some(Duration::from_millis(100)));
        assert!(d.cap.is_none());
        // cap is clamped to at least the base deadline
        cfg.round_timeout_max_ms = 50;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.cap, Some(Duration::from_millis(100)));
        cfg.round_timeout_max_ms = 5_000;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.cap, Some(Duration::from_millis(5_000)));
        // a cap without a base deadline is meaningless: stays unbounded
        cfg.round_timeout_ms = 0;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert!(d.timeout.is_none() && d.cap.is_none());
    }
}
