//! The transport-agnostic round engine: **one** federated round loop
//! (plan → broadcast probs → collect masks → renormalized aggregate →
//! ledger row → eval) shared by every driver in the repo.
//!
//! The paper's protocol is transport-independent — server and clients
//! only exchange the Bernoulli mask over `p` — so the round state
//! machine lives here once, generic over two traits:
//!
//! * [`Transport`] — how the round frame reaches the participants and
//!   how their mask contributions come back.  Implementations:
//!   [`InProcessTransport`](super::InProcessTransport) (sequential
//!   clients through one executor), [`PoolTransport`](super::PoolTransport)
//!   (clients sharded across `runtime::pool`),
//!   [`TcpTransport`](super::transport::TcpTransport) (real sockets via
//!   the fault-tolerant [`Leader`](super::transport::Leader)),
//!   [`ShardedTransport`](super::transport::ShardedTransport)
//!   (multi-leader: the client space partitioned by a [`ShardPlan`]
//!   across per-shard leaders whose partial vote sums merge at a root,
//!   with [`ShardedSimTransport`](super::ShardedSimTransport) as its
//!   in-process twin), and
//!   [`PeerTransport`](super::gossip::PeerTransport) (decentralized
//!   gossip — each node runs a tiny aggregation engine for its
//!   neighbours), and
//!   [`WirePeerTransport`](super::gossip::WirePeerTransport) (the same
//!   gossip protocol with every node a separate process over real
//!   sockets, coordinated through unbilled `PeerRound`/`Report`
//!   frames).
//! * [`ParticipationPolicy`] — who participates each round.
//!   [`Uniform`] reproduces the seeded `RoundPlan` sampling;
//!   [`StragglerAware`] feeds the per-round `participants`/`dropped`
//!   ledger history back into the draw, deprioritizing clients that
//!   keep missing the deadline.
//!
//! At `participation = 1.0` with the [`Uniform`] policy the engine is
//! **byte-identical** to the four pre-refactor drivers
//! (`run_federated`, `run_federated_parallel`, the TCP leader loop,
//! `run_gossip`) — pinned by the legacy-replica and cross-transport
//! tests in `federated::sim`, `federated::gossip`, and
//! `tests/federated_integration.rs`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::{CommLedger, EdgeCost, RoundCost, ShardCost};
use crate::config::{FedConfig, PolicyKind};
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::one_hot_into;
use crate::rng::{sample_distinct, Rng, SeedTree, Xoshiro256pp};
use crate::sparse::QMatrix;
use crate::util::error::Result;
use crate::zampling::{evaluate, DenseExecutor, ProbVector};
use crate::{anyhow, bail, ensure};

use super::checkpoint::{Checkpoint, CheckpointManifest};
use super::protocol::{encode_server, ServerMsg};
use super::Server;

/// Result of a federated run.
pub struct FedOutcome {
    /// Per-round accuracy/loss records (the run's CSV rows).
    pub log: RunLog,
    /// Per-round communication accounting.
    pub ledger: CommLedger,
    /// The server's final probability vector `p(T)`.
    pub final_probs: Vec<f32>,
    /// Final per-client participation history (drop pressure) — the
    /// sharded leader summarizes it per shard via
    /// [`RoundHistory::shard_misses`].
    pub history: RoundHistory,
}

/// Which clients a round selects (sorted client ids).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    /// The round index the selection is for.
    pub round: usize,
    /// Selected client ids, strictly ascending.
    pub participants: Vec<usize>,
}

/// Contiguous partition of the client id space across `S` shard leaders
/// — the topology behind the sharded transports
/// ([`ShardedTransport`](super::transport::ShardedTransport) on real
/// sockets, [`ShardedSimTransport`](super::ShardedSimTransport)
/// in-process).  Shard sizes differ by at most one; both root and
/// workers derive the same partition from `(clients, shards)` alone, so
/// no shard map ever travels on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    clients: usize,
    shards: usize,
}

impl ShardPlan {
    /// Partition `clients` ids across `shards` leaders.  Panics unless
    /// `1 ≤ shards ≤ clients` (an empty shard would be a leader with
    /// nothing to lead).
    pub fn new(clients: usize, shards: usize) -> ShardPlan {
        assert!(clients > 0, "shard plan needs at least one client");
        assert!(
            shards >= 1 && shards <= clients,
            "shards {shards} must be in 1..={clients}"
        );
        ShardPlan { clients, shards }
    }

    /// Total client population.
    pub fn clients(&self) -> usize {
        self.clients
    }

    /// Number of shard leaders.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The half-open client id range shard `shard` owns.  The first
    /// `clients % shards` shards hold one extra client.
    pub fn range(&self, shard: usize) -> std::ops::Range<usize> {
        assert!(shard < self.shards, "shard {shard} ≥ {}", self.shards);
        let base = self.clients / self.shards;
        let rem = self.clients % self.shards;
        let lo = shard * base + shard.min(rem);
        let hi = lo + base + usize::from(shard < rem);
        lo..hi
    }

    /// Which shard owns client `client` (inverse of [`Self::range`]).
    pub fn owner(&self, client: usize) -> usize {
        assert!(client < self.clients, "client {client} ≥ {}", self.clients);
        let base = self.clients / self.shards;
        let rem = self.clients % self.shards;
        let big = rem * (base + 1);
        if client < big {
            client / (base + 1)
        } else {
            rem + (client - big) / base
        }
    }

    /// Split an ascending participant list into one sub-slice per shard.
    /// Because shards own contiguous id ranges, each shard's
    /// participants are a contiguous window of the input — no copying.
    pub fn split<'a>(&self, participants: &'a [usize]) -> Vec<&'a [usize]> {
        let mut out = Vec::with_capacity(self.shards);
        let mut start = 0usize;
        for s in 0..self.shards {
            let hi = self.range(s).end;
            let len = participants[start..].iter().take_while(|&&k| k < hi).count();
            out.push(&participants[start..start + len]);
            start += len;
        }
        debug_assert_eq!(start, participants.len(), "participant outside every shard");
        out
    }
}

/// Shared subset-sizing rule for every policy: `None` means "everyone,
/// no rng stream consumed" (the byte-identical legacy regime); `Some(k)`
/// is `max(1, round(participation·clients))`.  One definition, so no
/// two policies can ever disagree on the subset size for a config.
fn plan_size(clients: usize, participation: f64) -> Option<usize> {
    assert!(clients > 0, "round plan needs at least one client");
    assert!(
        participation > 0.0 && participation <= 1.0,
        "participation {participation} must be in (0, 1]"
    );
    if participation >= 1.0 {
        return None;
    }
    Some(((participation * clients as f64).round() as usize).clamp(1, clients))
}

impl RoundPlan {
    /// Select the round's participants uniformly.  `participation = 1.0`
    /// selects everyone without touching any rng stream; below that,
    /// `max(1, round(participation·clients))` distinct clients are drawn
    /// from the shared seed tree so leader and simulator agree on the
    /// subset without communicating it.
    pub fn for_round(
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        round: usize,
    ) -> RoundPlan {
        let Some(k) = plan_size(clients, participation) else {
            return RoundPlan { round, participants: (0..clients).collect() };
        };
        let mut rng = seeds.rng("round-participants", round as u64);
        let mut picks: Vec<u32> = Vec::with_capacity(k);
        sample_distinct(&mut rng, clients, k, &mut picks);
        let mut participants: Vec<usize> = picks.into_iter().map(|i| i as usize).collect();
        participants.sort_unstable();
        RoundPlan { round, participants }
    }
}

/// What actually happened in a round, after aggregation.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// The selection the round ran with.
    pub plan: RoundPlan,
    /// Masks folded into the global mean (the renormalization count).
    pub received: usize,
    /// Selected clients whose mask never arrived.
    pub dropped: Vec<usize>,
    /// Total encoded uplink bits the round cost.
    pub up_bits: u64,
    /// Total broadcast bits delivered.
    pub down_bits: u64,
    /// Sum of the received clients' local losses.
    pub round_loss: f64,
}

/// One client's contribution to a round, as the transport saw it.
#[derive(Clone, Debug)]
pub struct Contribution {
    /// The contributing client's id.
    pub client: usize,
    /// Local training loss (0.0 for remote transports — workers keep
    /// their losses local).
    pub loss: f64,
    /// Encoded uplink bits this mask actually cost on the wire.
    pub up_bits: u64,
    /// The mask, bit-packed for aggregation.
    pub packed_mask: Vec<u64>,
}

/// Everything a transport's round exchange produced.  `contributions`
/// MUST be in ascending client order — every driver reduces in client
/// order so f64 summation and mask-fold order never change.
#[derive(Clone, Debug, Default)]
pub struct RoundTraffic {
    /// Received contributions, ascending by client id.
    pub contributions: Vec<Contribution>,
    /// Selected clients whose mask did not arrive, ascending.
    pub dropped: Vec<usize>,
    /// Broadcast bits actually delivered this round.
    pub down_bits: u64,
    /// Per-shard breakdown from sharded (multi-leader) transports —
    /// empty for single-leader transports.  The engine forwards it to
    /// the ledger's shard table verbatim.
    pub shard_costs: Vec<ShardCost>,
    /// Per-directed-edge breakdown from gossip transports — empty for
    /// centralized transports.  The engine forwards it to the ledger's
    /// edge table verbatim.
    pub edge_costs: Vec<EdgeCost>,
    /// Round wall-clock: the engine stamps the exchange → aggregate
    /// span after `aggregate` returns (transports construct this as
    /// `Duration::ZERO` and need not measure anything themselves).  The
    /// ledger derives bits/sec throughput from it.
    pub wall: Duration,
}

/// Mask-collection deadline semantics, owned by the engine and handed to
/// the transport each round.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeadlinePolicy {
    /// Base per-round deadline (`None` = wait forever).
    pub timeout: Option<Duration>,
    /// Heartbeat extension cap, measured from collection start: a
    /// heartbeat from a pending participant pushes the deadline out to
    /// `now + timeout`, but never past `start + cap`.  `None` disables
    /// extension, so "slow but alive" and "dead" are treated alike.
    pub cap: Option<Duration>,
}

impl DeadlinePolicy {
    /// Wait forever (the in-process semantics).
    pub fn unbounded() -> Self {
        Self { timeout: None, cap: None }
    }

    /// A fixed deadline with no heartbeat extension.
    pub fn fixed(timeout: Duration) -> Self {
        Self { timeout: Some(timeout), cap: None }
    }

    /// Derive from config: `round_timeout_ms` (0 = ∞) as the base and
    /// `round_timeout_max_ms` (0 = no extension) as the heartbeat cap,
    /// clamped so the cap is never shorter than the base deadline.
    pub fn from_cfg(cfg: &FedConfig) -> Self {
        let timeout =
            (cfg.round_timeout_ms > 0).then(|| Duration::from_millis(cfg.round_timeout_ms));
        let cap = (cfg.round_timeout_max_ms > 0 && cfg.round_timeout_ms > 0)
            .then(|| Duration::from_millis(cfg.round_timeout_max_ms.max(cfg.round_timeout_ms)));
        Self { timeout, cap }
    }
}

/// Everything a transport needs to run one round's exchange.
pub struct RoundCtx<'a> {
    /// The round index.
    pub round: u32,
    /// The encoded `ServerMsg::Round` frame — exactly the bytes a TCP
    /// leader ships; in-process transports feed it to `client_round` so
    /// the ledger counts real protocol bytes everywhere.
    pub frame: &'a [u8],
    /// This round's participants, ascending.
    pub participants: &'a [usize],
    /// Model size (mask length) — remote transports validate against it.
    pub n: usize,
    /// Mask-collection deadline semantics for this round.
    pub deadline: DeadlinePolicy,
}

/// How masks move: broadcast the round frame, return what came back.
pub trait Transport {
    /// Whether this transport consumes the engine's encoded broadcast
    /// frame.  Peer-to-peer transports (gossip) return `false`, letting
    /// the engine skip the per-round probs clone + wire encode they
    /// would ignore; `ctx.frame` is then empty.
    fn wants_broadcast(&self) -> bool {
        true
    }

    /// Execute one round's communication: deliver `ctx.frame` to the
    /// participants, gather their mask contributions (deadline-bounded
    /// for remote implementations), and report drops + traffic.
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic>;

    /// Fold the round's masks into the global model state.  The default
    /// is the paper's central aggregation — mean over received masks,
    /// renormalized by the received count.  [`PeerTransport`]
    /// (decentralized gossip) overrides it with per-node neighbour means
    /// and writes the consensus vector into `server.probs` so the
    /// engine's evaluation path stays uniform.
    ///
    /// [`PeerTransport`]: super::gossip::PeerTransport
    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        for c in &traffic.contributions {
            server.receive_mask(&c.packed_mask);
        }
        server.try_aggregate()
    }

    /// The executor the engine evaluates the global model on.
    fn eval_executor(&mut self) -> &mut dyn DenseExecutor;

    /// Ids of previously-unknown clients (`id >= population`) whose
    /// `Hello` has arrived since the last round boundary — elastic
    /// membership.  The engine calls this at every round boundary and
    /// grows the population to cover the returned ids; transports with a
    /// fixed roster keep the default (no joins).  Returned ids must be
    /// ascending and below the config's `max-clients` ceiling.
    fn poll_joins(&mut self, _round: u32, _population: usize) -> Vec<usize> {
        Vec::new()
    }

    /// Called once after the last round (e.g. broadcast `Shutdown`).
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Per-client participation history the engine accumulates and feeds
/// back into the policy: how often each client recently missed a round
/// it was selected for.
#[derive(Clone, Debug)]
pub struct RoundHistory {
    /// Consecutive-miss pressure per client: +1 on every drop, halved on
    /// every successful receipt — a client that recovers sheds its
    /// penalty geometrically.
    pub misses: Vec<u32>,
}

impl RoundHistory {
    /// Fresh history: nobody has missed anything yet.
    pub fn new(clients: usize) -> Self {
        Self { misses: vec![0; clients] }
    }

    /// Current miss pressure for `client` (0 for out-of-range ids).
    pub fn miss_count(&self, client: usize) -> u32 {
        self.misses.get(client).copied().unwrap_or(0)
    }

    /// Fold one round's outcome in.
    pub fn note_round(&mut self, traffic: &RoundTraffic) {
        for c in &traffic.contributions {
            if let Some(m) = self.misses.get_mut(c.client) {
                *m /= 2;
            }
        }
        for &k in &traffic.dropped {
            if let Some(m) = self.misses.get_mut(k) {
                *m = m.saturating_add(1);
            }
        }
    }

    /// The per-shard view of the same history: total miss pressure per
    /// shard of `plan`.  Because a whole-shard outage drops every client
    /// the shard owns, its misses accumulate together — the sharded
    /// leader prints this in its end-of-run summary; per-client
    /// policies like [`StragglerAware`] keep consuming
    /// [`Self::miss_count`] directly, which already deprioritizes every
    /// member of a dead shard.
    pub fn shard_misses(&self, plan: &ShardPlan) -> Vec<u32> {
        (0..plan.shards())
            .map(|s| plan.range(s).map(|k| self.miss_count(k)).sum())
            .collect()
    }
}

/// Who participates each round.  Implementations must be deterministic
/// functions of `(seeds, round, history)` and must return a non-empty,
/// in-bounds, duplicate-free ascending subset (property-tested in
/// `tests/policy_properties.rs`).
pub trait ParticipationPolicy {
    /// Stable policy name (config values, logs, test failure messages).
    fn name(&self) -> &'static str;

    /// Select `round`'s participants from the population.
    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        history: &RoundHistory,
    ) -> RoundPlan;
}

/// The paper's policy: uniform seeded sampling, history-blind.  At
/// `participation = 1.0` no rng stream is consumed, which is what keeps
/// the engine byte-identical to the pre-refactor drivers.
pub struct Uniform;

impl ParticipationPolicy for Uniform {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        _history: &RoundHistory,
    ) -> RoundPlan {
        RoundPlan::for_round(clients, participation, seeds, round)
    }
}

/// Straggler-aware participation: clients are drawn **without
/// replacement** with weight `1 / (1 + misses)` (Efraimidis–Spirakis
/// keys over a dedicated seed stream), so clients that repeatedly miss
/// `round_timeout_ms` are geometrically deprioritized while they keep a
/// nonzero chance to rejoin and shed their penalty.  Deterministic for
/// identical `(seed, round, history)`.
pub struct StragglerAware;

impl ParticipationPolicy for StragglerAware {
    fn name(&self) -> &'static str {
        "straggler-aware"
    }

    fn select(
        &mut self,
        round: usize,
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        history: &RoundHistory,
    ) -> RoundPlan {
        let Some(k) = plan_size(clients, participation) else {
            return RoundPlan { round, participants: (0..clients).collect() };
        };
        let mut rng = seeds.rng("straggler-participants", round as u64);
        // Weighted sampling without replacement: key_i = ln(u_i) / w_i
        // (u in (0,1], so keys are ≤ 0); the k largest keys win.  Ties
        // break by client id, so the draw is a pure function of the
        // stream + history.
        let mut keyed: Vec<(f64, usize)> = (0..clients)
            .map(|i| {
                let u = rng.next_f64().max(f64::MIN_POSITIVE);
                let w = 1.0 / (1.0 + history.miss_count(i) as f64);
                (u.ln() / w, i)
            })
            .collect();
        keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        let mut participants: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
        participants.sort_unstable();
        RoundPlan { round, participants }
    }
}

/// Build the configured policy.
pub fn make_policy(kind: PolicyKind) -> Box<dyn ParticipationPolicy> {
    match kind {
        PolicyKind::Uniform => Box::new(Uniform),
        PolicyKind::StragglerAware => Box::new(StragglerAware),
    }
}

/// Chaos decorator for tests and the dropout experiment: after the inner
/// transport's exchange, deterministically drop each client's
/// contribution with its per-client rate (seed stream `"chaos-drop"`),
/// simulating a straggler that received the broadcast and trained but
/// missed the collection deadline.  Downlink bits are unaffected (the
/// broadcast was delivered); the dropped mask's uplink bits never hit
/// the ledger — exactly the TCP leader's deadline semantics.
///
/// Wrap transports that carry each contribution's `packed_mask` into
/// the engine's default aggregation (the in-process simulators) only:
/// streaming transports — the sharded family **and** the event-loop
/// [`TcpTransport`](super::transport::TcpTransport) — fold vote sums at
/// collection time, ahead of this decorator's filter, so chaos injected
/// here would desynchronize the folded sums from the surviving
/// contributions.  The sharded simulator has its own whole-shard
/// failure knob instead
/// ([`ShardedSimTransport::with_failed_shards`](super::ShardedSimTransport::with_failed_shards)).
pub struct Flaky<T: Transport> {
    /// The transport whose exchanges get chaos-filtered.
    pub inner: T,
    seeds: SeedTree,
    rates: Vec<f64>,
}

impl<T: Transport> Flaky<T> {
    /// Wrap `inner`, dropping client `k`'s contribution with
    /// probability `rates[k]` each round (seeded by `seeds`).
    pub fn new(inner: T, seeds: SeedTree, rates: Vec<f64>) -> Self {
        Self { inner, seeds, rates }
    }
}

impl<T: Transport> Transport for Flaky<T> {
    fn wants_broadcast(&self) -> bool {
        self.inner.wants_broadcast()
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mut traffic = self.inner.exchange(ctx)?;
        let mut rng = self.seeds.rng("chaos-drop", ctx.round as u64);
        // One draw per population slot, so a client's fate this round is
        // independent of who else was selected.
        let fates: Vec<bool> = self.rates.iter().map(|&r| rng.bernoulli(r)).collect();
        let mut kept = Vec::with_capacity(traffic.contributions.len());
        for c in traffic.contributions.drain(..) {
            if fates.get(c.client).copied().unwrap_or(false) {
                traffic.dropped.push(c.client);
            } else {
                kept.push(c);
            }
        }
        traffic.contributions = kept;
        traffic.dropped.sort_unstable();
        Ok(traffic)
    }

    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        self.inner.aggregate(server, traffic)
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.inner.eval_executor()
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

/// The one round loop.  Owns the global server state, the savings
/// ledger, the run log, the eval machinery, and the participation
/// history; everything transport-specific lives behind the traits.
///
/// # Quick start
///
/// Drive a tiny federated run through the engine with the sequential
/// in-process transport — `run_federated` is exactly this, packaged:
///
/// ```
/// use std::sync::Arc;
/// use zampling::config::FedConfig;
/// use zampling::data::Dataset;
/// use zampling::federated::{make_policy, InProcessTransport, RoundEngine};
/// use zampling::nn::ArchSpec;
/// use zampling::rng::SeedTree;
/// use zampling::sparse::QMatrix;
/// use zampling::zampling::{LocalZampling, NativeExecutor, ProbVector};
///
/// let mut cfg = FedConfig::paper(8);
/// cfg.train.arch = ArchSpec::small();
/// cfg.train.n = ArchSpec::small().num_params() / 8;
/// cfg.train.d = 5;
/// cfg.clients = 2;
/// cfg.rounds = 1;
///
/// // Shared-seed setup: data shards, Q, p(0), per-client states.
/// let seeds = SeedTree::new(cfg.train.seed);
/// let (train, test) = Dataset::synthetic_pair(256, 64, &seeds);
/// let shards = train.partition_iid(cfg.clients, &seeds);
/// let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
/// let csc = Arc::new(q.to_csc(None));
/// let mut init_rng = seeds.rng("p-init", 0);
/// let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();
/// let clients: Vec<LocalZampling> = (0..cfg.clients)
///     .map(|k| {
///         let sub = seeds.subtree("client", k as u64);
///         LocalZampling::from_parts(
///             &cfg.train,
///             Arc::clone(&q),
///             Arc::clone(&csc),
///             ProbVector::from_probs(p0.clone()),
///             &sub,
///         )
///     })
///     .collect();
///
/// // One engine, one transport, one policy: run the rounds.
/// let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 64);
/// let engine = RoundEngine::new(&cfg, cfg.clients, Arc::clone(&q), p0, &test, 2, 1, "doc");
/// let mut transport = InProcessTransport::new(&cfg, &mut exec, &shards, clients);
/// let mut policy = make_policy(cfg.policy);
/// let out = engine.run(&mut transport, policy.as_mut()).unwrap();
/// assert_eq!(out.final_probs.len(), cfg.train.n);
/// assert_eq!(out.ledger.rounds.len(), cfg.rounds);
/// ```
pub struct RoundEngine<'a> {
    cfg: &'a FedConfig,
    /// Client population (usually `cfg.clients`; the gossip transport
    /// passes its topology size).
    population: usize,
    seeds: SeedTree,
    server: Server,
    q: Arc<QMatrix>,
    test: &'a Dataset,
    test_y1h: Vec<f32>,
    eval_rng: Xoshiro256pp,
    eval_samples: usize,
    eval_every: usize,
    history: RoundHistory,
    log: RunLog,
    ledger: CommLedger,
    verbose: bool,
    /// First round `run` executes (0 for a fresh engine; the restored
    /// `next_round` cursor for a resumed one).
    start_round: usize,
    /// Write a checkpoint every K completed rounds (0 = never).
    checkpoint_every: usize,
    /// Where the checkpoint file goes (atomic temp + rename).
    checkpoint_path: Option<PathBuf>,
    /// Chaos hook: error out at the start of the given round, simulating
    /// a leader killed mid-run (testnet `kill-root` scenarios).
    fail_at_round: Option<u32>,
}

impl<'a> RoundEngine<'a> {
    /// Build an engine over `population` clients starting from
    /// `init_probs`, evaluating `eval_samples` sampled networks on
    /// `test` every `eval_every` rounds into a log named `log_name`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &'a FedConfig,
        population: usize,
        q: Arc<QMatrix>,
        init_probs: Vec<f32>,
        test: &'a Dataset,
        eval_samples: usize,
        eval_every: usize,
        log_name: &str,
    ) -> Self {
        assert!(population > 0, "engine needs at least one client");
        let seeds = SeedTree::new(cfg.train.seed);
        let out_dim = cfg.train.arch.output_dim();
        let mut test_y1h = vec![0.0f32; test.len() * out_dim];
        one_hot_into(&test.y, out_dim, &mut test_y1h);
        let eval_rng = seeds.rng("eval-sampler", 0);
        Self {
            cfg,
            population,
            seeds,
            server: Server::new(init_probs),
            q,
            test,
            test_y1h,
            eval_rng,
            eval_samples,
            eval_every,
            history: RoundHistory::new(population),
            log: RunLog::new(log_name),
            ledger: CommLedger::default(),
            verbose: false,
            start_round: 0,
            checkpoint_every: 0,
            checkpoint_path: None,
            fail_at_round: None,
        }
    }

    /// Reconstruct an engine mid-run from a [`Checkpoint`]: the restored
    /// engine executes rounds `next_round..rounds` and is byte-identical
    /// to the uninterrupted run — the probabilities, straggler history,
    /// run log, ledger, and evaluation-RNG cursor all continue exactly
    /// where the snapshot left them, and every other determinism-path
    /// stream is re-derived from `(seed, stream, round)`.
    ///
    /// The manifest is cross-checked against `cfg`: a checkpoint from a
    /// different run (seed, model size, roster, schedule, participation,
    /// or shard count drift) is rejected rather than silently blended.
    pub fn resume(
        cfg: &'a FedConfig,
        ckpt: Checkpoint,
        q: Arc<QMatrix>,
        test: &'a Dataset,
    ) -> Result<Self> {
        let m = &ckpt.manifest;
        ensure!(m.seed == cfg.train.seed, "checkpoint seed {} != config seed {}", m.seed, cfg.train.seed);
        ensure!(
            m.n as usize == cfg.train.n,
            "checkpoint n {} != config n {}",
            m.n,
            cfg.train.n
        );
        ensure!(
            m.clients as usize == cfg.clients,
            "checkpoint clients {} != config clients {}",
            m.clients,
            cfg.clients
        );
        ensure!(
            m.max_clients as usize == cfg.max_clients,
            "checkpoint max-clients {} != config max-clients {}",
            m.max_clients,
            cfg.max_clients
        );
        ensure!(
            m.rounds as usize == cfg.rounds,
            "checkpoint rounds {} != config rounds {}",
            m.rounds,
            cfg.rounds
        );
        ensure!(
            m.shards as usize == cfg.shards,
            "checkpoint shards {} != config shards {}",
            m.shards,
            cfg.shards
        );
        ensure!(
            m.participation_bits == cfg.participation.to_bits(),
            "checkpoint participation {} != config participation {}",
            f64::from_bits(m.participation_bits),
            cfg.participation
        );
        let eval_rng = Xoshiro256pp::from_state(ckpt.eval_rng)
            .ok_or_else(|| anyhow!("checkpoint eval-rng cursor is the all-zero state"))?;
        let out_dim = cfg.train.arch.output_dim();
        let mut test_y1h = vec![0.0f32; test.len() * out_dim];
        one_hot_into(&test.y, out_dim, &mut test_y1h);
        Ok(Self {
            cfg,
            population: m.population as usize,
            seeds: SeedTree::new(cfg.train.seed),
            server: Server::new(ckpt.probs),
            q,
            test,
            test_y1h,
            eval_rng,
            eval_samples: m.eval_samples as usize,
            eval_every: m.eval_every as usize,
            start_round: m.next_round as usize,
            history: RoundHistory { misses: ckpt.misses },
            log: RunLog { name: ckpt.log_name, rounds: ckpt.records },
            ledger: ckpt.ledger,
            verbose: false,
            checkpoint_every: 0,
            checkpoint_path: None,
            fail_at_round: None,
        })
    }

    /// Print per-round progress (drop reports + eval lines) as rounds
    /// complete — the TCP leader's live output.
    pub fn verbose(mut self, on: bool) -> Self {
        self.verbose = on;
        self
    }

    /// Write a checkpoint to `path` after every `every` completed rounds
    /// (0 disables).  The write happens at the round boundary — after the
    /// round's aggregation, history, ledger, and eval bookkeeping — so a
    /// resume replays from exactly that boundary.
    pub fn checkpoint_to(mut self, every: usize, path: Option<PathBuf>) -> Self {
        self.checkpoint_every = if path.is_some() { every } else { 0 };
        self.checkpoint_path = path;
        self
    }

    /// Chaos hook: make `run` error out at the start of round `round`
    /// (before broadcasting), simulating a leader killed mid-run.  The
    /// testnet's `kill-root` scenarios drive this via
    /// `--fail-at-round` and then resume from the last checkpoint.
    pub fn fail_at_round(mut self, round: Option<u32>) -> Self {
        self.fail_at_round = round;
        self
    }

    /// Drive `cfg.rounds` rounds over `transport` with `policy`.
    pub fn run(
        mut self,
        transport: &mut dyn Transport,
        policy: &mut dyn ParticipationPolicy,
    ) -> Result<FedOutcome> {
        let deadline = DeadlinePolicy::from_cfg(self.cfg);
        for round in self.start_round..self.cfg.rounds {
            // Elastic membership: admit clients whose `Hello` arrived
            // since the last boundary.  Population only ever grows; a
            // departed client ages out through the straggler history
            // instead of shrinking the roster, so client ids stay
            // stable for the whole run.
            let joined = transport.poll_joins(round as u32, self.population);
            if !joined.is_empty() {
                for &id in &joined {
                    ensure!(
                        id < self.cfg.max_clients,
                        "joining client {id} beyond max-clients {}",
                        self.cfg.max_clients
                    );
                    self.population = self.population.max(id + 1);
                }
                self.history.misses.resize(self.population, 0);
                if self.verbose {
                    println!("round {round:>3}  joined clients {joined:?}");
                }
            }
            if self.fail_at_round == Some(round as u32) {
                bail!("chaos: leader failing at round {round} (fail-at-round schedule)");
            }
            let plan = policy.select(
                round,
                self.population,
                self.cfg.participation,
                &self.seeds,
                &self.history,
            );
            // Broadcast p(t) — one encoded frame, shipped (or counted)
            // per participant by the transport.
            let frame = if transport.wants_broadcast() {
                encode_server(&ServerMsg::Round {
                    round: round as u32,
                    probs: self.server.probs.clone(),
                })
            } else {
                Vec::new()
            };
            let ctx = RoundCtx {
                round: round as u32,
                frame: &frame,
                participants: &plan.participants,
                n: self.cfg.train.n,
                deadline,
            };
            // lint: allow(nondeterminism) — wall-clock round duration is
            // telemetry only (the ledger's `wall_ns` column); it never feeds
            // back into training state, so byte-identicality is unaffected.
            let round_start = Instant::now();
            let mut traffic = transport.exchange(&ctx)?;

            // Reduce in client order (f64 summation order fixed), close
            // the aggregation renormalized by the received count, and
            // record the ledger row (plus the per-shard breakdown when a
            // sharded transport supplied one).
            let (mut up_bits, mut round_loss) = (0u64, 0.0f64);
            for c in &traffic.contributions {
                up_bits += c.up_bits;
                round_loss += c.loss;
            }
            let received = transport.aggregate(&mut self.server, &traffic);
            traffic.wall = round_start.elapsed();
            self.history.note_round(&traffic);
            self.ledger.record(RoundCost {
                uplink_bits: up_bits,
                downlink_bits: traffic.down_bits,
                clients: received as u32,
                participants: plan.participants.len() as u32,
                dropped: traffic.dropped.len() as u32,
                wall_ns: traffic.wall.as_nanos() as u64,
            });
            self.ledger.record_shard_costs(std::mem::take(&mut traffic.shard_costs));
            self.ledger.record_edge_costs(std::mem::take(&mut traffic.edge_costs));
            if self.verbose && !traffic.dropped.is_empty() {
                println!("round {round:>3}  dropped clients {:?}", traffic.dropped);
            }
            let outcome = RoundOutcome {
                plan,
                received,
                dropped: traffic.dropped,
                up_bits,
                down_bits: traffic.down_bits,
                round_loss,
            };
            self.eval_and_log(transport, &outcome);
            // Checkpoint at the round boundary, after all bookkeeping,
            // so a resume replays from exactly this point.  The final
            // round never checkpoints — the run's artifacts are about
            // to be written anyway.
            if self.checkpoint_every != 0
                && (round + 1) % self.checkpoint_every == 0
                && round + 1 < self.cfg.rounds
            {
                self.write_checkpoint((round + 1) as u32)?;
            }
        }
        transport.finish()?;
        Ok(FedOutcome {
            log: self.log,
            ledger: self.ledger,
            final_probs: self.server.probs,
            history: self.history,
        })
    }

    /// Snapshot the run at a round boundary: `next_round` is the first
    /// round a resume must execute.  Everything the snapshot needs is
    /// either immutable run geometry (re-checked at resume) or the
    /// engine's own accumulated state.
    fn write_checkpoint(&self, next_round: u32) -> Result<()> {
        let Some(path) = &self.checkpoint_path else {
            return Ok(());
        };
        let ckpt = Checkpoint {
            manifest: CheckpointManifest {
                seed: self.cfg.train.seed,
                n: self.cfg.train.n as u32,
                clients: self.cfg.clients as u32,
                max_clients: self.cfg.max_clients as u32,
                rounds: self.cfg.rounds as u32,
                shards: self.cfg.shards as u32,
                population: self.population as u32,
                next_round,
                eval_every: self.eval_every as u32,
                eval_samples: self.eval_samples as u32,
                participation_bits: self.cfg.participation.to_bits(),
            },
            probs: self.server.probs.clone(),
            eval_rng: self.eval_rng.state(),
            misses: self.history.misses.clone(),
            log_name: self.log.name.clone(),
            records: self.log.rounds.clone(),
            ledger: self.ledger.clone(),
        };
        ckpt.write_atomic(path)
    }

    /// Evaluate the global `p` and push the round record when the
    /// cadence (or the final round) says so.  One body for all
    /// transports is what makes the drivers' logs identical by
    /// construction.
    fn eval_and_log(&mut self, transport: &mut dyn Transport, outcome: &RoundOutcome) {
        let round = outcome.plan.round;
        if round % self.eval_every != 0 && round + 1 != self.cfg.rounds {
            return;
        }
        let pv = ProbVector::from_probs(self.server.probs.clone());
        let rep = evaluate(
            transport.eval_executor(),
            &self.q,
            &pv,
            &self.test.x,
            &self.test_y1h,
            self.test.len(),
            self.eval_samples,
            &mut self.eval_rng,
        );
        if self.verbose {
            println!(
                "round {:>3}  sampled {:.4} ± {:.4}  expected {:.4}  ({} of {} masks)",
                round,
                rep.mean_sampled_acc,
                rep.sampled_acc_std,
                rep.expected_acc,
                outcome.received,
                outcome.plan.participants.len()
            );
        }
        self.log.push(RoundRecord {
            round,
            mean_sampled_acc: rep.mean_sampled_acc,
            sampled_acc_std: rep.sampled_acc_std,
            expected_acc: rep.expected_acc,
            train_loss: outcome.round_loss / outcome.received.max(1) as f64,
            uplink_bits: outcome.up_bits,
            downlink_bits: outcome.down_bits,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_plan_is_deterministic_and_sized() {
        let seeds = SeedTree::new(9);
        for round in 0..20 {
            let a = RoundPlan::for_round(10, 0.5, &seeds, round);
            let b = RoundPlan::for_round(10, 0.5, &seeds, round);
            assert_eq!(a, b);
            assert_eq!(a.participants.len(), 5);
            let mut sorted = a.participants.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "duplicate participant in {a:?}");
            assert!(a.participants.iter().all(|&k| k < 10));
        }
        // subsets vary across rounds
        let p0 = RoundPlan::for_round(10, 0.5, &seeds, 0);
        assert!((1..20).any(|r| RoundPlan::for_round(10, 0.5, &seeds, r) != p0));
        // full participation selects everyone, tiny rates select at least one
        assert_eq!(RoundPlan::for_round(4, 1.0, &seeds, 3).participants, vec![0, 1, 2, 3]);
        assert_eq!(RoundPlan::for_round(4, 0.01, &seeds, 3).participants.len(), 1);
    }

    #[test]
    fn shard_plan_partitions_exactly() {
        for clients in 1..=17usize {
            for shards in 1..=clients {
                let plan = ShardPlan::new(clients, shards);
                // ranges tile the id space and sizes differ by ≤ 1
                let mut seen = 0usize;
                let (mut lo_sz, mut hi_sz) = (usize::MAX, 0usize);
                for s in 0..shards {
                    let r = plan.range(s);
                    assert_eq!(r.start, seen, "gap before shard {s}");
                    lo_sz = lo_sz.min(r.len());
                    hi_sz = hi_sz.max(r.len());
                    for k in r.clone() {
                        assert_eq!(plan.owner(k), s, "owner({k}) for {clients}/{shards}");
                    }
                    seen = r.end;
                }
                assert_eq!(seen, clients);
                assert!(hi_sz - lo_sz <= 1, "unbalanced: {lo_sz}..{hi_sz}");
            }
        }
    }

    #[test]
    fn shard_plan_split_covers_every_participant() {
        let plan = ShardPlan::new(10, 3); // ranges 0..4, 4..7, 7..10
        let parts = [0usize, 2, 3, 5, 6, 7, 9];
        let groups = plan.split(&parts);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0], &[0, 2, 3]);
        assert_eq!(groups[1], &[5, 6]);
        assert_eq!(groups[2], &[7, 9]);
        // a shard with no selected clients yields an empty slice
        let groups = plan.split(&[0, 1, 8]);
        assert_eq!(groups[1], &[] as &[usize]);
        // full participation splits into the exact ranges
        let all: Vec<usize> = (0..10).collect();
        let groups = plan.split(&all);
        for s in 0..3 {
            let want: Vec<usize> = plan.range(s).collect();
            assert_eq!(groups[s], &want[..]);
        }
    }

    #[test]
    fn shard_misses_aggregate_per_shard() {
        let plan = ShardPlan::new(6, 2); // 0..3, 3..6
        let mut h = RoundHistory::new(6);
        h.misses = vec![1, 0, 2, 0, 5, 1];
        assert_eq!(h.shard_misses(&plan), vec![3, 6]);
    }

    #[test]
    fn straggler_aware_deprioritizes_repeat_missers() {
        let seeds = SeedTree::new(3);
        let clean = RoundHistory::new(8);
        let mut dirty = RoundHistory::new(8);
        dirty.misses[2] = 9; // chronic straggler: weight 1/10
        let mut policy = StragglerAware;
        let (mut with2_clean, mut with2_dirty) = (0usize, 0usize);
        for round in 0..200 {
            if policy.select(round, 8, 0.5, &seeds, &clean).participants.contains(&2) {
                with2_clean += 1;
            }
            if policy.select(round, 8, 0.5, &seeds, &dirty).participants.contains(&2) {
                with2_dirty += 1;
            }
        }
        // Expected ≈ 100 clean vs ≈ 15 dirty selections over 200 rounds.
        assert!(
            with2_dirty * 2 < with2_clean,
            "straggler not deprioritized: {with2_dirty} vs {with2_clean}"
        );
        // ... but never permanently excluded: weights stay positive.
        assert!(with2_dirty > 0, "straggler must keep a rejoin chance");
    }

    #[test]
    fn history_decays_on_receipt_and_grows_on_drop() {
        let mut h = RoundHistory::new(3);
        let drop_round = RoundTraffic {
            contributions: vec![],
            dropped: vec![1],
            ..Default::default()
        };
        for _ in 0..4 {
            h.note_round(&drop_round);
        }
        assert_eq!(h.miss_count(1), 4);
        let ok_round = RoundTraffic {
            contributions: vec![Contribution {
                client: 1,
                loss: 0.0,
                up_bits: 0,
                packed_mask: vec![],
            }],
            ..Default::default()
        };
        h.note_round(&ok_round);
        assert_eq!(h.miss_count(1), 2, "receipt halves the penalty");
        h.note_round(&ok_round);
        h.note_round(&ok_round);
        assert_eq!(h.miss_count(1), 0);
        // out-of-range ids are ignored, never panic
        h.note_round(&RoundTraffic {
            contributions: vec![],
            dropped: vec![99],
            ..Default::default()
        });
    }

    #[test]
    fn deadline_policy_from_cfg() {
        let mut cfg = FedConfig::paper(8);
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert!(d.timeout.is_none() && d.cap.is_none(), "defaults wait forever");
        cfg.round_timeout_ms = 100;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.timeout, Some(Duration::from_millis(100)));
        assert!(d.cap.is_none());
        // cap is clamped to at least the base deadline
        cfg.round_timeout_max_ms = 50;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.cap, Some(Duration::from_millis(100)));
        cfg.round_timeout_max_ms = 5_000;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert_eq!(d.cap, Some(Duration::from_millis(5_000)));
        // a cap without a base deadline is meaningless: stays unbounded
        cfg.round_timeout_ms = 0;
        let d = DeadlinePolicy::from_cfg(&cfg);
        assert!(d.timeout.is_none() && d.cap.is_none());
    }
}
