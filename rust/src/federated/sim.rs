//! In-process federated simulator — the driver behind §3.2 / Fig. 4 /
//! Table 1.
//!
//! Clients run sequentially in one thread (PJRT executors are not `Send`)
//! but every message still round-trips through the wire encoder, so the
//! ledger's byte counts are the real protocol costs, bit-for-bit equal to
//! what the TCP transport ships.

use std::sync::Arc;

use crate::comm::{CommLedger, RoundCost};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::one_hot_into;
use crate::rng::SeedTree;
use crate::sparse::QMatrix;
use crate::zampling::{evaluate, DenseExecutor, LocalZampling, ProbVector};

use super::protocol::{
    decode_client, decode_server, encode_client, encode_server, ClientMsg, MaskCodec, ServerMsg,
};
use super::{pack_client_mask, Server};

/// Result of a federated run.
pub struct FedOutcome {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub final_probs: Vec<f32>,
}

/// Run Federated Zampling per the config.
///
/// * `exec` — the dense executor shared by all (simulated) clients.
/// * `shards` — per-client training shards (from `Dataset::partition_iid`).
/// * `test` — held-out split for the per-round evaluation.
/// * `eval_samples` — masks per mean-sampled-accuracy estimate (§3.2: 100).
/// * `eval_every` — evaluate every `eval_every` rounds (1 = paper).
pub fn run_federated(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let codec = if cfg.entropy_code_uplink { MaskCodec::Arithmetic } else { MaskCodec::Raw };

    // Shared-seed initialization: every party derives the same Q; the
    // server owns p(0) ~ U(0,1)^n from the shared stream.
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let mut init_rng = seeds.rng("p-init", 0);
    let mut server = Server::new(ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec());

    // Client states: local (Q, p) + a per-client seed subtree.
    let mut clients: Vec<LocalZampling> = (0..cfg.clients)
        .map(|k| {
            let sub = seeds.subtree("client", k as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(server.probs.clone()),
                &sub,
            )
        })
        .collect();

    // Staged test split for evaluation.
    let out_dim = exec.arch().output_dim();
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);
    let mut eval_rng = seeds.rng("eval-sampler", 0);

    let mut log = RunLog::new("federated");
    let mut ledger = CommLedger::default();

    for round in 0..cfg.rounds {
        let mut up_bits = 0u64;
        let mut down_bits = 0u64;
        let mut round_loss = 0.0f64;

        // 1. Broadcast p(t) — one encoded frame per client.
        let round_msg =
            encode_server(&ServerMsg::Round { round: round as u32, probs: server.probs.clone() });
        for (k, client) in clients.iter_mut().enumerate() {
            let msg = decode_server(&round_msg).expect("round frame");
            let ServerMsg::Round { probs, .. } = msg else { unreachable!() };
            down_bits += round_msg.len() as u64 * 8;

            // 2. Client local training-by-sampling.
            client.pv.set_probs(&probs);
            client.reset_optimizer(&cfg.train);
            let mut loss = 0.0;
            for _ in 0..cfg.local_epochs {
                loss = client.run_epoch(exec, &shards[k], cfg.train.batch);
            }
            round_loss += loss;

            // 3. Sample z_new ~ Bern(f(s)) and uplink the mask.
            let mut mask_rng = seeds.subtree("client", k as u64).rng("uplink-mask", round as u64);
            let mut mask = Vec::new();
            client.pv.sample_mask(&mut mask_rng, &mut mask);
            let frame = encode_client(
                &ClientMsg::Mask { round: round as u32, client: k as u32, n: mask.len(), mask },
                codec,
            );
            up_bits += frame.len() as u64 * 8;
            let ClientMsg::Mask { mask, .. } = decode_client(&frame).expect("mask frame") else {
                unreachable!()
            };
            server.receive_mask(&pack_client_mask(&mask));
        }

        // 4. Aggregate: p(t+1) = mean of masks.
        server.aggregate();
        ledger.record(RoundCost {
            uplink_bits: up_bits,
            downlink_bits: down_bits,
            clients: cfg.clients as u32,
        });

        // Evaluation on the server's new p.
        if round % eval_every == 0 || round + 1 == cfg.rounds {
            let pv = ProbVector::from_probs(server.probs.clone());
            let rep = evaluate(
                exec,
                &q,
                &pv,
                &test.x,
                &test_y1h,
                test.len(),
                eval_samples,
                &mut eval_rng,
            );
            log.push(RoundRecord {
                round,
                mean_sampled_acc: rep.mean_sampled_acc,
                sampled_acc_std: rep.sampled_acc_std,
                expected_acc: rep.expected_acc,
                train_loss: round_loss / cfg.clients as f64,
                uplink_bits: up_bits,
                downlink_bits: down_bits,
            });
        }
    }

    FedOutcome { log, ledger, final_probs: server.probs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    fn tiny_fed(entropy: bool) -> (FedConfig, Vec<Dataset>, Dataset) {
        let mut cfg = FedConfig::paper(8);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params() / 8;
        cfg.train.d = 5;
        cfg.train.lr = 0.1;
        cfg.train.seed = 1;
        cfg.clients = 4;
        cfg.rounds = 6;
        cfg.local_epochs = 1;
        cfg.entropy_code_uplink = entropy;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, test) = Dataset::synthetic_pair(1024, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        (cfg, shards, test)
    }

    #[test]
    fn federated_training_learns_and_accounts_comm() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 8, 1);
        let first = out.log.rounds.first().unwrap().mean_sampled_acc;
        let last = out.log.rounds.last().unwrap().mean_sampled_acc;
        assert!(last > first, "accuracy did not improve: {first} → {last}");
        assert!(last > 0.3, "final acc {last}");

        // Ledger: downlink is 32n-ish bits + framing; uplink ~ n bits.
        let rep = out.ledger.savings(cfg.train.arch.num_params());
        // client savings should approach 32·(m/n) = 256 (modulo framing)
        assert!(rep.client_savings > 200.0, "client savings {rep:?}");
        assert!(rep.server_savings > 6.0, "server savings {rep:?}");
        assert_eq!(out.final_probs.len(), cfg.train.n);
    }

    #[test]
    fn entropy_coded_uplink_beats_raw_bits_late_in_training() {
        let (cfg, shards, test) = tiny_fed(true);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 4, 3);
        // After aggregation p concentrates; the arithmetic coder should
        // drop below 1 bit/entry at least by the last round.
        let last = out.ledger.rounds.last().unwrap();
        let bits_per_entry =
            last.uplink_bits as f64 / (cfg.clients as f64 * cfg.train.n as f64);
        assert!(bits_per_entry < 1.2, "bits/entry {bits_per_entry}");
    }

    #[test]
    fn federated_run_is_deterministic() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 4, 2);
        assert_eq!(a.final_probs, b.final_probs);
    }

    #[test]
    #[should_panic(expected = "one shard per client")]
    fn shard_count_mismatch_panics() {
        let (cfg, mut shards, test) = tiny_fed(false);
        shards.pop();
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        run_federated(&cfg, &mut exec, &shards, &test, 2, 1);
    }
}
