//! In-process federated transports — the drivers behind §3.2 / Fig. 4 /
//! Table 1 — plus the shared per-client round body.
//!
//! Since the `RoundEngine` redesign the round state machine (plan →
//! broadcast → collect → renormalized aggregate → ledger → eval) lives
//! once in [`engine`](super::engine); this module only supplies the two
//! in-process [`Transport`] implementations and the thin constructors
//! that preserve the historical driver API:
//!
//! * [`InProcessTransport`] / [`run_federated`] — clients run
//!   sequentially through one shared executor.  Works with any backend,
//!   including PJRT executors, whose handles are not `Send`.
//! * [`PoolTransport`] / [`run_federated_parallel`] — clients shard
//!   across the process pool (`runtime::pool`), one `Native` executor
//!   per worker lane.  Per-client seed streams, the k-ordered f64 loss
//!   reduction, and the k-ordered mask aggregation are all preserved, so
//!   the result is **byte-identical to the sequential run** (asserted by
//!   the tests here); only the wall-clock changes.
//! * [`ShardedSimTransport`] / [`run_federated_sharded`] — the
//!   in-process twin of the multi-leader
//!   [`ShardedTransport`](super::transport::ShardedTransport): clients
//!   are grouped by a `ShardPlan`, each shard folds its masks into a
//!   partial vote sum shipped through a real encoded `ShardVotes`
//!   frame, and the root merges the frames before renormalizing.
//!   Byte-identical to [`InProcessTransport`] for any shard count at
//!   any participation (asserted here), with a whole-shard failure knob
//!   for the dropout experiment.
//!
//! Both drive the *same* per-client round body ([`client_round`]) as the
//! TCP worker (`repro serve-client`), so every transport trains the same
//! numbers.  Every message round-trips through the wire encoder, so the
//! ledger's byte counts are the real protocol costs, bit-for-bit equal
//! to what the TCP transport ships.

use std::sync::{Arc, Mutex};

use crate::comm::{unpack_bits, ShardCost};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::rng::SeedTree;
use crate::runtime::pool;
use crate::sparse::QMatrix;
use crate::util::error::Result;
use crate::zampling::{DenseExecutor, LocalZampling, NativeExecutor, ProbVector};
use crate::{bail, ensure};

use super::engine::{
    make_policy, Contribution, FedOutcome, ParticipationPolicy, RoundCtx, RoundEngine,
    RoundTraffic, ShardPlan, Transport,
};
use super::protocol::{
    decode_client, decode_server, encode_client, encode_shard, ClientMsg, MaskCodec, ServerMsg,
    ShardMsg,
};
use super::{pack_client_mask, Server};

/// What one client contributes to a round (reduced in client order by
/// every driver so f64 summation order never changes).
pub struct ClientRound {
    /// The round the contribution belongs to.
    pub round: u32,
    /// Final local training loss.
    pub loss: f64,
    /// Broadcast bits this client consumed.
    pub down_bits: u64,
    /// Encoded uplink bits the mask frame cost.
    pub up_bits: u64,
    /// The sampled mask, bit-packed for aggregation.
    pub packed_mask: Vec<u64>,
    /// The encoded uplink `Mask` frame — exactly the bytes the TCP
    /// worker ships; the simulator counts the same frame so the ledgers
    /// agree bit-for-bit.
    pub frame: Vec<u8>,
}

/// Shared per-client round body: decode the broadcast, local
/// training-by-sampling, sample and encode the uplink mask.  Driven by
/// the in-process transports *and* the TCP worker (`repro serve-client`),
/// which is what keeps all transports numerically identical.
///
/// `heartbeat`, when provided, is invoked between local epochs — the TCP
/// worker uses it to prove liveness during long local training so the
/// leader can extend the round deadline instead of dropping a slow but
/// alive client.
///
/// Errors (rather than panicking) on malformed `round_msg` bytes — the
/// TCP worker feeds it frames straight off the wire.
#[allow(clippy::too_many_arguments)]
pub fn client_round(
    cfg: &FedConfig,
    client: &mut LocalZampling,
    exec: &mut dyn DenseExecutor,
    shard: &Dataset,
    seeds: &SeedTree,
    round_msg: &[u8],
    codec: MaskCodec,
    k: usize,
    mut heartbeat: Option<&mut dyn FnMut()>,
) -> Result<ClientRound> {
    // 1. Receive p(t) — every client decodes its own frame copy.
    let ServerMsg::Round { round, probs } = decode_server(round_msg)? else {
        bail!("client {k}: expected a Round frame");
    };
    ensure!(
        probs.len() == cfg.train.n,
        "client {k}: round {round} ships {} probs, model has n = {}",
        probs.len(),
        cfg.train.n
    );
    let down_bits = round_msg.len() as u64 * 8;

    // 2. Client local training-by-sampling.  The batch sampler is
    // reseeded from `(seed, client, round)` so a client's round output
    // is a pure function of the broadcast it received: a worker that
    // crashed and reconnected — or a resumed leader replaying an
    // in-flight round from a checkpoint — recomputes exactly the same
    // masks as the uninterrupted run.
    client.pv.set_probs(&probs);
    client.reset_optimizer(&cfg.train);
    client.reseed_sampler(seeds.subtree("client", k as u64).rng("train-sampler", round as u64));
    let mut loss = 0.0;
    for epoch in 0..cfg.local_epochs {
        loss = client.run_epoch(exec, shard, cfg.train.batch);
        if epoch + 1 < cfg.local_epochs {
            if let Some(beat) = heartbeat.as_mut() {
                beat();
            }
        }
    }

    // 3. Sample z_new ~ Bern(f(s)) and uplink the mask.
    let mut mask_rng = seeds.subtree("client", k as u64).rng("uplink-mask", round as u64);
    let mut mask = Vec::new();
    client.pv.sample_mask(&mut mask_rng, &mut mask);
    let frame = encode_client(
        &ClientMsg::Mask { round, client: k as u32, n: mask.len(), mask },
        codec,
    );
    let up_bits = frame.len() as u64 * 8;
    let ClientMsg::Mask { mask, .. } = decode_client(&frame)? else {
        bail!("client {k}: own mask frame decoded to a non-Mask message");
    };
    Ok(ClientRound { round, loss, down_bits, up_bits, packed_mask: pack_client_mask(&mask), frame })
}

/// Shared-seed setup: `Q`, the server's `p(0)`, and the client states.
pub(super) struct FedSetup {
    pub q: Arc<QMatrix>,
    pub init_probs: Vec<f32>,
    pub clients: Vec<LocalZampling>,
}

/// `population` is how many client states to build — `cfg.clients` for
/// the classical fixed-roster drivers, `cfg.max_clients` for elastic
/// runs that must be ready to admit late joiners.
pub(super) fn init_clients(cfg: &FedConfig, seeds: &SeedTree, population: usize) -> FedSetup {
    // Shared-seed initialization: every party derives the same Q; the
    // server owns p(0) ~ U(0,1)^n from the shared stream.
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, seeds));
    let csc = Arc::new(q.to_csc(None));
    let mut init_rng = seeds.rng("p-init", 0);
    let init_probs = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();

    // Client states: local (Q, p) + a per-client seed subtree.
    let clients: Vec<LocalZampling> = (0..population)
        .map(|k| {
            let sub = seeds.subtree("client", k as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(init_probs.clone()),
                &sub,
            )
        })
        .collect();
    FedSetup { q, init_probs, clients }
}

fn codec_for(cfg: &FedConfig) -> MaskCodec {
    if cfg.entropy_code_uplink {
        MaskCodec::Arithmetic
    } else {
        MaskCodec::Raw
    }
}

/// Sequential in-process transport: every participant runs
/// [`client_round`] through one shared executor, in client order.
pub struct InProcessTransport<'a> {
    cfg: &'a FedConfig,
    exec: &'a mut dyn DenseExecutor,
    shards: &'a [Dataset],
    clients: Vec<LocalZampling>,
    seeds: SeedTree,
    codec: MaskCodec,
    /// Scheduled `(round, client)` joins — the in-process twin of a late
    /// `Hello` from an unknown client id on the TCP leader.  The engine
    /// polls at every round boundary and admits whichever scheduled ids
    /// have arrived (see [`Transport::poll_joins`]).
    joins: Vec<(u32, usize)>,
}

impl<'a> InProcessTransport<'a> {
    /// Build over a shared executor, per-client data shards, and
    /// per-client training states (see `init_clients`).  `shards` may
    /// cover more clients than the starting roster (`cfg.clients`) when
    /// a join schedule will grow the population mid-run.
    pub fn new(
        cfg: &'a FedConfig,
        exec: &'a mut dyn DenseExecutor,
        shards: &'a [Dataset],
        clients: Vec<LocalZampling>,
    ) -> Self {
        assert!(
            shards.len() >= cfg.clients,
            "need at least one shard per starting client ({} < {})",
            shards.len(),
            cfg.clients
        );
        assert_eq!(clients.len(), shards.len(), "need one state per shard");
        let seeds = SeedTree::new(cfg.train.seed);
        let codec = codec_for(cfg);
        Self { cfg, exec, shards, clients, seeds, codec, joins: Vec::new() }
    }

    /// Schedule `(round, client)` joins: from `round` on, `client`
    /// announces itself and is admitted at the next boundary — the sim
    /// twin of a late worker dialing the leader mid-run.
    pub fn with_join_schedule(mut self, joins: &[(u32, usize)]) -> Self {
        for &(_, k) in joins {
            assert!(k < self.shards.len(), "scheduled join for client {k} without a shard");
        }
        self.joins = joins.to_vec();
        self
    }
}

impl Transport for InProcessTransport<'_> {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut down_bits = 0u64;
        for &k in ctx.participants {
            let out = client_round(
                self.cfg,
                &mut self.clients[k],
                &mut *self.exec,
                &self.shards[k],
                &self.seeds,
                ctx.frame,
                self.codec,
                k,
                None,
            )?;
            down_bits += out.down_bits;
            contributions.push(Contribution {
                client: k,
                loss: out.loss,
                up_bits: out.up_bits,
                packed_mask: out.packed_mask,
            });
        }
        Ok(RoundTraffic { contributions, down_bits, ..Default::default() })
    }

    fn poll_joins(&mut self, round: u32, population: usize) -> Vec<usize> {
        let mut joined: Vec<usize> = self
            .joins
            .iter()
            .filter(|&&(r, k)| r <= round && k >= population)
            .map(|&(_, k)| k)
            .collect();
        joined.sort_unstable();
        joined.dedup();
        joined
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut *self.exec
    }
}

/// Pool-parallel in-process transport: the round's participants shard
/// across the persistent worker pool, one [`NativeExecutor`] per lane
/// (built once, reused across rounds); results are collected in
/// participant order afterwards, so losses, ledgers, and `final_probs`
/// are byte-identical to [`InProcessTransport`].  PJRT executors are not
/// `Send` — use the sequential transport for those.
pub struct PoolTransport<'a> {
    cfg: &'a FedConfig,
    shards: &'a [Dataset],
    clients: Vec<LocalZampling>,
    seeds: SeedTree,
    codec: MaskCodec,
    nt_max: usize,
    /// One training executor per lane.  The mutexes are uncontended —
    /// lane `l` only ever touches `lane_execs[l]` (lanes never evaluate,
    /// so eval scratch is minimal).
    lane_execs: Vec<Mutex<NativeExecutor>>,
    /// Dedicated per-round evaluation executor, sized by `eval_batch`.
    eval_exec: NativeExecutor,
}

impl<'a> PoolTransport<'a> {
    /// Build over per-client data shards and states; `eval_batch` sizes
    /// the dedicated evaluation executor.
    pub fn new(
        cfg: &'a FedConfig,
        shards: &'a [Dataset],
        clients: Vec<LocalZampling>,
        eval_batch: usize,
    ) -> Self {
        assert_eq!(shards.len(), cfg.clients, "need one shard per client");
        assert_eq!(clients.len(), cfg.clients, "need one state per client");
        let nt_max = pool::global().parallelism().min(cfg.clients).max(1);
        let lane_execs: Vec<Mutex<NativeExecutor>> = (0..nt_max)
            .map(|_| Mutex::new(NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 1)))
            .collect();
        let eval_exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, eval_batch);
        Self {
            cfg,
            shards,
            clients,
            seeds: SeedTree::new(cfg.train.seed),
            codec: codec_for(cfg),
            nt_max,
            lane_execs,
            eval_exec,
        }
    }
}

impl Transport for PoolTransport<'_> {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        // Shard the round's participants across the pool.  Each client is
        // visited by exactly one lane, so the per-client mutexes are
        // uncontended — they only convert `&mut` access into something a
        // shared `Fn` closure may hold.
        let parts = ctx.participants;
        let p_total = parts.len();
        let nt = self.nt_max.min(p_total).max(1);
        let cfg = self.cfg;
        let (seeds, codec, shards) = (&self.seeds, self.codec, self.shards);
        let cells: Vec<Mutex<&mut LocalZampling>> =
            self.clients.iter_mut().map(Mutex::new).collect();
        let results: Vec<Mutex<Option<ClientRound>>> =
            (0..p_total).map(|_| Mutex::new(None)).collect();
        let lane_execs = &self.lane_execs;
        pool::global().run(nt, |lane| {
            let mut exec = lane_execs[lane].lock().unwrap();
            let mut i = lane;
            while i < p_total {
                let k = parts[i];
                let mut client = cells[k].lock().unwrap();
                let out = client_round(
                    cfg,
                    &mut client,
                    &mut *exec,
                    &shards[k],
                    seeds,
                    ctx.frame,
                    codec,
                    k,
                    None,
                )
                .expect("simulator frames are well-formed");
                *results[i].lock().unwrap() = Some(out);
                i += nt;
            }
        });

        // Collect in participant order (bit-identical to the sequential
        // transport, which visits the sorted participant list).
        let mut contributions = Vec::with_capacity(p_total);
        let mut down_bits = 0u64;
        for (i, cell) in results.iter().enumerate() {
            let out = cell.lock().unwrap().take().expect("client result missing");
            down_bits += out.down_bits;
            contributions.push(Contribution {
                client: parts[i],
                loss: out.loss,
                up_bits: out.up_bits,
                packed_mask: out.packed_mask,
            });
        }
        Ok(RoundTraffic { contributions, down_bits, ..Default::default() })
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut self.eval_exec
    }
}

/// In-process twin of the multi-leader
/// [`ShardedTransport`](super::transport::ShardedTransport), for fast
/// tests and the whole-shard-failure experiment: participants are
/// grouped by a [`ShardPlan`], each live shard runs its clients through
/// [`client_round`] in client order and folds their masks into a
/// partial vote sum, and the sums travel to the root as real encoded
/// `ShardVotes` frames — merged in `aggregate` exactly like the TCP
/// root does.  With no failed shards the result is **byte-identical to
/// [`InProcessTransport`]** for any shard count at any participation
/// (same `client_round` order, and `u32` vote sums merge exactly).
///
/// A failed shard simulates its leader being down from a given round
/// on: its participants never receive the broadcast (no downlink, no
/// local training, no uplink) and are reported dropped; no merge frame
/// arrives from it.  An outage starting at round 0 is the
/// whole-run-failure scenario of the dropout experiment; a later start
/// is the twin of a `serve-shard` process killed mid-run on a testnet
/// chaos schedule (`--fail-at-round` exits before broadcasting, so the
/// kill round itself already bills the subtree as failed).
pub struct ShardedSimTransport<'a> {
    cfg: &'a FedConfig,
    exec: &'a mut dyn DenseExecutor,
    data: &'a [Dataset],
    clients: Vec<LocalZampling>,
    seeds: SeedTree,
    codec: MaskCodec,
    plan: ShardPlan,
    /// `(shard, from_round)` outages: the shard is down for every round
    /// `>= from_round`.
    outages: Vec<(usize, u32)>,
    /// This round's encoded `ShardVotes` frames (empty vec = the shard
    /// is failed and no frame arrived).
    pending_votes: Vec<Vec<u8>>,
}

impl<'a> ShardedSimTransport<'a> {
    /// Build over `num_shards` simulated shard leaders.
    pub fn new(
        cfg: &'a FedConfig,
        exec: &'a mut dyn DenseExecutor,
        data: &'a [Dataset],
        clients: Vec<LocalZampling>,
        num_shards: usize,
    ) -> Self {
        assert_eq!(data.len(), cfg.clients, "need one shard per client");
        assert_eq!(clients.len(), cfg.clients, "need one state per client");
        let seeds = SeedTree::new(cfg.train.seed);
        let codec = codec_for(cfg);
        let plan = ShardPlan::new(cfg.clients, num_shards);
        Self {
            cfg,
            exec,
            data,
            clients,
            seeds,
            codec,
            plan,
            outages: Vec::new(),
            pending_votes: Vec::new(),
        }
    }

    /// Mark shard leaders as down for the whole run (the
    /// whole-shard-failure scenario of the dropout experiment).
    pub fn with_failed_shards(mut self, failed: &[usize]) -> Self {
        for &s in failed {
            self = self.with_shard_outage(s, 0);
        }
        self
    }

    /// Mark one shard leader as down from `from_round` on — the twin of
    /// a `serve-shard` process killed on a chaos schedule.
    pub fn with_shard_outage(mut self, shard: usize, from_round: u32) -> Self {
        assert!(shard < self.plan.shards(), "failed shard {shard} ≥ {}", self.plan.shards());
        self.outages.push((shard, from_round));
        self
    }

    /// The client-space partition this twin simulates.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }
}

impl Transport for ShardedSimTransport<'_> {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let groups = self.plan.split(ctx.participants);
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut dropped = Vec::new();
        let mut down_bits = 0u64;
        let mut shard_costs = Vec::with_capacity(groups.len());
        self.pending_votes.clear();
        for (sid, parts) in groups.iter().copied().enumerate() {
            if self.outages.iter().any(|&(s, from)| s == sid && ctx.round >= from) {
                // Whole-shard failure: the shard leader is down, so its
                // participants never see the broadcast and are dropped.
                dropped.extend_from_slice(parts);
                shard_costs.push(ShardCost {
                    shard: sid as u32,
                    dropped: parts.len() as u32,
                    ..Default::default()
                });
                self.pending_votes.push(Vec::new());
                continue;
            }
            let mut votes = vec![0u32; ctx.n];
            let (mut shard_up, mut shard_down) = (0u64, 0u64);
            for &k in parts {
                let out = client_round(
                    self.cfg,
                    &mut self.clients[k],
                    &mut *self.exec,
                    &self.data[k],
                    &self.seeds,
                    ctx.frame,
                    self.codec,
                    k,
                    None,
                )?;
                shard_down += out.down_bits;
                shard_up += out.up_bits;
                let mask = unpack_bits(&out.packed_mask, ctx.n);
                super::fold_mask_votes(&mut votes, &mask);
                contributions.push(Contribution {
                    client: k,
                    loss: out.loss,
                    up_bits: out.up_bits,
                    packed_mask: out.packed_mask,
                });
            }
            let votes_frame = encode_shard(&ShardMsg::ShardVotes {
                shard: sid as u32,
                round: ctx.round,
                received: parts.len() as u32,
                n: ctx.n,
                votes,
            });
            down_bits += shard_down;
            shard_costs.push(ShardCost {
                shard: sid as u32,
                uplink_bits: shard_up,
                downlink_bits: shard_down,
                merge_bits: votes_frame.len() as u64 * 8,
                received: parts.len() as u32,
                dropped: 0,
            });
            self.pending_votes.push(votes_frame);
        }
        Ok(RoundTraffic { contributions, dropped, down_bits, shard_costs, ..Default::default() })
    }

    /// Root-side merge over the encoded `ShardVotes` frames — literally
    /// the same body as the TCP root (`merge_vote_frames`), so the merge
    /// path the fast tests pin is the one production runs.
    fn aggregate(&mut self, server: &mut Server, _traffic: &RoundTraffic) -> usize {
        super::merge_vote_frames(server, &self.plan, &mut self.pending_votes)
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut *self.exec
    }
}

/// [`InProcessTransport`] with a deterministic per-round drop schedule —
/// the replay twin for wire runs whose drop pattern is timing-dependent
/// (a worker killed and *restarted* mid-run rejoins whenever its
/// reconnect lands, so the twin takes the drop schedule the real
/// leader's log reports and replays it exactly).
///
/// Semantics per scheduled drop `(round, client)`:
///
/// * the client neither trains nor uplinks that round (reported
///   dropped, aggregation renormalizes without it) — a worker killed by
///   `--fail-at-round` exits on receiving the round frame, before any
///   local work;
/// * its training state is replaced fresh (`LocalZampling::from_parts`
///   over the same seed subtree), because the process that eventually
///   rejoins starts from scratch — and since [`client_round`] reseeds
///   the train sampler from `(seed, client, round)` every round, a
///   fresh state at the rejoin round computes exactly what the
///   restarted `serve-client` process does.  Resetting at every
///   scheduled drop round is idempotent (the rebuild is deterministic),
///   so the transport need not know the rejoin round;
/// * downlink is billed only when the previous round did **not** drop
///   the client: the first drop of a streak is the kill round, whose
///   broadcast write succeeded before the worker died; on later rounds
///   the leader's sweeper has already reaped the dead connection, so no
///   broadcast is written.
pub struct ScheduledDropTransport<'a> {
    cfg: &'a FedConfig,
    exec: &'a mut dyn DenseExecutor,
    shards: &'a [Dataset],
    clients: Vec<LocalZampling>,
    seeds: SeedTree,
    codec: MaskCodec,
    q: Arc<QMatrix>,
    csc: Arc<crate::sparse::CscView>,
    /// `(round, client)` pairs, in any order.
    schedule: Vec<(u32, usize)>,
}

impl<'a> ScheduledDropTransport<'a> {
    /// Build over the same parts as [`InProcessTransport`], plus the
    /// `(round, client)` drop schedule to replay.
    pub fn new(
        cfg: &'a FedConfig,
        exec: &'a mut dyn DenseExecutor,
        shards: &'a [Dataset],
        clients: Vec<LocalZampling>,
        q: Arc<QMatrix>,
        schedule: &[(u32, usize)],
    ) -> Self {
        assert_eq!(shards.len(), cfg.clients, "need one shard per client");
        assert_eq!(clients.len(), cfg.clients, "need one state per client");
        for &(_, k) in schedule {
            assert!(k < cfg.clients, "scheduled drop for client {k} ≥ {}", cfg.clients);
        }
        let seeds = SeedTree::new(cfg.train.seed);
        let codec = codec_for(cfg);
        let csc = Arc::new(q.to_csc(None));
        Self { cfg, exec, shards, clients, seeds, codec, q, csc, schedule: schedule.to_vec() }
    }

    fn is_dropped(&self, round: u32, k: usize) -> bool {
        self.schedule.iter().any(|&(r, c)| r == round && c == k)
    }

    /// Fresh client state over the same seed subtree — what a restarted
    /// `serve-client` process builds before its first round.
    fn reset_client(&mut self, k: usize) {
        let sub = self.seeds.subtree("client", k as u64);
        self.clients[k] = LocalZampling::from_parts(
            &self.cfg.train,
            Arc::clone(&self.q),
            Arc::clone(&self.csc),
            ProbVector::from_probs(vec![0.5; self.cfg.train.n]),
            &sub,
        );
    }
}

impl Transport for ScheduledDropTransport<'_> {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut dropped = Vec::new();
        let mut down_bits = 0u64;
        for &k in ctx.participants {
            if self.is_dropped(ctx.round, k) {
                // Kill round: the broadcast write succeeded before the
                // worker died, so the first drop of a streak still bills
                // downlink; while the worker stays dead its reaped slot
                // receives nothing.
                if ctx.round == 0 || !self.is_dropped(ctx.round - 1, k) {
                    down_bits += ctx.frame.len() as u64 * 8;
                }
                self.reset_client(k);
                dropped.push(k);
                continue;
            }
            let out = client_round(
                self.cfg,
                &mut self.clients[k],
                &mut *self.exec,
                &self.shards[k],
                &self.seeds,
                ctx.frame,
                self.codec,
                k,
                None,
            )?;
            down_bits += out.down_bits;
            contributions.push(Contribution {
                client: k,
                loss: out.loss,
                up_bits: out.up_bits,
                packed_mask: out.packed_mask,
            });
        }
        Ok(RoundTraffic { contributions, dropped, down_bits, ..Default::default() })
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut *self.exec
    }
}

/// Run Federated Zampling per the config (sequential client loop) — a
/// thin constructor over [`RoundEngine`] + [`InProcessTransport`] with
/// the config's participation policy.
///
/// * `exec` — the dense executor shared by all (simulated) clients.
/// * `shards` — per-client training shards (from `Dataset::partition_iid`).
/// * `test` — held-out split for the per-round evaluation.
/// * `eval_samples` — masks per mean-sampled-accuracy estimate (§3.2: 100).
/// * `eval_every` — evaluate every `eval_every` rounds (1 = paper).
pub fn run_federated(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> FedOutcome {
    let mut policy = make_policy(cfg.policy);
    run_federated_custom(cfg, exec, shards, test, eval_samples, eval_every, policy.as_mut(), None)
}

/// [`run_federated`] with an explicit policy and optional chaos drop
/// rates (per-client deadline-miss probabilities injected by
/// [`Flaky`](super::engine::Flaky)) — the hook behind the dropout /
/// straggler experiments and the policy tests.
#[allow(clippy::too_many_arguments)]
pub fn run_federated_custom(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    policy: &mut dyn ParticipationPolicy,
    drop_rates: Option<&[f64]>,
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let transport = InProcessTransport::new(cfg, exec, shards, setup.clients);
    let out = match drop_rates {
        None => {
            let mut transport = transport;
            engine.run(&mut transport, policy)
        }
        Some(rates) => {
            let mut flaky = super::engine::Flaky::new(transport, seeds, rates.to_vec());
            engine.run(&mut flaky, policy)
        }
    };
    out.expect("in-process transports are infallible")
}

/// [`run_federated`] with the client loop sharded across the process
/// pool — the `Native`-backend fast path (PJRT executors are not `Send`;
/// use the sequential driver for those).  Byte-identical to the
/// sequential run; only the wall-clock changes.
pub fn run_federated_parallel(
    cfg: &FedConfig,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    eval_batch: usize,
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let mut transport = PoolTransport::new(cfg, shards, setup.clients, eval_batch);
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut()).expect("in-process transports are infallible")
}

/// [`run_federated`] through the in-process sharded twin
/// ([`ShardedSimTransport`]): the client space is partitioned across
/// `num_shards` simulated shard leaders whose partial vote sums merge
/// at the root.  With `failed_shards` empty this is byte-identical to
/// [`run_federated`]; naming shard ids there simulates those leaders
/// being down for the whole run (the whole-shard-failure scenario of
/// `repro experiment --id dropout`).
#[allow(clippy::too_many_arguments)]
pub fn run_federated_sharded(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    num_shards: usize,
    failed_shards: &[usize],
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let mut transport = ShardedSimTransport::new(cfg, exec, shards, setup.clients, num_shards)
        .with_failed_shards(failed_shards);
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut()).expect("in-process transports are infallible")
}

/// [`run_federated_sharded`] with `(shard, from_round)` outages instead
/// of whole-run failures — the in-process twin of a testnet run whose
/// chaos schedule kills `serve-shard` processes at given rounds.
#[allow(clippy::too_many_arguments)]
pub fn run_federated_sharded_outages(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    num_shards: usize,
    outages: &[(usize, u32)],
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let mut transport = ShardedSimTransport::new(cfg, exec, shards, setup.clients, num_shards);
    for &(s, from) in outages {
        transport = transport.with_shard_outage(s, from);
    }
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut()).expect("in-process transports are infallible")
}

/// [`run_federated`] with an elastic roster: the run starts with
/// `cfg.clients` participants and admits the scheduled `(round, client)`
/// joins at round boundaries, exactly like the TCP leader admits a late
/// `Hello` from an unknown client id — the sim twin that replays a wire
/// run's logged join rounds byte-for-byte.  `shards` must cover every
/// client that can ever exist (`cfg.max_clients`); joined ids age into
/// the straggler history like any other client and the round plan
/// rebalances from the next boundary on.
#[allow(clippy::too_many_arguments)]
pub fn run_federated_elastic(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    joins: &[(u32, usize)],
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.max_clients, "need one shard per potential client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.max_clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let mut transport =
        InProcessTransport::new(cfg, exec, shards, setup.clients).with_join_schedule(joins);
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut()).expect("in-process transports are infallible")
}

/// Resume an in-process run from a checkpoint — the sequential twin of
/// `repro resume`: the deterministic parts (Q, client states, data
/// shards) rebuild from the shared seed, the mutable run state (`p`,
/// eval RNG cursor, straggler history, run log, comm ledger) comes from
/// `ckpt`, and the remaining rounds replay byte-identical to a run that
/// was never stopped.  `shards` must cover the full id space
/// (`cfg.max_clients`); errors if the checkpoint disagrees with `cfg`.
pub fn resume_federated(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    ckpt: super::Checkpoint,
) -> Result<FedOutcome> {
    ensure!(
        shards.len() == cfg.max_clients,
        "need one shard per potential client ({} != {})",
        shards.len(),
        cfg.max_clients
    );
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.max_clients);
    let engine = RoundEngine::resume(cfg, ckpt, Arc::clone(&setup.q), test)?;
    let mut transport = InProcessTransport::new(cfg, exec, shards, setup.clients);
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut())
}

/// [`run_federated`] through [`ScheduledDropTransport`]: replay an
/// observed `(round, client)` drop schedule deterministically — the
/// twin for kill-and-restart-a-worker testnet scenarios, whose rejoin
/// round depends on reconnect timing and is therefore taken from the
/// real leader's log rather than predicted.
pub fn run_federated_with_drop_schedule(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    schedule: &[(u32, usize)],
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let setup = init_clients(cfg, &seeds, cfg.clients);
    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&setup.q),
        setup.init_probs.clone(),
        test,
        eval_samples,
        eval_every,
        "federated",
    );
    let q = Arc::clone(&setup.q);
    let mut transport =
        ScheduledDropTransport::new(cfg, exec, shards, setup.clients, q, schedule);
    let mut policy = make_policy(cfg.policy);
    engine.run(&mut transport, policy.as_mut()).expect("in-process transports are infallible")
}

#[cfg(test)]
mod tests {
    use super::super::engine::{StragglerAware, Uniform};
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    fn tiny_fed(entropy: bool) -> (FedConfig, Vec<Dataset>, Dataset) {
        let mut cfg = FedConfig::paper(8);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params() / 8;
        cfg.train.d = 5;
        cfg.train.lr = 0.1;
        cfg.train.seed = 1;
        cfg.clients = 4;
        cfg.rounds = 6;
        cfg.local_epochs = 1;
        cfg.entropy_code_uplink = entropy;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, test) = Dataset::synthetic_pair(1024, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        (cfg, shards, test)
    }

    #[test]
    fn federated_training_learns_and_accounts_comm() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 8, 1);
        let first = out.log.rounds.first().unwrap().mean_sampled_acc;
        let last = out.log.rounds.last().unwrap().mean_sampled_acc;
        assert!(last > first, "accuracy did not improve: {first} → {last}");
        assert!(last > 0.3, "final acc {last}");

        // Ledger: downlink is 32n-ish bits + framing; uplink ~ n bits.
        let rep = out.ledger.savings(cfg.train.arch.num_params());
        // client savings should approach 32·(m/n) = 256 (modulo framing)
        assert!(rep.client_savings > 200.0, "client savings {rep:?}");
        assert!(rep.server_savings > 6.0, "server savings {rep:?}");
        assert_eq!(out.final_probs.len(), cfg.train.n);
        // full participation, no dropouts: every row says so
        for r in &out.ledger.rounds {
            assert_eq!(r.participants, cfg.clients as u32);
            assert_eq!(r.clients, cfg.clients as u32);
            assert_eq!(r.dropped, 0);
        }
    }

    #[test]
    fn entropy_coded_uplink_beats_raw_bits_late_in_training() {
        let (cfg, shards, test) = tiny_fed(true);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 4, 3);
        // After aggregation p concentrates; the arithmetic coder should
        // drop below 1 bit/entry at least by the last round.
        let last = out.ledger.rounds.last().unwrap();
        let bits_per_entry =
            last.uplink_bits as f64 / (cfg.clients as f64 * cfg.train.n as f64);
        assert!(bits_per_entry < 1.2, "bits/entry {bits_per_entry}");
    }

    #[test]
    fn federated_run_is_deterministic() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 4, 2);
        assert_eq!(a.final_probs, b.final_probs);
    }

    #[test]
    fn parallel_run_matches_sequential_byte_for_byte() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let seq = run_federated(&cfg, &mut exec, &shards, &test, 4, 2);
        let par = run_federated_parallel(&cfg, &shards, &test, 4, 2, 256);
        assert_eq!(seq.final_probs, par.final_probs);
        assert_eq!(seq.log.rounds.len(), par.log.rounds.len());
        for (a, b) in seq.log.rounds.iter().zip(&par.log.rounds) {
            assert_eq!(a.mean_sampled_acc, b.mean_sampled_acc, "round {}", a.round);
            assert_eq!(a.train_loss, b.train_loss, "round {}", a.round);
            assert_eq!(a.uplink_bits, b.uplink_bits, "round {}", a.round);
            assert_eq!(a.downlink_bits, b.downlink_bits, "round {}", a.round);
        }
        let (sa, sb) = (&seq.ledger.rounds, &par.ledger.rounds);
        assert_eq!(sa.len(), sb.len());
        for (a, b) in sa.iter().zip(sb) {
            assert_eq!(a.uplink_bits, b.uplink_bits);
            assert_eq!(a.downlink_bits, b.downlink_bits);
            assert_eq!(a.participants, b.participants);
        }
    }

    #[test]
    fn partial_participation_renormalizes_and_stays_deterministic() {
        let (mut cfg, shards, test) = tiny_fed(false);
        cfg.participation = 0.5;
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 4, 2);
        assert_eq!(a.final_probs, b.final_probs, "partial participation must be seeded");
        for r in &a.ledger.rounds {
            assert_eq!(r.participants, 2, "0.5 of 4 clients");
            assert_eq!(r.clients, 2);
            assert_eq!(r.dropped, 0);
        }
        // renormalized mean stays a probability
        assert!(a.final_probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
        // and the parallel driver agrees byte-for-byte on the subset runs
        let par = run_federated_parallel(&cfg, &shards, &test, 4, 2, 256);
        assert_eq!(a.final_probs, par.final_probs);
    }

    #[test]
    fn partial_participation_costs_proportionally_less_uplink() {
        let (mut cfg, shards, test) = tiny_fed(false);
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let full = run_federated(&cfg, &mut e1, &shards, &test, 2, 3);
        cfg.participation = 0.5;
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let half = run_federated(&cfg, &mut e2, &shards, &test, 2, 3);
        // raw-codec mask frames have fixed size → exactly half the uplink
        assert_eq!(half.ledger.total_uplink_bits() * 2, full.ledger.total_uplink_bits());
    }

    #[test]
    fn straggler_policy_is_selectable_via_config() {
        let (mut cfg, shards, test) = tiny_fed(false);
        cfg.participation = 0.5;
        cfg.policy = crate::config::PolicyKind::StragglerAware;
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 2, 3);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 2, 3);
        assert_eq!(a.final_probs, b.final_probs, "straggler policy must be deterministic");
        // the straggler stream differs from the uniform one
        cfg.policy = crate::config::PolicyKind::Uniform;
        let mut e3 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let uni = run_federated(&cfg, &mut e3, &shards, &test, 2, 3);
        assert_ne!(a.final_probs, uni.final_probs, "policies drew identical subsets every round");
    }

    #[test]
    fn chaos_drops_feed_history_and_straggler_policy_avoids_the_flake() {
        let (mut cfg, shards, test) = tiny_fed(false);
        cfg.participation = 0.5;
        cfg.rounds = 24;
        // Client 0 always misses the deadline when selected, so total
        // drops == how many selections each policy wasted on it
        // (expected ≈ 12 uniform vs ≈ 3 straggler-aware over 24 rounds).
        let mut rates = vec![0.0; cfg.clients];
        rates[0] = 1.0;
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut uniform = Uniform;
        let uni = run_federated_custom(
            &cfg, &mut e1, &shards, &test, 2, 4, &mut uniform, Some(&rates),
        );
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut aware = StragglerAware;
        let strag = run_federated_custom(
            &cfg, &mut e2, &shards, &test, 2, 4, &mut aware, Some(&rates),
        );
        let uni_drops = uni.ledger.total_dropped();
        let str_drops = strag.ledger.total_dropped();
        assert!(uni_drops > 0, "chaos transport never dropped anyone");
        assert!(
            str_drops < uni_drops,
            "straggler-aware should waste fewer rounds: {str_drops} vs {uni_drops}"
        );
        // drops renormalize, never corrupt: probabilities stay probabilities
        assert!(uni.final_probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
        for r in &uni.ledger.rounds {
            assert_eq!(r.clients + r.dropped, r.participants, "{r:?}");
        }
    }

    #[test]
    fn sharded_sim_matches_sequential_byte_for_byte_at_any_shard_count() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let seq = run_federated(&cfg, &mut exec, &shards, &test, 4, 2);
        for s in [1usize, 2, 3, 4] {
            let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
            let sharded = run_federated_sharded(&cfg, &mut exec, &shards, &test, 4, 2, s, &[]);
            assert_eq!(seq.final_probs, sharded.final_probs, "S={s} diverged");
            assert_eq!(seq.ledger.rounds.len(), sharded.ledger.rounds.len());
            for (a, b) in seq.ledger.rounds.iter().zip(&sharded.ledger.rounds) {
                assert_eq!(a.uplink_bits, b.uplink_bits, "S={s}");
                assert_eq!(a.downlink_bits, b.downlink_bits, "S={s}");
                assert_eq!(a.participants, b.participants, "S={s}");
                assert_eq!(a.clients, b.clients, "S={s}");
                assert_eq!(a.dropped, 0, "S={s}");
            }
            for (a, b) in seq.log.rounds.iter().zip(&sharded.log.rounds) {
                assert_eq!(a.mean_sampled_acc, b.mean_sampled_acc, "S={s} round {}", a.round);
                assert_eq!(a.train_loss, b.train_loss, "S={s} round {}", a.round);
            }
            // and the shard table reconciles with the round totals
            assert_eq!(sharded.ledger.shard_rounds.len(), sharded.ledger.rounds.len());
            for (round, per_shard) in
                sharded.ledger.rounds.iter().zip(&sharded.ledger.shard_rounds)
            {
                assert_eq!(per_shard.len(), s);
                let up: u64 = per_shard.iter().map(|c| c.uplink_bits).sum();
                let down: u64 = per_shard.iter().map(|c| c.downlink_bits).sum();
                assert_eq!(up, round.uplink_bits, "S={s}");
                assert_eq!(down, round.downlink_bits, "S={s}");
                assert!(per_shard.iter().all(|c| c.merge_bits > 0), "S={s}");
            }
        }
    }

    #[test]
    fn sharded_sim_matches_sequential_under_partial_participation() {
        let (mut cfg, shards, test) = tiny_fed(false);
        cfg.participation = 0.5;
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let seq = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let sharded = run_federated_sharded(&cfg, &mut e2, &shards, &test, 4, 2, 2, &[]);
        assert_eq!(seq.final_probs, sharded.final_probs);
    }

    #[test]
    fn whole_shard_failure_drops_exactly_that_shard_and_renormalizes() {
        let (cfg, shards, test) = tiny_fed(false);
        // 4 clients, 2 shards: shard 1 = clients {2, 3}, down all run.
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated_sharded(&cfg, &mut exec, &shards, &test, 4, 2, 2, &[1]);
        for r in &out.ledger.rounds {
            assert_eq!(r.participants, 4);
            assert_eq!(r.clients, 2, "only the surviving shard aggregates");
            assert_eq!(r.dropped, 2, "both shard-1 clients drop every round");
        }
        for per_shard in &out.ledger.shard_rounds {
            assert_eq!(per_shard[0].received, 2);
            assert_eq!(per_shard[0].dropped, 0);
            assert!(per_shard[0].merge_bits > 0);
            assert_eq!(per_shard[1].received, 0);
            assert_eq!(per_shard[1].dropped, 2);
            assert_eq!(per_shard[1].merge_bits, 0, "a dead shard ships no merge frame");
            assert_eq!(per_shard[1].uplink_bits, 0);
            assert_eq!(per_shard[1].downlink_bits, 0);
        }
        // renormalization keeps p a probability vector and the run alive
        assert!(out.final_probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
        // the survivors' aggregation must equal a run over shard 0 alone:
        // same seeds, same client_round order, renormalized by 2 — which
        // is exactly what the merge property test pins at the Server
        // level; here we sanity-check the uplink is half the healthy run.
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let healthy = run_federated_sharded(&cfg, &mut e2, &shards, &test, 4, 2, 2, &[]);
        assert_eq!(
            out.ledger.total_uplink_bits() * 2,
            healthy.ledger.total_uplink_bits(),
            "raw mask frames are fixed-size, so half the clients = half the uplink"
        );
    }

    #[test]
    #[should_panic(expected = "one shard per client")]
    fn shard_count_mismatch_panics() {
        let (cfg, mut shards, test) = tiny_fed(false);
        shards.pop();
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        run_federated(&cfg, &mut exec, &shards, &test, 2, 1);
    }

    #[test]
    fn checkpoint_resume_is_byte_identical_to_uninterrupted() {
        let (cfg, shards, test) = tiny_fed(false);

        // Reference: the uninterrupted run.
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let full = run_federated(&cfg, &mut e1, &shards, &test, 4, 1);

        // Interrupted twin: checkpoint every 2 rounds, leader killed at
        // the start of round 4 — one full round after the last boundary
        // checkpoint, so the resume replays an in-flight round.
        let path =
            std::env::temp_dir().join(format!("zampling-sim-ckpt-{}.bin", std::process::id()));
        let seeds = SeedTree::new(cfg.train.seed);
        let setup = init_clients(&cfg, &seeds, cfg.clients);
        let engine = RoundEngine::new(
            &cfg,
            cfg.clients,
            Arc::clone(&setup.q),
            setup.init_probs.clone(),
            &test,
            4,
            1,
            "federated",
        )
        .checkpoint_to(2, Some(path.clone()))
        .fail_at_round(Some(4));
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut transport = InProcessTransport::new(&cfg, &mut e2, &shards, setup.clients);
        let mut policy = make_policy(cfg.policy);
        let killed = engine.run(&mut transport, policy.as_mut());
        assert!(killed.is_err(), "the chaos kill must surface as an error");
        drop(transport);

        // Resume from the checkpoint with freshly built state — exactly
        // what a restarted leader process does.
        let ckpt = super::super::checkpoint::Checkpoint::load(&path).unwrap();
        assert_eq!(ckpt.manifest.next_round, 4, "last boundary before the kill");
        let setup2 = init_clients(&cfg, &seeds, cfg.clients);
        let engine2 =
            RoundEngine::resume(&cfg, ckpt, Arc::clone(&setup2.q), &test).unwrap();
        let mut e3 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut transport2 = InProcessTransport::new(&cfg, &mut e3, &shards, setup2.clients);
        let mut policy2 = make_policy(cfg.policy);
        let resumed = engine2.run(&mut transport2, policy2.as_mut()).unwrap();
        let _ = std::fs::remove_file(&path);

        assert_eq!(resumed.final_probs, full.final_probs, "resume diverged from the clean run");
        assert_eq!(resumed.log.rounds, full.log.rounds);
        assert_eq!(resumed.ledger.to_csv(), full.ledger.to_csv());
    }

    #[test]
    fn resume_rejects_a_mismatched_config() {
        let (cfg, shards, test) = tiny_fed(false);
        let path =
            std::env::temp_dir().join(format!("zampling-sim-ckpt-bad-{}.bin", std::process::id()));
        let seeds = SeedTree::new(cfg.train.seed);
        let setup = init_clients(&cfg, &seeds, cfg.clients);
        let engine = RoundEngine::new(
            &cfg,
            cfg.clients,
            Arc::clone(&setup.q),
            setup.init_probs.clone(),
            &test,
            2,
            1,
            "federated",
        )
        .checkpoint_to(2, Some(path.clone()))
        .fail_at_round(Some(2));
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut transport = InProcessTransport::new(&cfg, &mut exec, &shards, setup.clients);
        let mut policy = make_policy(cfg.policy);
        assert!(engine.run(&mut transport, policy.as_mut()).is_err());
        drop(transport);

        let ckpt = super::super::checkpoint::Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let mut other = cfg.clone();
        other.train.seed += 1;
        let setup2 = init_clients(&other, &seeds, other.clients);
        let err = RoundEngine::resume(&other, ckpt, Arc::clone(&setup2.q), &test);
        assert!(err.is_err(), "a checkpoint from a different seed must be refused");
    }

    #[test]
    fn elastic_joins_grow_the_roster_at_round_boundaries() {
        let (mut cfg, _, test) = tiny_fed(false);
        cfg.clients = 3;
        cfg.max_clients = 4;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, _) = Dataset::synthetic_pair(1024, 256, &seeds);
        let shards = train.partition_iid(cfg.max_clients, &seeds);

        // Client 3 announces itself at round 2 and joins from there on.
        let joins = [(2u32, 3usize)];
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated_elastic(&cfg, &mut e1, &shards, &test, 4, 2, &joins);
        assert_eq!(a.ledger.rounds.len(), cfg.rounds);
        for (i, r) in a.ledger.rounds.iter().enumerate() {
            let want = if i < 2 { 3 } else { 4 };
            assert_eq!(r.participants, want, "round {i} roster");
            assert_eq!(r.clients, want, "round {i} receipts");
            assert_eq!(r.dropped, 0);
        }
        // Elastic admission is deterministic: the twin reproduces the
        // run byte-for-byte from the same join schedule.
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let b = run_federated_elastic(&cfg, &mut e2, &shards, &test, 4, 2, &joins);
        assert_eq!(a.final_probs, b.final_probs);
        assert_eq!(a.ledger.to_csv(), b.ledger.to_csv());
        // And with no joins the elastic driver degenerates to the fixed
        // roster (over the max_clients partition).
        let mut e3 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let fixed = run_federated_elastic(&cfg, &mut e3, &shards, &test, 4, 2, &[]);
        for r in &fixed.ledger.rounds {
            assert_eq!(r.participants, 3, "no joins: the roster never grows");
        }
        assert_ne!(a.final_probs, fixed.final_probs, "the joiner must change the aggregate");
    }
}
