//! In-process federated simulator — the driver behind §3.2 / Fig. 4 /
//! Table 1 — and the round-orchestration types shared with the TCP
//! transport.
//!
//! Round orchestration is split into plan/outcome so every driver agrees
//! on the semantics:
//!
//! * [`RoundPlan`] — which clients a round selects.  With
//!   `cfg.participation < 1.0` a per-round subset is drawn from the
//!   shared [`SeedTree`] (tag `"round-participants"`), so partial
//!   participation stays deterministic across runs and transports; at
//!   `participation = 1.0` no stream is consumed and the plan is every
//!   client, byte-identical to the pre-participation driver.
//! * [`RoundOutcome`] — what actually happened: masks received, clients
//!   dropped, traffic, loss.  The server renormalizes by the *received*
//!   count ([`Server::try_aggregate`]), so late or dead clients shrink
//!   the mean instead of corrupting it.
//!
//! Two in-process drivers share one per-client round body
//! ([`client_round`]), so their numerics are identical by construction:
//!
//! * [`run_federated`] — clients run sequentially through one shared
//!   executor.  Works with any backend, including PJRT executors, whose
//!   handles are not `Send`.
//! * [`run_federated_parallel`] — clients shard across the process pool
//!   (`runtime::pool`), one `Native` executor per worker lane.  Per-client
//!   seed streams, the k-ordered f64 loss reduction, and the k-ordered
//!   mask aggregation are all preserved, so the result is **byte-identical
//!   to the sequential run** (asserted by the tests here); only the
//!   wall-clock changes.
//!
//! The TCP worker (`repro serve-client`) drives the *same*
//! [`client_round`] body over real sockets, so every transport trains
//! the same numbers.  Every message still round-trips through the wire
//! encoder in all drivers, so the ledger's byte counts are the real
//! protocol costs, bit-for-bit equal to what the TCP transport ships.

use std::sync::{Arc, Mutex};

use crate::comm::{CommLedger, RoundCost};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::one_hot_into;
use crate::rng::{sample_distinct, SeedTree, Xoshiro256pp};
use crate::runtime::pool;
use crate::sparse::{CscView, QMatrix};
use crate::util::error::Result;
use crate::zampling::{evaluate, DenseExecutor, LocalZampling, NativeExecutor, ProbVector};
use crate::{bail, ensure};

use super::protocol::{
    decode_client, decode_server, encode_client, encode_server, ClientMsg, MaskCodec, ServerMsg,
};
use super::{pack_client_mask, Server};

/// Result of a federated run.
pub struct FedOutcome {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub final_probs: Vec<f32>,
}

/// Which clients a round selects (sorted client ids).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    pub round: usize,
    pub participants: Vec<usize>,
}

impl RoundPlan {
    /// Select the round's participants.  `participation = 1.0` selects
    /// everyone without touching any rng stream; below that,
    /// `max(1, round(participation·clients))` distinct clients are drawn
    /// from the shared seed tree so leader and simulator agree on the
    /// subset without communicating it.
    pub fn for_round(
        clients: usize,
        participation: f64,
        seeds: &SeedTree,
        round: usize,
    ) -> RoundPlan {
        assert!(clients > 0, "round plan needs at least one client");
        assert!(
            participation > 0.0 && participation <= 1.0,
            "participation {participation} must be in (0, 1]"
        );
        if participation >= 1.0 {
            return RoundPlan { round, participants: (0..clients).collect() };
        }
        let k = ((participation * clients as f64).round() as usize).clamp(1, clients);
        let mut rng = seeds.rng("round-participants", round as u64);
        let mut picks: Vec<u32> = Vec::with_capacity(k);
        sample_distinct(&mut rng, clients, k, &mut picks);
        let mut participants: Vec<usize> = picks.into_iter().map(|i| i as usize).collect();
        participants.sort_unstable();
        RoundPlan { round, participants }
    }
}

/// What actually happened in a round, after aggregation.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub plan: RoundPlan,
    /// Masks folded into the server's mean (the renormalization count).
    pub received: usize,
    /// Selected clients whose mask never arrived (always empty for the
    /// in-process drivers; the TCP leader records real drops).
    pub dropped: Vec<usize>,
    pub up_bits: u64,
    pub down_bits: u64,
    pub round_loss: f64,
}

/// What one client contributes to a round (reduced in client order by
/// every driver so f64 summation order never changes).
pub struct ClientRound {
    pub round: u32,
    pub loss: f64,
    pub down_bits: u64,
    pub up_bits: u64,
    pub packed_mask: Vec<u64>,
    /// The encoded uplink `Mask` frame — exactly the bytes the TCP
    /// worker ships; the simulator counts the same frame so the ledgers
    /// agree bit-for-bit.
    pub frame: Vec<u8>,
}

/// Shared per-client round body: decode the broadcast, local
/// training-by-sampling, sample and encode the uplink mask.  Driven by
/// the in-process simulators *and* the TCP worker (`repro serve-client`),
/// which is what keeps all transports numerically identical.
///
/// Errors (rather than panicking) on malformed `round_msg` bytes — the
/// TCP worker feeds it frames straight off the wire.
#[allow(clippy::too_many_arguments)]
pub fn client_round(
    cfg: &FedConfig,
    client: &mut LocalZampling,
    exec: &mut dyn DenseExecutor,
    shard: &Dataset,
    seeds: &SeedTree,
    round_msg: &[u8],
    codec: MaskCodec,
    k: usize,
) -> Result<ClientRound> {
    // 1. Receive p(t) — every client decodes its own frame copy.
    let ServerMsg::Round { round, probs } = decode_server(round_msg)? else {
        bail!("client {k}: expected a Round frame");
    };
    ensure!(
        probs.len() == cfg.train.n,
        "client {k}: round {round} ships {} probs, model has n = {}",
        probs.len(),
        cfg.train.n
    );
    let down_bits = round_msg.len() as u64 * 8;

    // 2. Client local training-by-sampling.
    client.pv.set_probs(&probs);
    client.reset_optimizer(&cfg.train);
    let mut loss = 0.0;
    for _ in 0..cfg.local_epochs {
        loss = client.run_epoch(exec, shard, cfg.train.batch);
    }

    // 3. Sample z_new ~ Bern(f(s)) and uplink the mask.
    let mut mask_rng = seeds.subtree("client", k as u64).rng("uplink-mask", round as u64);
    let mut mask = Vec::new();
    client.pv.sample_mask(&mut mask_rng, &mut mask);
    let frame = encode_client(
        &ClientMsg::Mask { round, client: k as u32, n: mask.len(), mask },
        codec,
    );
    let up_bits = frame.len() as u64 * 8;
    let ClientMsg::Mask { mask, .. } = decode_client(&frame)? else {
        bail!("client {k}: own mask frame decoded to a non-Mask message");
    };
    Ok(ClientRound { round, loss, down_bits, up_bits, packed_mask: pack_client_mask(&mask), frame })
}

/// Shared-seed setup: `Q`, the server's `p(0)`, and the client states.
fn init_clients(
    cfg: &FedConfig,
    seeds: &SeedTree,
) -> (Arc<QMatrix>, Arc<CscView>, Server, Vec<LocalZampling>) {
    // Shared-seed initialization: every party derives the same Q; the
    // server owns p(0) ~ U(0,1)^n from the shared stream.
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, seeds));
    let csc = Arc::new(q.to_csc(None));
    let mut init_rng = seeds.rng("p-init", 0);
    let server =
        Server::new(ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec());

    // Client states: local (Q, p) + a per-client seed subtree.
    let clients: Vec<LocalZampling> = (0..cfg.clients)
        .map(|k| {
            let sub = seeds.subtree("client", k as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(server.probs.clone()),
                &sub,
            )
        })
        .collect();
    (q, csc, server, clients)
}

/// Shared round tail, part 1: fold the per-client results into the
/// server **in client order** (f64 summation order fixed), close the
/// aggregation renormalized by the received count, and record the
/// ledger row.
fn reduce_round(
    plan: RoundPlan,
    outs: Vec<ClientRound>,
    server: &mut Server,
    ledger: &mut CommLedger,
) -> RoundOutcome {
    let (mut up_bits, mut down_bits, mut round_loss) = (0u64, 0u64, 0.0f64);
    for out in &outs {
        down_bits += out.down_bits;
        up_bits += out.up_bits;
        round_loss += out.loss;
        server.receive_mask(&out.packed_mask);
    }
    let received = server.try_aggregate();
    let dropped: Vec<usize> = Vec::new(); // in-process clients never drop
    ledger.record(RoundCost {
        uplink_bits: up_bits,
        downlink_bits: down_bits,
        clients: received as u32,
        participants: plan.participants.len() as u32,
        dropped: dropped.len() as u32,
    });
    RoundOutcome { plan, received, dropped, up_bits, down_bits, round_loss }
}

/// Shared round tail, part 2: evaluate the server's new `p` and push the
/// round record when the cadence (or the final round) says so.  Keeping
/// this in one place is what makes the drivers' logs identical by
/// construction.
#[allow(clippy::too_many_arguments)]
fn eval_and_log_round(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    q: &QMatrix,
    server: &Server,
    test: &Dataset,
    test_y1h: &[f32],
    eval_samples: usize,
    eval_every: usize,
    eval_rng: &mut Xoshiro256pp,
    log: &mut RunLog,
    outcome: &RoundOutcome,
) {
    let round = outcome.plan.round;
    if round % eval_every != 0 && round + 1 != cfg.rounds {
        return;
    }
    let pv = ProbVector::from_probs(server.probs.clone());
    let rep = evaluate(exec, q, &pv, &test.x, test_y1h, test.len(), eval_samples, eval_rng);
    log.push(RoundRecord {
        round,
        mean_sampled_acc: rep.mean_sampled_acc,
        sampled_acc_std: rep.sampled_acc_std,
        expected_acc: rep.expected_acc,
        train_loss: outcome.round_loss / outcome.received.max(1) as f64,
        uplink_bits: outcome.up_bits,
        downlink_bits: outcome.down_bits,
    });
}

/// Run Federated Zampling per the config (sequential client loop).
///
/// * `exec` — the dense executor shared by all (simulated) clients.
/// * `shards` — per-client training shards (from `Dataset::partition_iid`).
/// * `test` — held-out split for the per-round evaluation.
/// * `eval_samples` — masks per mean-sampled-accuracy estimate (§3.2: 100).
/// * `eval_every` — evaluate every `eval_every` rounds (1 = paper).
pub fn run_federated(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let codec = if cfg.entropy_code_uplink { MaskCodec::Arithmetic } else { MaskCodec::Raw };
    let (q, _csc, mut server, mut clients) = init_clients(cfg, &seeds);

    // Staged test split for evaluation.
    let out_dim = cfg.train.arch.output_dim();
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);
    let mut eval_rng = seeds.rng("eval-sampler", 0);

    let mut log = RunLog::new("federated");
    let mut ledger = CommLedger::default();

    for round in 0..cfg.rounds {
        let plan = RoundPlan::for_round(cfg.clients, cfg.participation, &seeds, round);
        // Broadcast p(t) — one encoded frame per participant.
        let round_msg =
            encode_server(&ServerMsg::Round { round: round as u32, probs: server.probs.clone() });
        let outs: Vec<ClientRound> = plan
            .participants
            .iter()
            .map(|&k| {
                client_round(cfg, &mut clients[k], exec, &shards[k], &seeds, &round_msg, codec, k)
                    .expect("simulator frames are well-formed")
            })
            .collect();

        let outcome = reduce_round(plan, outs, &mut server, &mut ledger);
        eval_and_log_round(
            cfg,
            exec,
            &q,
            &server,
            test,
            &test_y1h,
            eval_samples,
            eval_every,
            &mut eval_rng,
            &mut log,
            &outcome,
        );
    }

    FedOutcome { log, ledger, final_probs: server.probs }
}

/// [`run_federated`] with the client loop sharded across the process
/// pool — the `Native`-backend fast path (PJRT executors are not `Send`;
/// use the sequential driver for those).
///
/// Each pool lane owns a [`NativeExecutor`] (built once, reused across
/// rounds) and strides the round's participants; the per-round
/// evaluation runs on a dedicated executor whose eval scratch is sized
/// by `eval_batch`, matching the executor a sequential caller would
/// pass.  Per-client results are reduced in participant order
/// afterwards, so losses, ledgers, and `final_probs` are byte-identical
/// to the sequential run.
pub fn run_federated_parallel(
    cfg: &FedConfig,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    eval_batch: usize,
) -> FedOutcome {
    assert_eq!(shards.len(), cfg.clients, "need one shard per client");
    let seeds = SeedTree::new(cfg.train.seed);
    let codec = if cfg.entropy_code_uplink { MaskCodec::Arithmetic } else { MaskCodec::Raw };
    let (q, _csc, mut server, mut clients) = init_clients(cfg, &seeds);

    let out_dim = cfg.train.arch.output_dim();
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);
    let mut eval_rng = seeds.rng("eval-sampler", 0);
    let mut eval_exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, eval_batch);

    let mut log = RunLog::new("federated");
    let mut ledger = CommLedger::default();
    let nt_max = pool::global().parallelism().min(cfg.clients).max(1);

    // One training executor per lane, built once and reused every round
    // (lanes never evaluate, so eval scratch is minimal).  The mutexes
    // are uncontended — lane `l` only ever touches `lane_execs[l]`.
    let lane_execs: Vec<Mutex<NativeExecutor>> = (0..nt_max)
        .map(|_| Mutex::new(NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 1)))
        .collect();

    for round in 0..cfg.rounds {
        let plan = RoundPlan::for_round(cfg.clients, cfg.participation, &seeds, round);
        let round_msg =
            encode_server(&ServerMsg::Round { round: round as u32, probs: server.probs.clone() });

        // Shard the round's participants across the pool.  Each client is
        // visited by exactly one lane, so the per-client mutexes are
        // uncontended — they only convert `&mut` access into something a
        // shared `Fn` closure may hold.
        let parts = &plan.participants;
        let p_total = parts.len();
        let nt = nt_max.min(p_total).max(1);
        let cells: Vec<Mutex<&mut LocalZampling>> = clients.iter_mut().map(Mutex::new).collect();
        let results: Vec<Mutex<Option<ClientRound>>> =
            (0..p_total).map(|_| Mutex::new(None)).collect();
        pool::global().run(nt, |lane| {
            let mut exec = lane_execs[lane].lock().unwrap();
            let mut i = lane;
            while i < p_total {
                let k = parts[i];
                let mut client = cells[k].lock().unwrap();
                let out = client_round(
                    cfg,
                    &mut client,
                    &mut *exec,
                    &shards[k],
                    &seeds,
                    &round_msg,
                    codec,
                    k,
                )
                .expect("simulator frames are well-formed");
                *results[i].lock().unwrap() = Some(out);
                i += nt;
            }
        });

        // Collect in participant order (bit-identical to the sequential
        // loop, which visits the sorted participant list).
        let outs: Vec<ClientRound> = results
            .iter()
            .map(|cell| cell.lock().unwrap().take().expect("client result missing"))
            .collect();

        let outcome = reduce_round(plan, outs, &mut server, &mut ledger);
        eval_and_log_round(
            cfg,
            &mut eval_exec,
            &q,
            &server,
            test,
            &test_y1h,
            eval_samples,
            eval_every,
            &mut eval_rng,
            &mut log,
            &outcome,
        );
    }

    FedOutcome { log, ledger, final_probs: server.probs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    fn tiny_fed(entropy: bool) -> (FedConfig, Vec<Dataset>, Dataset) {
        let mut cfg = FedConfig::paper(8);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params() / 8;
        cfg.train.d = 5;
        cfg.train.lr = 0.1;
        cfg.train.seed = 1;
        cfg.clients = 4;
        cfg.rounds = 6;
        cfg.local_epochs = 1;
        cfg.entropy_code_uplink = entropy;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, test) = Dataset::synthetic_pair(1024, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        (cfg, shards, test)
    }

    #[test]
    fn federated_training_learns_and_accounts_comm() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 8, 1);
        let first = out.log.rounds.first().unwrap().mean_sampled_acc;
        let last = out.log.rounds.last().unwrap().mean_sampled_acc;
        assert!(last > first, "accuracy did not improve: {first} → {last}");
        assert!(last > 0.3, "final acc {last}");

        // Ledger: downlink is 32n-ish bits + framing; uplink ~ n bits.
        let rep = out.ledger.savings(cfg.train.arch.num_params());
        // client savings should approach 32·(m/n) = 256 (modulo framing)
        assert!(rep.client_savings > 200.0, "client savings {rep:?}");
        assert!(rep.server_savings > 6.0, "server savings {rep:?}");
        assert_eq!(out.final_probs.len(), cfg.train.n);
        // full participation, no dropouts: every row says so
        for r in &out.ledger.rounds {
            assert_eq!(r.participants, cfg.clients as u32);
            assert_eq!(r.clients, cfg.clients as u32);
            assert_eq!(r.dropped, 0);
        }
    }

    #[test]
    fn entropy_coded_uplink_beats_raw_bits_late_in_training() {
        let (cfg, shards, test) = tiny_fed(true);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_federated(&cfg, &mut exec, &shards, &test, 4, 3);
        // After aggregation p concentrates; the arithmetic coder should
        // drop below 1 bit/entry at least by the last round.
        let last = out.ledger.rounds.last().unwrap();
        let bits_per_entry =
            last.uplink_bits as f64 / (cfg.clients as f64 * cfg.train.n as f64);
        assert!(bits_per_entry < 1.2, "bits/entry {bits_per_entry}");
    }

    #[test]
    fn federated_run_is_deterministic() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 4, 2);
        assert_eq!(a.final_probs, b.final_probs);
    }

    #[test]
    fn parallel_run_matches_sequential_byte_for_byte() {
        let (cfg, shards, test) = tiny_fed(false);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let seq = run_federated(&cfg, &mut exec, &shards, &test, 4, 2);
        let par = run_federated_parallel(&cfg, &shards, &test, 4, 2, 256);
        assert_eq!(seq.final_probs, par.final_probs);
        assert_eq!(seq.log.rounds.len(), par.log.rounds.len());
        for (a, b) in seq.log.rounds.iter().zip(&par.log.rounds) {
            assert_eq!(a.mean_sampled_acc, b.mean_sampled_acc, "round {}", a.round);
            assert_eq!(a.train_loss, b.train_loss, "round {}", a.round);
            assert_eq!(a.uplink_bits, b.uplink_bits, "round {}", a.round);
            assert_eq!(a.downlink_bits, b.downlink_bits, "round {}", a.round);
        }
        let (sa, sb) = (&seq.ledger.rounds, &par.ledger.rounds);
        assert_eq!(sa.len(), sb.len());
        for (a, b) in sa.iter().zip(sb) {
            assert_eq!(a.uplink_bits, b.uplink_bits);
            assert_eq!(a.downlink_bits, b.downlink_bits);
            assert_eq!(a.participants, b.participants);
        }
    }

    #[test]
    fn round_plan_is_deterministic_and_sized() {
        let seeds = SeedTree::new(9);
        for round in 0..20 {
            let a = RoundPlan::for_round(10, 0.5, &seeds, round);
            let b = RoundPlan::for_round(10, 0.5, &seeds, round);
            assert_eq!(a, b);
            assert_eq!(a.participants.len(), 5);
            let mut sorted = a.participants.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "duplicate participant in {a:?}");
            assert!(a.participants.iter().all(|&k| k < 10));
        }
        // subsets vary across rounds
        let p0 = RoundPlan::for_round(10, 0.5, &seeds, 0);
        assert!((1..20).any(|r| RoundPlan::for_round(10, 0.5, &seeds, r) != p0));
        // full participation selects everyone, tiny rates select at least one
        assert_eq!(RoundPlan::for_round(4, 1.0, &seeds, 3).participants, vec![0, 1, 2, 3]);
        assert_eq!(RoundPlan::for_round(4, 0.01, &seeds, 3).participants.len(), 1);
    }

    #[test]
    fn partial_participation_renormalizes_and_stays_deterministic() {
        let (mut cfg, shards, test) = tiny_fed(false);
        cfg.participation = 0.5;
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let a = run_federated(&cfg, &mut e1, &shards, &test, 4, 2);
        let b = run_federated(&cfg, &mut e2, &shards, &test, 4, 2);
        assert_eq!(a.final_probs, b.final_probs, "partial participation must be seeded");
        for r in &a.ledger.rounds {
            assert_eq!(r.participants, 2, "0.5 of 4 clients");
            assert_eq!(r.clients, 2);
            assert_eq!(r.dropped, 0);
        }
        // renormalized mean stays a probability
        assert!(a.final_probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
        // and the parallel driver agrees byte-for-byte on the subset runs
        let par = run_federated_parallel(&cfg, &shards, &test, 4, 2, 256);
        assert_eq!(a.final_probs, par.final_probs);
    }

    #[test]
    fn partial_participation_costs_proportionally_less_uplink() {
        let (mut cfg, shards, test) = tiny_fed(false);
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let full = run_federated(&cfg, &mut e1, &shards, &test, 2, 3);
        cfg.participation = 0.5;
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let half = run_federated(&cfg, &mut e2, &shards, &test, 2, 3);
        // raw-codec mask frames have fixed size → exactly half the uplink
        assert_eq!(half.ledger.total_uplink_bits() * 2, full.ledger.total_uplink_bits());
    }

    #[test]
    #[should_panic(expected = "one shard per client")]
    fn shard_count_mismatch_panics() {
        let (cfg, mut shards, test) = tiny_fed(false);
        shards.pop();
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        run_federated(&cfg, &mut exec, &shards, &test, 2, 1);
    }
}
