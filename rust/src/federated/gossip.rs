//! Decentralized Zampling — the paper's §4 future-work direction:
//! *"a distributed setting, without a central server, testing the
//! performance of Federated Zampling where the communication between
//! clients follows arbitrary graph patterns."*
//!
//! Each node holds its own probability vector.  Per round, every node
//! trains locally by sampling, samples a fresh mask from its clipped
//! scores, and **gossips the n-bit mask to its graph neighbours**; it
//! then averages its own mask with the received ones:
//! `p_k(t+1) = mean({z_k} ∪ {z_j : j ~ k})`.  The complete graph
//! recovers the centralized protocol exactly (same mean over the same
//! masks); sparser topologies trade convergence speed for per-node
//! degree-proportional communication.
//!
//! Since the `RoundEngine` redesign the round loop lives in
//! [`engine`](super::engine); this module supplies [`PeerTransport`],
//! where **each node runs a tiny aggregation engine (a [`Server`]) for
//! itself and its neighbours**, and overrides the central aggregation
//! hook to write the consensus (node-average) vector into the engine's
//! global state — which is exactly what the engine then evaluates.

use std::sync::Arc;

use crate::comm::CommLedger;
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::metrics::RunLog;
use crate::rng::SeedTree;
use crate::sparse::QMatrix;
use crate::util::error::Result;
use crate::zampling::{DenseExecutor, LocalZampling, ProbVector};

use super::engine::{make_policy, Contribution, RoundCtx, RoundEngine, RoundTraffic, Transport};
use super::{pack_client_mask, Server};

/// Undirected communication graph over `k` nodes (adjacency lists).
#[derive(Clone, Debug)]
pub struct Topology {
    /// `neighbors[i]` lists node `i`'s graph neighbours.
    pub neighbors: Vec<Vec<usize>>,
}

impl Topology {
    /// Every node talks to every other node (recovers centralized).
    pub fn complete(k: usize) -> Self {
        Self {
            neighbors: (0..k).map(|i| (0..k).filter(|&j| j != i).collect()).collect(),
        }
    }

    /// Each node talks to its two ring neighbours.
    pub fn ring(k: usize) -> Self {
        assert!(k >= 2);
        Self {
            neighbors: (0..k)
                .map(|i| {
                    let mut v = vec![(i + 1) % k, (i + k - 1) % k];
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect(),
        }
    }

    /// Star around node 0 (the "almost centralized" topology).
    pub fn star(k: usize) -> Self {
        assert!(k >= 2);
        let mut neighbors = vec![Vec::new(); k];
        for i in 1..k {
            neighbors[0].push(i);
            neighbors[i].push(0);
        }
        Self { neighbors }
    }

    /// Number of nodes in the graph.
    pub fn len(&self) -> usize {
        self.neighbors.len()
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.neighbors.is_empty()
    }

    /// Total directed edges (messages per round).
    pub fn num_messages(&self) -> usize {
        self.neighbors.iter().map(|n| n.len()).sum()
    }
}

/// Outcome of a decentralized run; accuracy is evaluated on the
/// node-averaged consensus vector (what the nodes converge towards).
pub struct GossipOutcome {
    /// Per-round consensus accuracy/loss records.
    pub log: RunLog,
    /// Per-round communication accounting (edge messages, no downlink).
    pub ledger: CommLedger,
    /// Every node's final probability vector.
    pub node_probs: Vec<Vec<f32>>,
}

/// The peer-to-peer [`Transport`]: no central server — each
/// participating node trains on its own `p`, gossips its mask to its
/// participating neighbours (counted as `n` raw bits per directed
/// edge, no downlink), and aggregates through a **tiny per-node
/// `Server`** over its own + received masks.  The engine's global state
/// is overwritten with the consensus (node-average) vector, so the
/// shared evaluation path reports what the nodes converge towards.
pub struct PeerTransport<'a> {
    cfg: &'a FedConfig,
    topo: &'a Topology,
    exec: &'a mut dyn DenseExecutor,
    shards: &'a [Dataset],
    nodes: Vec<LocalZampling>,
    seeds: SeedTree,
    /// This round's packed masks by node id (None for non-participants).
    round_masks: Vec<Option<Vec<u64>>>,
}

impl<'a> PeerTransport<'a> {
    /// Build over a topology, per-node data shards, and per-node states.
    pub fn new(
        cfg: &'a FedConfig,
        topo: &'a Topology,
        exec: &'a mut dyn DenseExecutor,
        shards: &'a [Dataset],
        nodes: Vec<LocalZampling>,
    ) -> Self {
        assert_eq!(shards.len(), topo.len(), "one shard per node");
        assert_eq!(nodes.len(), topo.len(), "one state per node");
        let k = topo.len();
        Self {
            cfg,
            topo,
            exec,
            shards,
            nodes,
            seeds: SeedTree::new(cfg.train.seed),
            round_masks: vec![None; k],
        }
    }

    /// The per-node probability vectors (after a run: the final state).
    pub fn node_probs(&self) -> Vec<Vec<f32>> {
        self.nodes.iter().map(|s| s.pv.probs().to_vec()).collect()
    }
}

impl Transport for PeerTransport<'_> {
    /// Nodes never consume a central broadcast — each trains on its own
    /// current p — so the engine skips encoding one (downlink is 0).
    fn wants_broadcast(&self) -> bool {
        false
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mask_bits = ctx.n as u64; // per directed edge (raw bit-packed)
        self.round_masks.iter_mut().for_each(|m| *m = None);
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        for &i in ctx.participants {
            let node = &mut self.nodes[i];
            node.reset_optimizer(&self.cfg.train);
            let mut loss = 0.0;
            for _ in 0..self.cfg.local_epochs {
                loss = node.run_epoch(&mut *self.exec, &self.shards[i], self.cfg.train.batch);
            }
            let mut rng =
                self.seeds.subtree("client", i as u64).rng("gossip-mask", ctx.round as u64);
            let mut mask = Vec::new();
            node.pv.sample_mask(&mut rng, &mut mask);
            let packed = pack_client_mask(&mask);
            // One mask per directed edge to a *participating* neighbour
            // (at full participation: the node's full degree).
            let degree = self.topo.neighbors[i]
                .iter()
                .filter(|&&j| ctx.participants.binary_search(&j).is_ok())
                .count();
            // `packed_mask` stays empty: only the engine's default
            // central aggregation reads it, and this transport overrides
            // `aggregate` to work from `round_masks` instead.
            contributions.push(Contribution {
                client: i,
                loss,
                up_bits: mask_bits * degree as u64,
                packed_mask: Vec::new(),
            });
            self.round_masks[i] = Some(packed);
        }
        Ok(RoundTraffic {
            contributions,
            dropped: Vec::new(),
            down_bits: 0,
            shard_costs: Vec::new(),
        })
    }

    /// Decentralized aggregation: node `i` averages its own mask with
    /// its participating neighbours' via a tiny per-node [`Server`]
    /// (`u32` mask sums are exact, so the division is bit-identical to
    /// an f32 accumulate); the engine's global probs become the
    /// consensus (node-average) vector.
    ///
    /// The consensus is refreshed every round (the legacy loop only
    /// built it on eval rounds) so the engine's shared eval path stays
    /// uniform; the O(k·n) average is noise next to the k local
    /// training epochs that precede it.
    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        let n = server.n();
        let k = self.nodes.len();
        for c in &traffic.contributions {
            let i = c.client;
            let mut tiny = Server::new(vec![0.0; n]);
            tiny.receive_mask(self.round_masks[i].as_ref().expect("own mask present"));
            for &j in &self.topo.neighbors[i] {
                if let Some(m) = &self.round_masks[j] {
                    tiny.receive_mask(m);
                }
            }
            tiny.try_aggregate();
            self.nodes[i].pv.set_probs(&tiny.probs);
        }
        // Consensus over *all* nodes, in node order (fixed f32 order).
        let mut consensus = vec![0.0f32; n];
        for node in &self.nodes {
            for (c, &p) in consensus.iter_mut().zip(node.pv.probs()) {
                *c += p;
            }
        }
        for c in consensus.iter_mut() {
            *c /= k as f32;
        }
        server.probs = consensus;
        traffic.contributions.len()
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut *self.exec
    }
}

/// Run decentralized Zampling over `topo` — a thin constructor over
/// [`RoundEngine`] + [`PeerTransport`].
pub fn run_gossip(
    cfg: &FedConfig,
    topo: &Topology,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> GossipOutcome {
    assert_eq!(shards.len(), topo.len(), "one shard per node");
    let k = topo.len();
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let n = cfg.train.n;

    // All nodes start from the shared-seed p(0) (same as centralized).
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(n, &mut init_rng).probs().to_vec();
    let nodes: Vec<LocalZampling> = (0..k)
        .map(|i| {
            let sub = seeds.subtree("client", i as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(p0.clone()),
                &sub,
            )
        })
        .collect();

    let engine = RoundEngine::new(
        cfg,
        k,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "gossip",
    );
    let mut transport = PeerTransport::new(cfg, topo, exec, shards, nodes);
    let mut policy = make_policy(cfg.policy);
    let out = engine
        .run(&mut transport, policy.as_mut())
        .expect("in-process transports are infallible");
    GossipOutcome { log: out.log, ledger: out.ledger, node_probs: transport.node_probs() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::RoundCost;
    use crate::metrics::RoundRecord;
    use crate::nn::ArchSpec;
    use crate::zampling::{evaluate, NativeExecutor};

    fn ci_setup() -> (FedConfig, Vec<Dataset>, Dataset) {
        let mut cfg = FedConfig::paper(8);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params() / 8;
        cfg.train.d = 5;
        cfg.train.lr = 0.1;
        cfg.train.seed = 1;
        cfg.clients = 4;
        cfg.rounds = 6;
        cfg.local_epochs = 1;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, test) = Dataset::synthetic_pair(1_024, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        (cfg, shards, test)
    }

    #[test]
    fn topologies_are_well_formed() {
        for topo in [Topology::complete(5), Topology::ring(5), Topology::star(5)] {
            assert_eq!(topo.len(), 5);
            for (i, ns) in topo.neighbors.iter().enumerate() {
                for &j in ns {
                    assert_ne!(i, j);
                    assert!(topo.neighbors[j].contains(&i), "graph not symmetric");
                }
            }
        }
        assert_eq!(Topology::complete(5).num_messages(), 20);
        assert_eq!(Topology::ring(5).num_messages(), 10);
        assert_eq!(Topology::star(5).num_messages(), 8);
    }

    #[test]
    fn gossip_learns_on_ring_and_complete() {
        let (cfg, shards, test) = ci_setup();
        for topo in [Topology::complete(cfg.clients), Topology::ring(cfg.clients)] {
            let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let out = run_gossip(&cfg, &topo, &mut exec, &shards, &test, 6, 2);
            let first = out.log.rounds.first().unwrap().mean_sampled_acc;
            let last = out.log.rounds.last().unwrap().mean_sampled_acc;
            assert!(last > first, "no improvement on {topo:?}: {first} → {last}");
            assert!(last > 0.3, "failed to learn on {topo:?}: {last}");
        }
    }

    #[test]
    fn ring_uses_less_traffic_than_complete() {
        let (cfg, shards, test) = ci_setup();
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let complete = run_gossip(
            &cfg,
            &Topology::complete(cfg.clients),
            &mut e1,
            &shards,
            &test,
            2,
            5,
        );
        let ring =
            run_gossip(&cfg, &Topology::ring(cfg.clients), &mut e2, &shards, &test, 2, 5);
        assert!(ring.ledger.total_uplink_bits() < complete.ledger.total_uplink_bits());
    }

    #[test]
    fn nodes_drift_apart_on_sparse_graphs_but_not_complete() {
        let (cfg, shards, test) = ci_setup();
        let spread = |probs: &[Vec<f32>]| -> f64 {
            // max pairwise L2 distance between node vectors
            let mut worst = 0.0f64;
            for a in probs {
                for b in probs {
                    let d: f64 = a
                        .iter()
                        .zip(b)
                        .map(|(&x, &y)| ((x - y) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt();
                    worst = worst.max(d);
                }
            }
            worst
        };
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let complete = run_gossip(
            &cfg,
            &Topology::complete(cfg.clients),
            &mut e1,
            &shards,
            &test,
            2,
            5,
        );
        // Complete graph: all nodes average the same masks → identical p.
        assert!(spread(&complete.node_probs) < 1e-6, "{}", spread(&complete.node_probs));
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let ring =
            run_gossip(&cfg, &Topology::ring(cfg.clients), &mut e2, &shards, &test, 2, 5);
        assert!(spread(&ring.node_probs) > spread(&complete.node_probs));
    }

    /// Replica of the pre-engine `run_gossip` loop (the seed's gossip
    /// driver), built from public API pieces.  The engine-based driver
    /// must reproduce it byte-for-byte: node probs, ledger rows, and
    /// log records — the gossip leg of the "no behavior change at
    /// defaults" guarantee.
    fn legacy_gossip_driver(
        cfg: &FedConfig,
        topo: &Topology,
        exec: &mut dyn DenseExecutor,
        shards: &[Dataset],
        test: &Dataset,
        eval_samples: usize,
        eval_every: usize,
    ) -> GossipOutcome {
        use crate::nn::one_hot_into;

        let k = topo.len();
        let seeds = SeedTree::new(cfg.train.seed);
        let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
        let csc = Arc::new(q.to_csc(None));
        let n = cfg.train.n;
        let mut init_rng = seeds.rng("p-init", 0);
        let p0 = ProbVector::init_uniform(n, &mut init_rng).probs().to_vec();
        let mut nodes: Vec<LocalZampling> = (0..k)
            .map(|i| {
                let sub = seeds.subtree("client", i as u64);
                LocalZampling::from_parts(
                    &cfg.train,
                    Arc::clone(&q),
                    Arc::clone(&csc),
                    ProbVector::from_probs(p0.clone()),
                    &sub,
                )
            })
            .collect();

        let out_dim = exec.arch().output_dim();
        let mut test_y1h = vec![0.0f32; test.len() * out_dim];
        one_hot_into(&test.y, out_dim, &mut test_y1h);
        let mut eval_rng = seeds.rng("eval-sampler", 0);
        let mut log = RunLog::new("gossip");
        let mut ledger = CommLedger::default();
        let mask_bits = n as u64;

        for round in 0..cfg.rounds {
            let mut masks: Vec<Vec<bool>> = Vec::with_capacity(k);
            let mut round_loss = 0.0f64;
            for (i, node) in nodes.iter_mut().enumerate() {
                node.reset_optimizer(&cfg.train);
                let mut loss = 0.0;
                for _ in 0..cfg.local_epochs {
                    loss = node.run_epoch(exec, &shards[i], cfg.train.batch);
                }
                round_loss += loss;
                let mut rng =
                    seeds.subtree("client", i as u64).rng("gossip-mask", round as u64);
                let mut mask = Vec::new();
                node.pv.sample_mask(&mut rng, &mut mask);
                masks.push(mask);
            }
            let mut new_probs: Vec<Vec<f32>> = Vec::with_capacity(k);
            for i in 0..k {
                let mut acc: Vec<f32> = masks[i].iter().map(|&b| b as u8 as f32).collect();
                for &j in &topo.neighbors[i] {
                    for (a, &b) in acc.iter_mut().zip(&masks[j]) {
                        *a += b as u8 as f32;
                    }
                }
                let denom = (topo.neighbors[i].len() + 1) as f32;
                for a in acc.iter_mut() {
                    *a /= denom;
                }
                new_probs.push(acc);
            }
            for (node, p) in nodes.iter_mut().zip(&new_probs) {
                node.pv.set_probs(p);
            }
            ledger.record(RoundCost {
                uplink_bits: mask_bits * topo.num_messages() as u64,
                downlink_bits: 0,
                clients: k as u32,
                participants: k as u32,
                dropped: 0,
            });
            if round % eval_every == 0 || round + 1 == cfg.rounds {
                let mut consensus = vec![0.0f32; n];
                for node in &nodes {
                    for (c, &p) in consensus.iter_mut().zip(node.pv.probs()) {
                        *c += p;
                    }
                }
                for c in consensus.iter_mut() {
                    *c /= k as f32;
                }
                let pv = ProbVector::from_probs(consensus);
                let rep = evaluate(
                    exec,
                    &q,
                    &pv,
                    &test.x,
                    &test_y1h,
                    test.len(),
                    eval_samples,
                    &mut eval_rng,
                );
                log.push(RoundRecord {
                    round,
                    mean_sampled_acc: rep.mean_sampled_acc,
                    sampled_acc_std: rep.sampled_acc_std,
                    expected_acc: rep.expected_acc,
                    train_loss: round_loss / k as f64,
                    uplink_bits: mask_bits * topo.num_messages() as u64,
                    downlink_bits: 0,
                });
            }
        }
        GossipOutcome {
            log,
            ledger,
            node_probs: nodes.into_iter().map(|s| s.pv.probs().to_vec()).collect(),
        }
    }

    #[test]
    fn engine_gossip_is_byte_identical_to_the_legacy_driver() {
        let (cfg, shards, test) = ci_setup();
        for topo in [Topology::ring(cfg.clients), Topology::star(cfg.clients)] {
            let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let legacy =
                legacy_gossip_driver(&cfg, &topo, &mut e1, &shards, &test, 3, 2);
            let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let new = run_gossip(&cfg, &topo, &mut e2, &shards, &test, 3, 2);
            assert_eq!(new.node_probs, legacy.node_probs, "node probs diverged on {topo:?}");
            assert_eq!(new.ledger.rounds.len(), legacy.ledger.rounds.len());
            for (a, b) in new.ledger.rounds.iter().zip(&legacy.ledger.rounds) {
                assert_eq!(a.uplink_bits, b.uplink_bits);
                assert_eq!(a.downlink_bits, b.downlink_bits);
                assert_eq!(a.clients, b.clients);
                assert_eq!(a.participants, b.participants);
                assert_eq!(a.dropped, b.dropped);
            }
            assert_eq!(new.log.rounds.len(), legacy.log.rounds.len());
            for (a, b) in new.log.rounds.iter().zip(&legacy.log.rounds) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.mean_sampled_acc, b.mean_sampled_acc, "round {}", a.round);
                assert_eq!(a.sampled_acc_std, b.sampled_acc_std, "round {}", a.round);
                assert_eq!(a.expected_acc, b.expected_acc, "round {}", a.round);
                assert_eq!(a.train_loss, b.train_loss, "round {}", a.round);
                assert_eq!(a.uplink_bits, b.uplink_bits);
                assert_eq!(a.downlink_bits, b.downlink_bits);
            }
        }
    }
}
