//! Decentralized Zampling — the paper's §4 future-work direction:
//! *"a distributed setting, without a central server, testing the
//! performance of Federated Zampling where the communication between
//! clients follows arbitrary graph patterns."*
//!
//! Each node holds its own probability vector.  Per round, every node
//! trains locally by sampling, samples a fresh mask from its clipped
//! scores, and **gossips the n-bit mask to its graph neighbours**; it
//! then averages its own mask with the received ones:
//! `p_k(t+1) = mean({z_k} ∪ {z_j : j ~ k})`.  The complete graph
//! recovers the centralized protocol exactly (same mean over the same
//! masks); sparser topologies trade convergence speed for per-node
//! degree-proportional communication.
//!
//! Since the `RoundEngine` redesign the round loop lives in
//! [`engine`](super::engine); this module supplies [`PeerTransport`],
//! where **each node runs a tiny aggregation engine (a [`Server`]) for
//! itself and its neighbours**, and overrides the central aggregation
//! hook to write the consensus (node-average) vector into the engine's
//! global state — which is exactly what the engine then evaluates.
//!
//! The same protocol runs **over real sockets** via
//! [`WirePeerTransport`] + [`run_peer`]: every node is a separate
//! process running a tiny [`Leader`] for its graph neighbours (the TCP
//! leader's sweeper/event-channel/deadline/reconnect machinery,
//! scoped by [`Leader::from_listener_subset`]), masks travel
//! peer-to-peer one `n`-bit frame per directed edge, and a coordinator
//! drives rounds with unbilled `PeerRound`/`Report` frames.
//! Byte-identical to the in-process transport at the same seed and
//! topology; semantics in `docs/GOSSIP.md`, wire format in
//! `docs/PROTOCOL.md` §7.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use crate::comm::{CommLedger, EdgeCost};
use crate::config::{FedConfig, TopologyKind};
use crate::data::Dataset;
use crate::metrics::RunLog;
use crate::rng::SeedTree;
use crate::sparse::QMatrix;
use crate::util::error::Result;
use crate::zampling::{DenseExecutor, LocalZampling, ProbVector};
use crate::{bail, ensure};

use super::engine::{
    make_policy, Contribution, DeadlinePolicy, RoundCtx, RoundEngine, RoundTraffic, Transport,
};
use super::protocol::{
    decode_server, encode_client, encode_server, peek_server_frame, ClientMsg, MaskCodec,
    ServerFrameKind, ServerMsg,
};
use super::transport::{Leader, Worker};
use super::{pack_client_mask, Server};

/// How long gossip processes keep retrying their startup dials
/// (coordinator + every neighbour's listener) before giving up.
const PEER_CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Undirected communication graph over `k` nodes (adjacency lists).
#[derive(Clone, Debug)]
pub struct Topology {
    /// `neighbors[i]` lists node `i`'s graph neighbours.
    pub neighbors: Vec<Vec<usize>>,
}

impl Topology {
    /// Checked constructor over explicit adjacency lists: rejects
    /// self-loops, out-of-range neighbour ids, duplicate entries, and
    /// asymmetric edges (an undirected graph must list every edge from
    /// both ends) — the config-parse-time guard that used to be a
    /// mid-round panic.  Neighbour lists are canonicalized to ascending
    /// order, the form every consumer (participant intersection via
    /// `binary_search`) relies on.
    pub fn from_neighbors(neighbors: Vec<Vec<usize>>) -> Result<Self, String> {
        crate::config::validate_topology_adjacency(&neighbors)?;
        let neighbors = neighbors
            .into_iter()
            .map(|mut v| {
                v.sort_unstable();
                v
            })
            .collect();
        Ok(Self { neighbors })
    }

    /// Build one of the named topologies over `k` nodes, erroring (not
    /// panicking) on degenerate sizes.
    pub fn from_kind(kind: TopologyKind, k: usize) -> Result<Self, String> {
        if k < kind.min_nodes() {
            return Err(format!(
                "{} topology needs at least {} nodes, got {k}",
                kind.as_str(),
                kind.min_nodes()
            ));
        }
        Ok(match kind {
            TopologyKind::Complete => Self::complete(k),
            TopologyKind::Ring => Self::ring(k),
            TopologyKind::Star => Self::star(k),
        })
    }

    /// Build the configured topology over `cfg.clients` nodes: an
    /// explicit `federated.topology-adj` adjacency wins (re-validated
    /// here), otherwise the named `federated.topology` kind.
    pub fn from_cfg(cfg: &FedConfig) -> Result<Self, String> {
        if !cfg.topology_adj.is_empty() {
            if cfg.topology_adj.len() != cfg.clients {
                return Err(format!(
                    "topology-adj lists {} nodes for {} clients",
                    cfg.topology_adj.len(),
                    cfg.clients
                ));
            }
            return Self::from_neighbors(cfg.topology_adj.clone());
        }
        Self::from_kind(cfg.topology, cfg.clients)
    }

    /// Every node talks to every other node (recovers centralized).
    pub fn complete(k: usize) -> Self {
        Self {
            neighbors: (0..k).map(|i| (0..k).filter(|&j| j != i).collect()).collect(),
        }
    }

    /// Each node talks to its two ring neighbours.
    pub fn ring(k: usize) -> Self {
        assert!(k >= 2);
        Self {
            neighbors: (0..k)
                .map(|i| {
                    let mut v = vec![(i + 1) % k, (i + k - 1) % k];
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect(),
        }
    }

    /// Star around node 0 (the "almost centralized" topology).
    pub fn star(k: usize) -> Self {
        assert!(k >= 2);
        let mut neighbors = vec![Vec::new(); k];
        for i in 1..k {
            neighbors[0].push(i);
            neighbors[i].push(0);
        }
        Self { neighbors }
    }

    /// Number of nodes in the graph.
    pub fn len(&self) -> usize {
        self.neighbors.len()
    }

    /// Whether the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.neighbors.is_empty()
    }

    /// Total directed edges (messages per round).
    pub fn num_messages(&self) -> usize {
        self.neighbors.iter().map(|n| n.len()).sum()
    }
}

/// Consensus (node-average) vector over every node's probabilities, in
/// node order — **one** definition of the f32 summation order, shared
/// by the in-process and wire transports so the byte-identity tests
/// can never be broken by the two drifting apart.
fn consensus_mean<'p>(nodes: impl ExactSizeIterator<Item = &'p [f32]>, n: usize) -> Vec<f32> {
    let k = nodes.len();
    let mut consensus = vec![0.0f32; n];
    for node in nodes {
        for (c, &p) in consensus.iter_mut().zip(node) {
            *c += p;
        }
    }
    for c in consensus.iter_mut() {
        *c /= k as f32;
    }
    consensus
}

/// Bill node `node`'s gossip sends for a round: append one [`EdgeCost`]
/// row per live directed edge (each *participating* neighbour) and
/// return the live degree — the shared billing body of the in-process
/// and wire transports (`n` bits per edge, the `num_messages()` model).
fn bill_edges(
    topo: &Topology,
    node: usize,
    participants: &[usize],
    bits: u64,
    out: &mut Vec<EdgeCost>,
) -> u64 {
    let mut degree = 0u64;
    for &j in &topo.neighbors[node] {
        if participants.binary_search(&j).is_ok() {
            degree += 1;
            out.push(EdgeCost { from: node as u32, to: j as u32, bits });
        }
    }
    degree
}

/// Outcome of a decentralized run; accuracy is evaluated on the
/// node-averaged consensus vector (what the nodes converge towards).
pub struct GossipOutcome {
    /// Per-round consensus accuracy/loss records.
    pub log: RunLog,
    /// Per-round communication accounting (edge messages, no downlink),
    /// including the per-directed-edge table (`CommLedger::edge_rounds`).
    pub ledger: CommLedger,
    /// The final consensus (node-average) probability vector — what the
    /// engine evaluated after the last round.
    pub final_probs: Vec<f32>,
    /// Every node's final probability vector.
    pub node_probs: Vec<Vec<f32>>,
}

/// The peer-to-peer [`Transport`]: no central server — each
/// participating node trains on its own `p`, gossips its mask to its
/// participating neighbours (counted as `n` raw bits per directed
/// edge, no downlink), and aggregates through a **tiny per-node
/// `Server`** over its own + received masks.  The engine's global state
/// is overwritten with the consensus (node-average) vector, so the
/// shared evaluation path reports what the nodes converge towards.
pub struct PeerTransport<'a> {
    cfg: &'a FedConfig,
    topo: &'a Topology,
    exec: &'a mut dyn DenseExecutor,
    shards: &'a [Dataset],
    nodes: Vec<LocalZampling>,
    seeds: SeedTree,
    /// This round's packed masks by node id (None for non-participants).
    round_masks: Vec<Option<Vec<u64>>>,
}

impl<'a> PeerTransport<'a> {
    /// Build over a topology, per-node data shards, and per-node states.
    pub fn new(
        cfg: &'a FedConfig,
        topo: &'a Topology,
        exec: &'a mut dyn DenseExecutor,
        shards: &'a [Dataset],
        nodes: Vec<LocalZampling>,
    ) -> Self {
        assert_eq!(shards.len(), topo.len(), "one shard per node");
        assert_eq!(nodes.len(), topo.len(), "one state per node");
        let k = topo.len();
        Self {
            cfg,
            topo,
            exec,
            shards,
            nodes,
            seeds: SeedTree::new(cfg.train.seed),
            round_masks: vec![None; k],
        }
    }

    /// The per-node probability vectors (after a run: the final state).
    pub fn node_probs(&self) -> Vec<Vec<f32>> {
        self.nodes.iter().map(|s| s.pv.probs().to_vec()).collect()
    }
}

impl Transport for PeerTransport<'_> {
    /// Nodes never consume a central broadcast — each trains on its own
    /// current p — so the engine skips encoding one (downlink is 0).
    fn wants_broadcast(&self) -> bool {
        false
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mask_bits = ctx.n as u64; // per directed edge (raw bit-packed)
        self.round_masks.iter_mut().for_each(|m| *m = None);
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut edge_costs = Vec::new();
        for &i in ctx.participants {
            let node = &mut self.nodes[i];
            node.reset_optimizer(&self.cfg.train);
            let mut loss = 0.0;
            for _ in 0..self.cfg.local_epochs {
                loss = node.run_epoch(&mut *self.exec, &self.shards[i], self.cfg.train.batch);
            }
            let mut rng =
                self.seeds.subtree("client", i as u64).rng("gossip-mask", ctx.round as u64);
            let mut mask = Vec::new();
            node.pv.sample_mask(&mut rng, &mut mask);
            let packed = pack_client_mask(&mask);
            // One mask per directed edge to a *participating* neighbour
            // (at full participation: the node's full degree) — each
            // billed as its own ledger edge row.
            let degree = bill_edges(self.topo, i, ctx.participants, mask_bits, &mut edge_costs);
            // `packed_mask` stays empty: only the engine's default
            // central aggregation reads it, and this transport overrides
            // `aggregate` to work from `round_masks` instead.
            contributions.push(Contribution {
                client: i,
                loss,
                up_bits: mask_bits * degree,
                packed_mask: Vec::new(),
            });
            self.round_masks[i] = Some(packed);
        }
        Ok(RoundTraffic { contributions, edge_costs, ..Default::default() })
    }

    /// Decentralized aggregation: node `i` averages its own mask with
    /// its participating neighbours' via a tiny per-node [`Server`]
    /// (`u32` mask sums are exact, so the division is bit-identical to
    /// an f32 accumulate); the engine's global probs become the
    /// consensus (node-average) vector.
    ///
    /// The consensus is refreshed every round (the legacy loop only
    /// built it on eval rounds) so the engine's shared eval path stays
    /// uniform; the O(k·n) average is noise next to the k local
    /// training epochs that precede it.
    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        let n = server.n();
        for c in &traffic.contributions {
            let i = c.client;
            let mut tiny = Server::new(vec![0.0; n]);
            tiny.receive_mask(self.round_masks[i].as_ref().expect("own mask present"));
            for &j in &self.topo.neighbors[i] {
                if let Some(m) = &self.round_masks[j] {
                    tiny.receive_mask(m);
                }
            }
            tiny.try_aggregate();
            self.nodes[i].pv.set_probs(&tiny.probs);
        }
        // Consensus over *all* nodes, in node order (fixed f32 order).
        server.probs = consensus_mean(self.nodes.iter().map(|s| s.pv.probs()), n);
        traffic.contributions.len()
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        &mut *self.exec
    }
}

/// Run decentralized Zampling over `topo` — a thin constructor over
/// [`RoundEngine`] + [`PeerTransport`].
pub fn run_gossip(
    cfg: &FedConfig,
    topo: &Topology,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> GossipOutcome {
    assert_eq!(shards.len(), topo.len(), "one shard per node");
    let k = topo.len();
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let n = cfg.train.n;

    // All nodes start from the shared-seed p(0) (same as centralized).
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(n, &mut init_rng).probs().to_vec();
    let nodes: Vec<LocalZampling> = (0..k)
        .map(|i| {
            let sub = seeds.subtree("client", i as u64);
            LocalZampling::from_parts(
                &cfg.train,
                Arc::clone(&q),
                Arc::clone(&csc),
                ProbVector::from_probs(p0.clone()),
                &sub,
            )
        })
        .collect();

    let engine = RoundEngine::new(
        cfg,
        k,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "gossip",
    );
    let mut transport = PeerTransport::new(cfg, topo, exec, shards, nodes);
    let mut policy = make_policy(cfg.policy);
    let out = engine
        .run(&mut transport, policy.as_mut())
        .expect("in-process transports are infallible");
    GossipOutcome {
        log: out.log,
        ledger: out.ledger,
        final_probs: out.final_probs,
        node_probs: transport.node_probs(),
    }
}

/// The wire-gossip [`Transport`]: the same decentralized protocol as
/// [`PeerTransport`], but every node is a **separate process** and masks
/// cross real sockets.
///
/// Topology of processes:
///
/// * each peer (`repro serve-peer --node-id i`) runs a **tiny
///   [`Leader`] for its graph neighbours** — its own listener and
///   event-loop sweeper, the shared event channel,
///   per-round deadlines with heartbeat extension, connection
///   generations, and reconnect-with-`Hello`, all inherited from the
///   TCP leader via [`Leader::from_listener_subset`] — and dials every
///   neighbour's tiny leader as a [`Worker`], so each undirected
///   topology edge is two TCP connections carrying one `Mask` frame per
///   round in each direction;
/// * this transport is the **coordinator** (`repro train-federated
///   --transport gossip-tcp`): a full [`Leader`] over all `k` peers
///   that kicks every round off with a `PeerRound` frame (round index +
///   participant set — no probabilities travel) and collects one
///   `Report` per participant (local loss + post-aggregation node
///   probs), from which it maintains the consensus vector the engine
///   evaluates.  Coordination frames are never billed; the billed
///   gossip traffic is `n` bits per live directed edge, recorded per
///   edge in the ledger's `edge_rounds` table — exactly
///   [`PeerTransport`]'s `num_messages()` cost model.
///
/// With every peer alive the run is **byte-identical** to the
/// in-process [`PeerTransport`] at the same seed and topology (pinned
/// over loopback sockets in `tests/federated_integration.rs`).  A peer
/// that dies mid-run is dropped by the coordinator's report collection
/// *and* by its neighbours' mask collections, whose tiny servers then
/// renormalize over whatever arrived — the decentralized analogue of
/// the leader's drop semantics.
///
/// # Example
///
/// A three-node ring over loopback: three peer processes (threads
/// here) gossip masks over real sockets while the coordinator drives
/// one engine round end to end.
///
/// ```
/// use std::net::TcpListener;
/// use zampling::config::FedConfig;
/// use zampling::data::Dataset;
/// use zampling::federated::gossip::{run_gossip_wire, run_peer, Topology};
/// use zampling::nn::ArchSpec;
/// use zampling::rng::SeedTree;
/// use zampling::zampling::NativeExecutor;
///
/// let mut cfg = FedConfig::paper(8);
/// cfg.train.arch = ArchSpec::small();
/// cfg.train.n = ArchSpec::small().num_params() / 8;
/// cfg.train.d = 3;
/// cfg.clients = 3;
/// cfg.rounds = 1;
/// cfg.local_epochs = 1;
/// let seeds = SeedTree::new(cfg.train.seed);
/// let (train, test) = Dataset::synthetic_pair(96, 32, &seeds);
/// let shards = train.partition_iid(cfg.clients, &seeds);
/// let topo = Topology::ring(cfg.clients);
///
/// // Bind everything up front (no connect races), then launch peers.
/// let coord = TcpListener::bind("127.0.0.1:0").unwrap();
/// let coord_addr = coord.local_addr().unwrap().to_string();
/// let listeners: Vec<TcpListener> =
///     (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
/// let addrs: Vec<String> =
///     listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
/// let peers: Vec<_> = listeners
///     .into_iter()
///     .enumerate()
///     .map(|(i, listener)| {
///         let (cfg, topo, addrs, coord_addr, shard) =
///             (cfg.clone(), topo.clone(), addrs.clone(), coord_addr.clone(), shards[i].clone());
///         std::thread::spawn(move || {
///             let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 32);
///             run_peer(&cfg, &topo, i, listener, &addrs, &coord_addr, &mut exec, &shard, None)
///                 .unwrap();
///         })
///     })
///     .collect();
///
/// let exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 32);
/// let out = run_gossip_wire(&cfg, &topo, coord, &test, 1, 1, Box::new(exec), false).unwrap();
/// assert_eq!(out.node_probs.len(), 3);
/// assert_eq!(out.ledger.edge_rounds[0].len(), topo.num_messages());
/// for p in peers {
///     p.join().unwrap();
/// }
/// ```
pub struct WirePeerTransport {
    topo: Topology,
    leader: Leader,
    exec: Box<dyn DenseExecutor>,
    /// Last reported probability vector per node (initialized to the
    /// shared-seed `p(0)`); non-participants and dropped peers keep
    /// their previous entry, exactly like an in-process node whose
    /// state nobody touched this round.
    node_probs: Vec<Vec<f32>>,
}

impl WirePeerTransport {
    /// Bind `addr` and wait for all `topo.len()` peers to `Hello`.
    pub fn accept(
        addr: &str,
        topo: Topology,
        init_probs: Vec<f32>,
        exec: Box<dyn DenseExecutor>,
    ) -> Result<Self> {
        use crate::util::error::Context;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding coordinator {addr}"))?;
        Self::from_listener(listener, topo, init_probs, exec)
    }

    /// Race-free entry point over a pre-bound coordinator listener:
    /// blocks until every one of the topology's nodes has completed a
    /// `Hello` handshake.
    pub fn from_listener(
        listener: TcpListener,
        topo: Topology,
        init_probs: Vec<f32>,
        exec: Box<dyn DenseExecutor>,
    ) -> Result<Self> {
        ensure!(!topo.is_empty(), "gossip topology has no nodes");
        let leader = Leader::from_listener(listener, topo.len())?;
        let node_probs = vec![init_probs; topo.len()];
        Ok(Self { topo, leader, exec, node_probs })
    }

    /// Every node's last reported probability vector.
    pub fn node_probs(&self) -> Vec<Vec<f32>> {
        self.node_probs.clone()
    }

    /// The coordinator-side connection registry (byte counters live
    /// here; this traffic is coordination, never billed to the ledger).
    pub fn leader(&self) -> &Leader {
        &self.leader
    }
}

impl Transport for WirePeerTransport {
    /// Like [`PeerTransport`]: peers never consume a central broadcast
    /// of `p` — the coordinator ships only the tiny `PeerRound`
    /// coordination frame — so the engine skips encoding one and the
    /// ledger's downlink column stays 0.
    fn wants_broadcast(&self) -> bool {
        false
    }

    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let frame = encode_server(&ServerMsg::PeerRound {
            round: ctx.round,
            participants: ctx.participants.iter().map(|&p| p as u32).collect(),
        });
        self.leader.broadcast_frame(&frame, ctx.participants)?;
        let receipt =
            self.leader.collect_reports(ctx.round, ctx.participants, ctx.n, ctx.deadline)?;

        let mask_bits = ctx.n as u64;
        let mut contributions = Vec::with_capacity(receipt.received.len());
        let mut edge_costs = Vec::new();
        let mut reports = receipt.reports;
        for &i in &receipt.received {
            let rep = reports[i].take().expect("received report present");
            self.node_probs[i] = rep.probs;
            // Per-directed-edge accounting, identical to the in-process
            // transport: one n-bit mask per *participating* neighbour.
            // Billing is keyed to the sender's round report, matching
            // the centralized convention that a dropped client's round
            // traffic never hits the ledger; an edge toward a neighbour
            // that died mid-round IS billed — the bits left the sender.
            let degree = bill_edges(&self.topo, i, ctx.participants, mask_bits, &mut edge_costs);
            contributions.push(Contribution {
                client: i,
                loss: rep.loss,
                up_bits: mask_bits * degree,
                packed_mask: Vec::new(),
            });
        }
        Ok(RoundTraffic {
            contributions,
            dropped: receipt.dropped,
            edge_costs,
            ..Default::default()
        })
    }

    /// Consensus over the last known probability vector of *all* nodes,
    /// in node order — the same fixed f32 summation as
    /// [`PeerTransport::aggregate`], so the engine's evaluation (and
    /// `final_probs`) stay byte-identical to the in-process run.
    fn aggregate(&mut self, server: &mut Server, traffic: &RoundTraffic) -> usize {
        let n = server.n();
        server.probs = consensus_mean(self.node_probs.iter().map(|v| v.as_slice()), n);
        traffic.contributions.len()
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.exec.as_mut()
    }

    fn finish(&mut self) -> Result<()> {
        self.leader.shutdown()
    }
}

/// Run decentralized Zampling over real sockets: the [`RoundEngine`]
/// over a [`WirePeerTransport`], coordinating `topo.len()` `run_peer`
/// processes — the wire twin of [`run_gossip`], byte-identical to it
/// when every peer stays alive.
#[allow(clippy::too_many_arguments)]
pub fn run_gossip_wire(
    cfg: &FedConfig,
    topo: &Topology,
    listener: TcpListener,
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    exec: Box<dyn DenseExecutor>,
    verbose: bool,
) -> Result<GossipOutcome> {
    let k = topo.len();
    ensure!(k == cfg.clients, "topology has {k} nodes for {} clients", cfg.clients);
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();

    let mut transport = WirePeerTransport::from_listener(listener, topo.clone(), p0.clone(), exec)?;
    let engine = RoundEngine::new(
        cfg,
        k,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "federated_gossip",
    )
    .verbose(verbose);
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut())?;
    Ok(GossipOutcome {
        log: out.log,
        ledger: out.ledger,
        final_probs: out.final_probs,
        node_probs: transport.node_probs(),
    })
}

/// The gossip peer process body (`repro serve-peer`): run node
/// `node_id`'s side of every wire-gossip round until the coordinator
/// broadcasts `Shutdown`.
///
/// Startup is dial-then-accept and therefore launch-order-free: the
/// caller binds this node's own listener first, the peer dials every
/// neighbour with retry (the `Hello`s land in the OS backlog even
/// before the remote acceptors drain them), blocks in
/// [`Leader::from_listener_subset`] for its own neighbours'
/// handshakes, and announces itself to the coordinator **last** — so
/// round 0 cannot start anywhere until every peer's tiny leader is
/// ready to collect masks.
///
/// Per `PeerRound` the peer trains on its own `p` (heartbeating the
/// coordinator between local epochs), samples its mask from the
/// `"gossip-mask"` seed stream, ships it to every participating
/// neighbour, collects theirs under the configured deadline, averages
/// own + received masks through a tiny [`Server`] (renormalizing over
/// whatever arrived if a neighbour died), and reports its loss +
/// post-aggregation probs to the coordinator.
///
/// `die_after_round` is the chaos knob for tests and CI: the peer
/// exits cleanly right after reporting that round, simulating a
/// mid-run crash for every party still running.
#[allow(clippy::too_many_arguments)]
pub fn run_peer(
    cfg: &FedConfig,
    topo: &Topology,
    node_id: usize,
    listener: TcpListener,
    peer_addrs: &[String],
    coordinator: &str,
    exec: &mut dyn DenseExecutor,
    shard: &Dataset,
    die_after_round: Option<u32>,
) -> Result<()> {
    let k = topo.len();
    ensure!(node_id < k, "node id {node_id} ≥ topology size {k}");
    ensure!(peer_addrs.len() == k, "{} peer addresses for {k} nodes", peer_addrs.len());
    let n = cfg.train.n;
    let neighbors = &topo.neighbors[node_id];

    // Identical shared-seed state to every other party (coordinator,
    // in-process simulator): Q, p(0), this node's per-client subtree.
    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(n, &mut init_rng).probs().to_vec();
    let sub = seeds.subtree("client", node_id as u64);
    let mut node = LocalZampling::from_parts(
        &cfg.train,
        Arc::clone(&q),
        Arc::clone(&csc),
        ProbVector::from_probs(p0),
        &sub,
    );

    // Startup order matters: dial every neighbour first (their `Hello`s
    // land in bound backlogs, so no peer can block another), then bring
    // this node's own tiny leader fully up, and only *then* announce
    // readiness to the coordinator.  The coordinator starts round 0 the
    // moment all k peers have said `Hello`, so a peer that greeted it
    // before its tiny leader finished accepting could have a fast
    // neighbour's round-0 mask land mid-startup — where the control
    // loop discards `Msg` events — and then hang waiting for a mask
    // that will never come again.
    let mut out_links: Vec<Option<Worker>> = (0..k).map(|_| None).collect();
    for &j in neighbors {
        out_links[j] = Some(Worker::connect_retry(
            &peer_addrs[j],
            node_id as u32,
            MaskCodec::Raw,
            PEER_CONNECT_TIMEOUT,
        )?);
    }
    // This node's tiny leader over exactly its neighbours (slots are
    // indexed by global node id; an isolated node skips the machinery).
    let mut tiny_leader = if neighbors.is_empty() {
        None
    } else {
        Some(Leader::from_listener_subset(listener, k, neighbors)?)
    };
    let mut coord =
        Worker::connect_retry(coordinator, node_id as u32, MaskCodec::Raw, PEER_CONNECT_TIMEOUT)?;
    let deadline = DeadlinePolicy::from_cfg(cfg);

    loop {
        let frame = coord.recv_raw()?;
        let (round, participants) = match peek_server_frame(&frame)? {
            ServerFrameKind::Shutdown => return Ok(()),
            ServerFrameKind::PeerRound => {
                let ServerMsg::PeerRound { round, participants } = decode_server(&frame)? else {
                    bail!("peer {node_id}: PeerRound peek/decode disagree");
                };
                let participants: Vec<usize> =
                    participants.into_iter().map(|p| p as usize).collect();
                if let Some(&bad) = participants.iter().find(|&&p| p >= k) {
                    bail!("peer {node_id}: participant id {bad} ≥ topology size {k}");
                }
                (round, participants)
            }
            ServerFrameKind::Round => {
                bail!("peer {node_id}: unexpected centralized Round frame on the gossip wire")
            }
        };
        if participants.binary_search(&node_id).is_err() {
            continue; // not selected this round (defensive: not broadcast to us)
        }

        // Local training-by-sampling on this node's own p, heartbeating
        // the coordinator between epochs so its report deadline can be
        // extended for slow-but-alive peers.
        node.reset_optimizer(&cfg.train);
        let mut loss = 0.0;
        for epoch in 0..cfg.local_epochs {
            loss = node.run_epoch(exec, shard, cfg.train.batch);
            if epoch + 1 < cfg.local_epochs {
                // Beat the coordinator *and* every neighbour's tiny
                // leader, so both report and mask collection deadlines
                // can be heartbeat-extended for a slow-but-alive peer.
                // Like serve-client, beats only flow between local
                // epochs — extension needs local-epochs ≥ 2.
                let _ = coord.send_heartbeat();
                for &j in neighbors {
                    if let Some(w) = out_links[j].as_mut() {
                        let _ = w.send_heartbeat();
                    }
                }
            }
        }
        let mut rng = seeds.subtree("client", node_id as u64).rng("gossip-mask", round as u64);
        let mut mask = Vec::new();
        node.pv.sample_mask(&mut rng, &mut mask);

        // Gossip: ship the mask to every participating neighbour (a
        // failed send means that neighbour is dead — its own collection
        // below renormalizes without us, so we just carry on), then
        // collect theirs under the deadline.
        let live: Vec<usize> = neighbors
            .iter()
            .copied()
            .filter(|j| participants.binary_search(j).is_ok())
            .collect();
        for &j in &live {
            if let Some(w) = out_links[j].as_mut() {
                let _ = w.send_mask(round, mask.clone());
            }
        }
        // Average own + received masks through a tiny per-node Server —
        // the exact aggregation (and u32 → f32 division) the in-process
        // transport runs, renormalized over whatever actually arrived.
        let mut tiny = Server::new(vec![0.0; n]);
        tiny.receive_mask(&pack_client_mask(&mask));
        if let (Some(leader), false) = (tiny_leader.as_mut(), live.is_empty()) {
            // About to block for up to a full mask deadline: prove
            // liveness to the coordinator first, so (with a configured
            // round-timeout-max-ms cap) its report deadline extends by
            // one more timeout to cover this nested wait.  This bounds
            // — it does not fully eliminate — the cascade where a
            // *stalled* neighbour makes the coordinator drop the live
            // peers merely waiting on it; see docs/GOSSIP.md
            // §"Deadline composition" for the sizing rule.
            let _ = coord.send_heartbeat();
            let receipt = leader.collect_masks(round, &live, n, deadline)?;
            for &j in neighbors {
                if let Some(m) = &receipt.masks[j] {
                    tiny.receive_mask(&pack_client_mask(m));
                }
            }
        }
        tiny.try_aggregate();
        node.pv.set_probs(&tiny.probs);

        // Report loss + post-aggregation probs to the coordinator.
        coord.send_frame(&encode_client(
            &ClientMsg::Report {
                round,
                client: node_id as u32,
                loss,
                probs: node.pv.probs().to_vec(),
            },
            MaskCodec::Raw,
        ))?;

        if die_after_round == Some(round) {
            return Ok(()); // chaos knob: simulate a mid-run crash
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::RoundCost;
    use crate::metrics::RoundRecord;
    use crate::nn::ArchSpec;
    use crate::zampling::{evaluate, NativeExecutor};

    fn ci_setup() -> (FedConfig, Vec<Dataset>, Dataset) {
        let mut cfg = FedConfig::paper(8);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params() / 8;
        cfg.train.d = 5;
        cfg.train.lr = 0.1;
        cfg.train.seed = 1;
        cfg.clients = 4;
        cfg.rounds = 6;
        cfg.local_epochs = 1;
        let seeds = SeedTree::new(cfg.train.seed);
        let (train, test) = Dataset::synthetic_pair(1_024, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        (cfg, shards, test)
    }

    #[test]
    fn topologies_are_well_formed() {
        for topo in [Topology::complete(5), Topology::ring(5), Topology::star(5)] {
            assert_eq!(topo.len(), 5);
            for (i, ns) in topo.neighbors.iter().enumerate() {
                for &j in ns {
                    assert_ne!(i, j);
                    assert!(topo.neighbors[j].contains(&i), "graph not symmetric");
                }
            }
        }
        assert_eq!(Topology::complete(5).num_messages(), 20);
        assert_eq!(Topology::ring(5).num_messages(), 10);
        assert_eq!(Topology::star(5).num_messages(), 8);
    }

    #[test]
    fn topology_validation_rejects_malformed_adjacency() {
        // a valid custom graph canonicalizes neighbour order
        let topo = Topology::from_neighbors(vec![vec![2, 1], vec![0], vec![0]]).unwrap();
        assert_eq!(topo.neighbors[0], vec![1, 2]);
        // self-loops, out-of-range ids, asymmetric edges, duplicates
        assert!(Topology::from_neighbors(vec![vec![0], vec![]]).is_err());
        assert!(Topology::from_neighbors(vec![vec![5], vec![0]]).is_err());
        assert!(Topology::from_neighbors(vec![vec![1], vec![]]).is_err());
        assert!(Topology::from_neighbors(vec![vec![1, 1], vec![0, 0]]).is_err());
        // named kinds reject degenerate sizes instead of panicking
        assert!(Topology::from_kind(TopologyKind::Ring, 1).is_err());
        assert!(Topology::from_kind(TopologyKind::Star, 1).is_err());
        assert!(Topology::from_kind(TopologyKind::Complete, 0).is_err());
        assert_eq!(Topology::from_kind(TopologyKind::Ring, 5).unwrap().num_messages(), 10);
    }

    #[test]
    fn gossip_edge_ledger_reconciles_with_uplink_totals() {
        let (cfg, shards, test) = ci_setup();
        let topo = Topology::ring(cfg.clients);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let out = run_gossip(&cfg, &topo, &mut exec, &shards, &test, 2, 3);
        assert_eq!(out.ledger.edge_rounds.len(), out.ledger.rounds.len());
        for (round, edges) in out.ledger.rounds.iter().zip(&out.ledger.edge_rounds) {
            assert_eq!(edges.len(), topo.num_messages());
            assert_eq!(edges.iter().map(|e| e.bits).sum::<u64>(), round.uplink_bits);
        }
        assert_eq!(out.ledger.total_edge_bits(), out.ledger.total_uplink_bits());
        // every node sends and receives its ring degree's worth of bits
        for (sent, recv) in out.ledger.node_edge_totals(cfg.clients) {
            assert_eq!(sent, cfg.rounds as u64 * 2 * cfg.train.n as u64);
            assert_eq!(recv, sent);
        }
    }

    #[test]
    fn gossip_learns_on_ring_and_complete() {
        let (cfg, shards, test) = ci_setup();
        for topo in [Topology::complete(cfg.clients), Topology::ring(cfg.clients)] {
            let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let out = run_gossip(&cfg, &topo, &mut exec, &shards, &test, 6, 2);
            let first = out.log.rounds.first().unwrap().mean_sampled_acc;
            let last = out.log.rounds.last().unwrap().mean_sampled_acc;
            assert!(last > first, "no improvement on {topo:?}: {first} → {last}");
            assert!(last > 0.3, "failed to learn on {topo:?}: {last}");
        }
    }

    #[test]
    fn ring_uses_less_traffic_than_complete() {
        let (cfg, shards, test) = ci_setup();
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let complete = run_gossip(
            &cfg,
            &Topology::complete(cfg.clients),
            &mut e1,
            &shards,
            &test,
            2,
            5,
        );
        let ring =
            run_gossip(&cfg, &Topology::ring(cfg.clients), &mut e2, &shards, &test, 2, 5);
        assert!(ring.ledger.total_uplink_bits() < complete.ledger.total_uplink_bits());
    }

    #[test]
    fn nodes_drift_apart_on_sparse_graphs_but_not_complete() {
        let (cfg, shards, test) = ci_setup();
        let spread = |probs: &[Vec<f32>]| -> f64 {
            // max pairwise L2 distance between node vectors
            let mut worst = 0.0f64;
            for a in probs {
                for b in probs {
                    let d: f64 = a
                        .iter()
                        .zip(b)
                        .map(|(&x, &y)| ((x - y) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt();
                    worst = worst.max(d);
                }
            }
            worst
        };
        let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let complete = run_gossip(
            &cfg,
            &Topology::complete(cfg.clients),
            &mut e1,
            &shards,
            &test,
            2,
            5,
        );
        // Complete graph: all nodes average the same masks → identical p.
        assert!(spread(&complete.node_probs) < 1e-6, "{}", spread(&complete.node_probs));
        let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
        let ring =
            run_gossip(&cfg, &Topology::ring(cfg.clients), &mut e2, &shards, &test, 2, 5);
        assert!(spread(&ring.node_probs) > spread(&complete.node_probs));
    }

    /// Replica of the pre-engine `run_gossip` loop (the seed's gossip
    /// driver), built from public API pieces.  The engine-based driver
    /// must reproduce it byte-for-byte: node probs, ledger rows, and
    /// log records — the gossip leg of the "no behavior change at
    /// defaults" guarantee.
    fn legacy_gossip_driver(
        cfg: &FedConfig,
        topo: &Topology,
        exec: &mut dyn DenseExecutor,
        shards: &[Dataset],
        test: &Dataset,
        eval_samples: usize,
        eval_every: usize,
    ) -> GossipOutcome {
        use crate::nn::one_hot_into;

        let k = topo.len();
        let seeds = SeedTree::new(cfg.train.seed);
        let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
        let csc = Arc::new(q.to_csc(None));
        let n = cfg.train.n;
        let mut init_rng = seeds.rng("p-init", 0);
        let p0 = ProbVector::init_uniform(n, &mut init_rng).probs().to_vec();
        let mut nodes: Vec<LocalZampling> = (0..k)
            .map(|i| {
                let sub = seeds.subtree("client", i as u64);
                LocalZampling::from_parts(
                    &cfg.train,
                    Arc::clone(&q),
                    Arc::clone(&csc),
                    ProbVector::from_probs(p0.clone()),
                    &sub,
                )
            })
            .collect();

        let out_dim = exec.arch().output_dim();
        let mut test_y1h = vec![0.0f32; test.len() * out_dim];
        one_hot_into(&test.y, out_dim, &mut test_y1h);
        let mut eval_rng = seeds.rng("eval-sampler", 0);
        let mut log = RunLog::new("gossip");
        let mut ledger = CommLedger::default();
        let mask_bits = n as u64;

        for round in 0..cfg.rounds {
            let mut masks: Vec<Vec<bool>> = Vec::with_capacity(k);
            let mut round_loss = 0.0f64;
            for (i, node) in nodes.iter_mut().enumerate() {
                node.reset_optimizer(&cfg.train);
                let mut loss = 0.0;
                for _ in 0..cfg.local_epochs {
                    loss = node.run_epoch(exec, &shards[i], cfg.train.batch);
                }
                round_loss += loss;
                let mut rng =
                    seeds.subtree("client", i as u64).rng("gossip-mask", round as u64);
                let mut mask = Vec::new();
                node.pv.sample_mask(&mut rng, &mut mask);
                masks.push(mask);
            }
            let mut new_probs: Vec<Vec<f32>> = Vec::with_capacity(k);
            for i in 0..k {
                let mut acc: Vec<f32> = masks[i].iter().map(|&b| b as u8 as f32).collect();
                for &j in &topo.neighbors[i] {
                    for (a, &b) in acc.iter_mut().zip(&masks[j]) {
                        *a += b as u8 as f32;
                    }
                }
                let denom = (topo.neighbors[i].len() + 1) as f32;
                for a in acc.iter_mut() {
                    *a /= denom;
                }
                new_probs.push(acc);
            }
            for (node, p) in nodes.iter_mut().zip(&new_probs) {
                node.pv.set_probs(p);
            }
            ledger.record(RoundCost {
                uplink_bits: mask_bits * topo.num_messages() as u64,
                downlink_bits: 0,
                clients: k as u32,
                participants: k as u32,
                dropped: 0,
                // Sequentially-simulated nodes: no transport ran, so
                // there is no honest wall clock to attribute.
                wall_ns: 0,
            });
            if round % eval_every == 0 || round + 1 == cfg.rounds {
                let mut consensus = vec![0.0f32; n];
                for node in &nodes {
                    for (c, &p) in consensus.iter_mut().zip(node.pv.probs()) {
                        *c += p;
                    }
                }
                for c in consensus.iter_mut() {
                    *c /= k as f32;
                }
                let pv = ProbVector::from_probs(consensus);
                let rep = evaluate(
                    exec,
                    &q,
                    &pv,
                    &test.x,
                    &test_y1h,
                    test.len(),
                    eval_samples,
                    &mut eval_rng,
                );
                log.push(RoundRecord {
                    round,
                    mean_sampled_acc: rep.mean_sampled_acc,
                    sampled_acc_std: rep.sampled_acc_std,
                    expected_acc: rep.expected_acc,
                    train_loss: round_loss / k as f64,
                    uplink_bits: mask_bits * topo.num_messages() as u64,
                    downlink_bits: 0,
                });
            }
        }
        let node_probs: Vec<Vec<f32>> =
            nodes.into_iter().map(|s| s.pv.probs().to_vec()).collect();
        let mut final_probs = vec![0.0f32; n];
        for node in &node_probs {
            for (c, &p) in final_probs.iter_mut().zip(node) {
                *c += p;
            }
        }
        for c in final_probs.iter_mut() {
            *c /= k as f32;
        }
        GossipOutcome { log, ledger, final_probs, node_probs }
    }

    #[test]
    fn engine_gossip_is_byte_identical_to_the_legacy_driver() {
        let (cfg, shards, test) = ci_setup();
        for topo in [Topology::ring(cfg.clients), Topology::star(cfg.clients)] {
            let mut e1 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let legacy =
                legacy_gossip_driver(&cfg, &topo, &mut e1, &shards, &test, 3, 2);
            let mut e2 = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
            let new = run_gossip(&cfg, &topo, &mut e2, &shards, &test, 3, 2);
            assert_eq!(new.node_probs, legacy.node_probs, "node probs diverged on {topo:?}");
            assert_eq!(new.final_probs, legacy.final_probs, "consensus diverged on {topo:?}");
            assert_eq!(new.ledger.rounds.len(), legacy.ledger.rounds.len());
            for (a, b) in new.ledger.rounds.iter().zip(&legacy.ledger.rounds) {
                assert_eq!(a.uplink_bits, b.uplink_bits);
                assert_eq!(a.downlink_bits, b.downlink_bits);
                assert_eq!(a.clients, b.clients);
                assert_eq!(a.participants, b.participants);
                assert_eq!(a.dropped, b.dropped);
            }
            assert_eq!(new.log.rounds.len(), legacy.log.rounds.len());
            for (a, b) in new.log.rounds.iter().zip(&legacy.log.rounds) {
                assert_eq!(a.round, b.round);
                assert_eq!(a.mean_sampled_acc, b.mean_sampled_acc, "round {}", a.round);
                assert_eq!(a.sampled_acc_std, b.sampled_acc_std, "round {}", a.round);
                assert_eq!(a.expected_acc, b.expected_acc, "round {}", a.round);
                assert_eq!(a.train_loss, b.train_loss, "round {}", a.round);
                assert_eq!(a.uplink_bits, b.uplink_bits);
                assert_eq!(a.downlink_bits, b.downlink_bits);
            }
        }
    }
}
