//! Wire protocol: framed messages shared by the in-process simulator and
//! the TCP transport.
//!
//! Frame layout: `[tag: u8][len: u32 le][payload: len bytes]`.
//! The byte counts the ledger records are exactly `frame_len(msg)`.
#![cfg_attr(
    not(test),
    deny(clippy::cast_possible_truncation, clippy::cast_sign_loss, clippy::unwrap_used)
)]

use crate::comm::{arith, BitPack, FloatVec};
use crate::util::error::Result;
use crate::{anyhow, bail};

/// How the client mask is encoded on the uplink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskCodec {
    /// Raw packed bits: exactly `⌈n/64⌉·8` bytes — the paper's "n bits".
    Raw,
    /// Adaptive arithmetic coding (≈ H(p̂)·n bits — the Isik-style rate).
    Arithmetic,
}

/// Server → client.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerMsg {
    /// Start round `round` with the current global probabilities.
    Round {
        /// The round index.
        round: u32,
        /// The global probability vector `p(t)`.
        probs: Vec<f32>,
    },
    /// Gossip round kick-off (coordinator → peer): no probabilities
    /// travel — each peer trains on its **own** `p` — only the round
    /// index and the round's participant set, which tells every peer
    /// which of its topology edges are live this round.  A coordination
    /// frame: never billed to the comm ledger (see `docs/GOSSIP.md`).
    PeerRound {
        /// The round index.
        round: u32,
        /// This round's participating node ids, strictly ascending.
        participants: Vec<u32>,
    },
    /// Training is over; workers exit.
    Shutdown,
}

/// Client → server.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMsg {
    /// The sampled mask for `round` (encoded per `codec`).
    Mask {
        /// The round the mask belongs to.
        round: u32,
        /// The sender's client id (must match its `Hello`).
        client: u32,
        /// Mask length — must equal the model's `n`.
        n: usize,
        /// The sampled Bernoulli mask.
        mask: Vec<bool>,
    },
    /// Worker greets with its client id (TCP handshake; also the
    /// reconnect path after a dropped connection).
    Hello {
        /// The registering client id.
        client: u32,
    },
    /// Worker is leaving for good — the leader marks it dropped
    /// immediately instead of waiting for a read error or deadline.
    Abort {
        /// The departing client id.
        client: u32,
    },
    /// Liveness ping: proves the connection is up without contributing
    /// to any round.  The leader consumes and ignores it.
    Heartbeat {
        /// The pinging client id.
        client: u32,
    },
    /// Gossip round report (peer → coordinator): the peer's local
    /// training loss and its **post-aggregation** probability vector,
    /// from which the coordinator maintains the consensus (node-average)
    /// state the engine evaluates.  Like `PeerRound` this is
    /// coordination traffic, never billed to the ledger — the billed
    /// gossip traffic is the `n` bits per directed edge the `Mask`
    /// frames carry between peers.
    Report {
        /// The round the report belongs to.
        round: u32,
        /// The reporting node's id (must match its `Hello`).
        client: u32,
        /// Final local training loss this round.
        loss: f64,
        /// The node's probability vector after neighbour aggregation
        /// (every entry must be finite and in `[0, 1]`).
        probs: Vec<f32>,
    },
}

/// Shard leader → root: the merge frames of the sharded aggregation
/// topology (`federated::transport::ShardedTransport`).
///
/// A shard leader never forwards its workers' masks upward — it folds
/// them into a per-entry **vote sum** and ships that one frame, so the
/// root's merge traffic is `~32n` bits per shard per round regardless of
/// how many clients the shard serves.  Vote sums merge additively
/// (`u32` adds are exact), which is what keeps sharded aggregation
/// byte-identical to a single leader after `Server::try_aggregate`
/// renormalizes.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardMsg {
    /// Shard `shard`'s partial aggregation state for `round`: per-entry
    /// vote sums over the `received` masks its leader collected.
    ShardVotes {
        /// Shard index (0-based, matching `ShardPlan::range`).
        shard: u32,
        /// Round the votes belong to.
        round: u32,
        /// How many masks the sums fold in (the renormalization weight
        /// this shard contributes; 0 for a fully-dropped shard).
        received: u32,
        /// Mask length — must equal the model's `n`.
        n: usize,
        /// Per-entry counts of 1-bits across the shard's received masks;
        /// each entry is ≤ `received` by construction.
        votes: Vec<u32>,
    },
}

/// Upper bound on a wire-supplied mask length.  The decoder allocates
/// `n` entries before decoding, and the arithmetic codec can expand a
/// few bytes into billions of zero bits, so `n` from the wire must be
/// capped or a hostile frame becomes a memory bomb.  16M entries is
/// ~60× the paper's largest model (MnistFc m = 266,610).
pub const MAX_MASK_LEN: usize = 1 << 24;

/// Upper bound on a wire-supplied `PeerRound` participant count.  The
/// decoder allocates the id vector before reading it, so a forged count
/// must be capped before allocation — 2²⁰ nodes is far past any gossip
/// graph this stack will ever coordinate.
pub const MAX_PEER_COUNT: usize = 1 << 20;

const TAG_ROUND: u8 = 1;
const TAG_SHUTDOWN: u8 = 2;
const TAG_MASK_RAW: u8 = 3;
const TAG_MASK_ARITH: u8 = 4;
const TAG_HELLO: u8 = 5;
const TAG_ABORT: u8 = 6;
const TAG_HEARTBEAT: u8 = 7;
const TAG_SHARD_VOTES: u8 = 8;
const TAG_PEER_ROUND: u8 = 9;
const TAG_PEER_REPORT: u8 = 10;

/// Read a little-endian `u32` at byte offset `off` of `p`.  Errors —
/// never panics — on a short slice: every decoder bounds-checks its
/// payload length up front, so a failure here is a decoder bug, and the
/// leader's policy for *any* bad frame is drop-the-connection, not
/// panic (the `xtask analyze` panic-lint enforces this file stays
/// `unwrap`-free; see docs/ANALYSIS.md).
fn le_u32(p: &[u8], off: usize) -> Result<u32> {
    match p.get(off..off + 4) {
        Some(b) => {
            let mut a = [0u8; 4];
            a.copy_from_slice(b);
            Ok(u32::from_le_bytes(a))
        }
        None => Err(anyhow!("truncated u32 field at offset {off} of a {}-byte payload", p.len())),
    }
}

/// Read a little-endian `f64` at byte offset `off` of `p` (same
/// never-panics contract as [`le_u32`]).
fn le_f64(p: &[u8], off: usize) -> Result<f64> {
    match p.get(off..off + 8) {
        Some(b) => {
            let mut a = [0u8; 8];
            a.copy_from_slice(b);
            Ok(f64::from_le_bytes(a))
        }
        None => Err(anyhow!("truncated f64 field at offset {off} of a {}-byte payload", p.len())),
    }
}

/// The payload length a 5-byte frame header (`[tag][len: u32 le]`)
/// declares.  Shared by every streaming reader (`read_frame`, the
/// sweeper's incremental reassembly) so the length decode itself can
/// never panic on a short buffer.
pub(crate) fn declared_frame_len(header: &[u8]) -> Result<usize> {
    Ok(le_u32(header, 1)? as usize)
}

/// Narrow an in-memory length/count to a `u32` wire field, checked.
/// Encoders only — wire input never reaches this.
#[allow(clippy::missing_panics_doc)]
pub(crate) fn wire_u32(v: usize) -> u32 {
    // lint: allow(panic) — encoder-local invariant, not wire data: every
    // value encoded through this helper (payload length, mask length,
    // participant count, shard id) is bounded by a protocol cap
    // (`MAX_FRAME_LEN`, `MAX_MASK_LEN`, `MAX_PEER_COUNT`) or by the
    // in-memory population before it gets here, so the narrowing can
    // only fail on a programming error on *our* side of the wire.
    u32::try_from(v).expect("value exceeds a u32 wire field")
}

fn frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(tag);
    out.extend_from_slice(&wire_u32(payload.len()).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Encode a server message.
pub fn encode_server(msg: &ServerMsg) -> Vec<u8> {
    match msg {
        ServerMsg::Round { round, probs } => {
            let mut payload = Vec::with_capacity(4 + probs.len() * 4);
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&FloatVec::encode(probs));
            frame(TAG_ROUND, &payload)
        }
        ServerMsg::PeerRound { round, participants } => {
            let mut payload = Vec::with_capacity(8 + participants.len() * 4);
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&wire_u32(participants.len()).to_le_bytes());
            for id in participants {
                payload.extend_from_slice(&id.to_le_bytes());
            }
            frame(TAG_PEER_ROUND, &payload)
        }
        ServerMsg::Shutdown => frame(TAG_SHUTDOWN, &[]),
    }
}

/// Encode a client message with the chosen mask codec.
pub fn encode_client(msg: &ClientMsg, codec: MaskCodec) -> Vec<u8> {
    match msg {
        ClientMsg::Mask { round, client, n, mask } => {
            debug_assert_eq!(mask.len(), *n);
            let (tag, body) = match codec {
                MaskCodec::Raw => (TAG_MASK_RAW, BitPack::encode(mask)),
                MaskCodec::Arithmetic => (TAG_MASK_ARITH, arith::encode(mask)),
            };
            let mut payload = Vec::with_capacity(12 + body.len());
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&client.to_le_bytes());
            payload.extend_from_slice(&wire_u32(*n).to_le_bytes());
            payload.extend_from_slice(&body);
            frame(tag, &payload)
        }
        ClientMsg::Hello { client } => frame(TAG_HELLO, &client.to_le_bytes()),
        ClientMsg::Abort { client } => frame(TAG_ABORT, &client.to_le_bytes()),
        ClientMsg::Heartbeat { client } => frame(TAG_HEARTBEAT, &client.to_le_bytes()),
        ClientMsg::Report { round, client, loss, probs } => {
            let mut payload = Vec::with_capacity(20 + probs.len() * 4);
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&client.to_le_bytes());
            payload.extend_from_slice(&wire_u32(probs.len()).to_le_bytes());
            payload.extend_from_slice(&loss.to_le_bytes());
            payload.extend_from_slice(&FloatVec::encode(probs));
            frame(TAG_PEER_REPORT, &payload)
        }
    }
}

/// Encode a shard-merge message (fixed layout: `round`, `shard`,
/// `received`, `n`, then `n` little-endian `u32` vote sums).
pub fn encode_shard(msg: &ShardMsg) -> Vec<u8> {
    match msg {
        ShardMsg::ShardVotes { shard, round, received, n, votes } => {
            debug_assert_eq!(votes.len(), *n);
            let mut payload = Vec::with_capacity(16 + votes.len() * 4);
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&shard.to_le_bytes());
            payload.extend_from_slice(&received.to_le_bytes());
            payload.extend_from_slice(&wire_u32(*n).to_le_bytes());
            for v in votes {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            frame(TAG_SHARD_VOTES, &payload)
        }
    }
}

/// Decode a shard-merge frame, with the same hardening as the client
/// decoders: the wire-supplied `n` is capped (`MAX_MASK_LEN`) before the
/// vote vector is allocated, the body length must match `n` exactly, and
/// every vote sum must be ≤ `received` — a sum larger than the mask
/// count it claims to fold is arithmetically impossible and would skew
/// the renormalized mean, so it is rejected, never merged.
pub fn decode_shard(buf: &[u8]) -> Result<ShardMsg> {
    let (tag, p) = split_frame(buf)?;
    if tag != TAG_SHARD_VOTES {
        bail!("unexpected shard tag {tag}");
    }
    if p.len() < 16 {
        bail!("bad ShardVotes payload length {}", p.len());
    }
    let round = le_u32(p, 0)?;
    let shard = le_u32(p, 4)?;
    let received = le_u32(p, 8)?;
    let n = le_u32(p, 12)? as usize;
    if n > MAX_MASK_LEN {
        bail!("vote length {n} exceeds protocol maximum {MAX_MASK_LEN}");
    }
    if p.len() - 16 != n * 4 {
        bail!("ShardVotes body {} bytes, want {}", p.len() - 16, n * 4);
    }
    let mut votes = Vec::with_capacity(n);
    for chunk in p[16..].chunks_exact(4) {
        let v = le_u32(chunk, 0)?;
        if v > received {
            bail!("vote sum {v} exceeds received mask count {received}");
        }
        votes.push(v);
    }
    Ok(ShardMsg::ShardVotes { shard, round, received, n, votes })
}

/// Split one frame off the front of `buf`; returns `(tag, payload)`.
fn split_frame(buf: &[u8]) -> Result<(u8, &[u8])> {
    if buf.len() < 5 {
        bail!("truncated frame header ({} bytes)", buf.len());
    }
    let tag = buf[0];
    let len = declared_frame_len(buf)?;
    let payload = buf.get(5..5 + len).ok_or_else(|| anyhow!("truncated frame payload"))?;
    Ok((tag, payload))
}

/// Decode a server frame (strictly length-checked; see `encode_server`).
pub fn decode_server(buf: &[u8]) -> Result<ServerMsg> {
    let (tag, p) = split_frame(buf)?;
    match tag {
        TAG_ROUND => {
            if p.len() < 4 || (p.len() - 4) % 4 != 0 {
                bail!("bad Round payload length {}", p.len());
            }
            let round = le_u32(p, 0)?;
            Ok(ServerMsg::Round { round, probs: FloatVec::decode(&p[4..]) })
        }
        TAG_PEER_ROUND => {
            if p.len() < 8 {
                bail!("bad PeerRound payload length {}", p.len());
            }
            let round = le_u32(p, 0)?;
            let count = le_u32(p, 4)? as usize;
            if count > MAX_PEER_COUNT {
                bail!("participant count {count} exceeds protocol maximum {MAX_PEER_COUNT}");
            }
            if p.len() - 8 != count * 4 {
                bail!("PeerRound body {} bytes, want {}", p.len() - 8, count * 4);
            }
            let mut participants = Vec::with_capacity(count);
            for chunk in p[8..].chunks_exact(4) {
                let id = le_u32(chunk, 0)?;
                // Strictly ascending ⇒ sorted and duplicate-free: the
                // canonical form every consumer (binary_search over the
                // set) relies on, enforced at the wire boundary.
                if participants.last().is_some_and(|&prev| id <= prev) {
                    bail!("PeerRound participants not strictly ascending at id {id}");
                }
                participants.push(id);
            }
            Ok(ServerMsg::PeerRound { round, participants })
        }
        TAG_SHUTDOWN => Ok(ServerMsg::Shutdown),
        t => bail!("unexpected server tag {t}"),
    }
}

/// Strict 4-byte client-id payload shared by Hello/Abort/Heartbeat.
fn decode_client_id(p: &[u8], what: &str) -> Result<u32> {
    if p.len() != 4 {
        bail!("bad {what} payload length {} (want 4)", p.len());
    }
    le_u32(p, 0)
}

/// What a client frame claims to be, from a cheap header peek.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientFrameKind {
    /// A `Mask` uplink (either codec).
    Mask,
    /// A `Hello` handshake / reconnect.
    Hello,
    /// An explicit `Abort` departure.
    Abort,
    /// A liveness `Heartbeat`.
    Heartbeat,
    /// A gossip-round `Report` (peer → coordinator).
    Report,
}

/// What a server frame claims to be, from a cheap header peek.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerFrameKind {
    /// A `Round` broadcast carrying the global probabilities.
    Round,
    /// A gossip `PeerRound` kick-off carrying the participant set.
    PeerRound,
    /// The end-of-training `Shutdown`.
    Shutdown,
}

/// Header-only peek for server frames: workers route Round vs Shutdown
/// without materializing the probs vector (which `client_round` will
/// decode anyway).
pub fn peek_server_frame(buf: &[u8]) -> Result<ServerFrameKind> {
    let (tag, _p) = split_frame(buf)?;
    match tag {
        TAG_ROUND => Ok(ServerFrameKind::Round),
        TAG_PEER_ROUND => Ok(ServerFrameKind::PeerRound),
        TAG_SHUTDOWN => Ok(ServerFrameKind::Shutdown),
        t => bail!("unexpected server tag {t}"),
    }
}

/// Header-only peek: the frame's kind and claimed client id, **without**
/// decoding the mask body.  The leader's sweeper uses this to
/// route frames, so a small arithmetic-coded frame is only expanded
/// into its (up to `MAX_MASK_LEN`-entry) mask at collection time —
/// never amplified while sitting in the event queue.
pub fn peek_client_frame(buf: &[u8]) -> Result<(ClientFrameKind, u32)> {
    let (tag, p) = split_frame(buf)?;
    match tag {
        TAG_MASK_RAW | TAG_MASK_ARITH => {
            if p.len() < 12 {
                bail!("bad Mask payload length {}", p.len());
            }
            Ok((ClientFrameKind::Mask, le_u32(p, 4)?))
        }
        TAG_HELLO => Ok((ClientFrameKind::Hello, decode_client_id(p, "Hello")?)),
        TAG_ABORT => Ok((ClientFrameKind::Abort, decode_client_id(p, "Abort")?)),
        TAG_HEARTBEAT => Ok((ClientFrameKind::Heartbeat, decode_client_id(p, "Heartbeat")?)),
        TAG_PEER_REPORT => {
            if p.len() < 20 {
                bail!("bad Report payload length {}", p.len());
            }
            Ok((ClientFrameKind::Report, le_u32(p, 4)?))
        }
        t => bail!("unexpected client tag {t}"),
    }
}

/// Decode a client frame, expanding the mask body per its codec tag.
/// Every length is checked before allocation and a truncated arithmetic
/// body errors instead of decoding zeros (see `MAX_MASK_LEN`).
pub fn decode_client(buf: &[u8]) -> Result<ClientMsg> {
    let (tag, p) = split_frame(buf)?;
    match tag {
        TAG_MASK_RAW | TAG_MASK_ARITH => {
            if p.len() < 12 {
                bail!("bad Mask payload length {}", p.len());
            }
            let round = le_u32(p, 0)?;
            let client = le_u32(p, 4)?;
            let n = le_u32(p, 8)? as usize;
            if n > MAX_MASK_LEN {
                bail!("mask length {n} exceeds protocol maximum {MAX_MASK_LEN}");
            }
            let mask = if tag == TAG_MASK_RAW {
                if p.len() - 12 != BitPack::wire_bytes(n) {
                    bail!("raw mask body {} bytes, want {}", p.len() - 12, BitPack::wire_bytes(n));
                }
                BitPack::decode(&p[12..], n)
            } else {
                arith::decode(&p[12..], n)?
            };
            Ok(ClientMsg::Mask { round, client, n, mask })
        }
        TAG_HELLO => Ok(ClientMsg::Hello { client: decode_client_id(p, "Hello")? }),
        TAG_ABORT => Ok(ClientMsg::Abort { client: decode_client_id(p, "Abort")? }),
        TAG_HEARTBEAT => Ok(ClientMsg::Heartbeat { client: decode_client_id(p, "Heartbeat")? }),
        TAG_PEER_REPORT => {
            if p.len() < 20 {
                bail!("bad Report payload length {}", p.len());
            }
            let round = le_u32(p, 0)?;
            let client = le_u32(p, 4)?;
            let n = le_u32(p, 8)? as usize;
            if n > MAX_MASK_LEN {
                bail!("report length {n} exceeds protocol maximum {MAX_MASK_LEN}");
            }
            if p.len() - 20 != n * 4 {
                bail!("Report body {} bytes, want {}", p.len() - 20, n * 4);
            }
            // `loss` is advisory telemetry (it only feeds the run
            // log's train_loss column, never the model state), so it is
            // carried verbatim — a peer whose training honestly
            // diverged reports inf/NaN exactly like an in-process node
            // would log it, instead of being ejected as a protocol
            // violator.  The probs below DO feed the consensus mean and
            // are strictly validated.
            let loss = le_f64(p, 12)?;
            let probs = FloatVec::decode(&p[20..]);
            // A probability outside [0, 1] (or NaN) would poison the
            // coordinator's consensus mean: rejected, never averaged.
            if let Some(bad) = probs.iter().find(|v| !(0.0..=1.0).contains(*v)) {
                bail!("report probability {bad} outside [0, 1]");
            }
            Ok(ClientMsg::Report { round, client, loss, probs })
        }
        t => bail!("unexpected client tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn server_roundtrip() {
        let msg = ServerMsg::Round { round: 7, probs: vec![0.25, 0.5, 1.0] };
        assert_eq!(decode_server(&encode_server(&msg)).unwrap(), msg);
        assert_eq!(decode_server(&encode_server(&ServerMsg::Shutdown)).unwrap(), ServerMsg::Shutdown);
    }

    #[test]
    fn client_roundtrip_both_codecs() {
        let mut rng = Xoshiro256pp::seed_from(3);
        let mask: Vec<bool> = (0..517).map(|_| rng.bernoulli(0.3)).collect();
        let msg = ClientMsg::Mask { round: 2, client: 9, n: 517, mask };
        for codec in [MaskCodec::Raw, MaskCodec::Arithmetic] {
            assert_eq!(decode_client(&encode_client(&msg, codec)).unwrap(), msg);
        }
        let hello = ClientMsg::Hello { client: 4 };
        assert_eq!(decode_client(&encode_client(&hello, MaskCodec::Raw)).unwrap(), hello);
    }

    #[test]
    fn arithmetic_uplink_is_smaller_on_skewed_masks() {
        let mut rng = Xoshiro256pp::seed_from(4);
        // Interpreted (Miri-lane) runs shrink the mask; the 2× margin
        // already holds comfortably at 4k symbols.
        let n = if cfg!(miri) { 4_000 } else { 20_000 };
        let mask: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.05)).collect();
        let msg = ClientMsg::Mask { round: 0, client: 0, n: mask.len(), mask };
        let raw = encode_client(&msg, MaskCodec::Raw).len();
        let arith = encode_client(&msg, MaskCodec::Arithmetic).len();
        assert!(arith < raw / 2, "arith {arith} raw {raw}");
    }

    #[test]
    fn rejects_malformed_frames() {
        assert!(decode_server(&[]).is_err());
        assert!(decode_server(&[9, 0, 0, 0, 0]).is_err());
        assert!(decode_client(&[3, 2, 0, 0, 0, 1, 2]).is_err());
        // truncated payload
        let good = encode_server(&ServerMsg::Round { round: 0, probs: vec![1.0] });
        assert!(decode_server(&good[..good.len() - 1]).is_err());
    }

    #[test]
    fn control_frames_roundtrip() {
        for msg in [ClientMsg::Abort { client: 3 }, ClientMsg::Heartbeat { client: 7 }] {
            let frame = encode_client(&msg, MaskCodec::Raw);
            assert_eq!(decode_client(&frame).unwrap(), msg);
            // wrong payload length must error, not panic
            let mut bad = frame.clone();
            bad[1] = 3; // declared len 3, body still 4 → split keeps 3 bytes
            bad.pop();
            assert!(decode_client(&bad).is_err());
        }
    }

    #[test]
    fn truncated_arith_mask_is_an_error_not_garbage() {
        let mut rng = Xoshiro256pp::seed_from(6);
        let mask: Vec<bool> = (0..4096).map(|_| rng.bernoulli(0.2)).collect();
        let msg = ClientMsg::Mask { round: 1, client: 0, n: mask.len(), mask };
        let frame = encode_client(&msg, MaskCodec::Arithmetic);
        // Chop bytes off the arithmetic body and patch the frame length:
        // every truncation must surface as Err (the seed silently decoded
        // zeros past end-of-input).
        for chop in [1usize, 2, 8] {
            let mut bad = frame[..frame.len() - chop].to_vec();
            let plen = (bad.len() - 5) as u32;
            bad[1..5].copy_from_slice(&plen.to_le_bytes());
            assert!(decode_client(&bad).is_err(), "chop={chop} decoded");
        }
        // Extra trailing body bytes are rejected too.
        let mut bad = frame.clone();
        bad.push(0x55);
        let plen = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_client(&bad).is_err());
    }

    #[test]
    fn peek_matches_full_decode() {
        let mask_msg = ClientMsg::Mask { round: 1, client: 9, n: 3, mask: vec![true; 3] };
        for (msg, kind) in [
            (mask_msg, ClientFrameKind::Mask),
            (ClientMsg::Hello { client: 9 }, ClientFrameKind::Hello),
            (ClientMsg::Abort { client: 9 }, ClientFrameKind::Abort),
            (ClientMsg::Heartbeat { client: 9 }, ClientFrameKind::Heartbeat),
        ] {
            for codec in [MaskCodec::Raw, MaskCodec::Arithmetic] {
                let frame = encode_client(&msg, codec);
                assert_eq!(peek_client_frame(&frame).unwrap(), (kind, 9));
            }
        }
        // peek is as strict as decode on headers
        assert!(peek_client_frame(&[]).is_err());
        assert!(peek_client_frame(&[9, 0, 0, 0, 0]).is_err());
        assert!(peek_client_frame(&[3, 2, 0, 0, 0, 1, 2]).is_err());
    }

    #[test]
    fn wire_supplied_mask_length_is_capped() {
        // A forged header claiming n = u32::MAX must be rejected before
        // any allocation, for both codecs.
        for tag in [3u8, 4] {
            let mut payload = Vec::new();
            payload.extend_from_slice(&0u32.to_le_bytes());
            payload.extend_from_slice(&0u32.to_le_bytes());
            payload.extend_from_slice(&u32::MAX.to_le_bytes());
            payload.extend_from_slice(&[0u8; 16]);
            let mut frame = vec![tag];
            frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            frame.extend_from_slice(&payload);
            assert!(decode_client(&frame).is_err(), "tag={tag}");
        }
    }

    #[test]
    fn shard_votes_roundtrip() {
        let msg = ShardMsg::ShardVotes {
            shard: 2,
            round: 9,
            received: 3,
            n: 5,
            votes: vec![0, 1, 3, 2, 3],
        };
        let frame = encode_shard(&msg);
        assert_eq!(decode_shard(&frame).unwrap(), msg);
        // fixed wire size: header + 16-byte preamble + 4 bytes per entry
        assert_eq!(frame.len(), 5 + 16 + 5 * 4);
        // a client/server decoder must reject the shard tag, and vice versa
        assert!(decode_client(&frame).is_err());
        assert!(decode_server(&frame).is_err());
        let hello = encode_client(&ClientMsg::Hello { client: 0 }, MaskCodec::Raw);
        assert!(decode_shard(&hello).is_err());
    }

    #[test]
    fn shard_votes_rejects_malformed_frames() {
        let msg =
            ShardMsg::ShardVotes { shard: 0, round: 0, received: 2, n: 3, votes: vec![2, 0, 1] };
        let frame = encode_shard(&msg);
        // truncated payload (patched length) and trailing bytes both error
        let mut bad = frame[..frame.len() - 2].to_vec();
        let plen = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_shard(&bad).is_err());
        let mut bad = frame.clone();
        bad.extend_from_slice(&[0, 0, 0, 0]);
        let plen = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_shard(&bad).is_err());
        // a forged n = u32::MAX must be rejected before any allocation
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut forged = vec![8u8];
        forged.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        forged.extend_from_slice(&payload);
        assert!(decode_shard(&forged).is_err());
    }

    #[test]
    fn shard_votes_rejects_impossible_sums() {
        // A vote sum exceeding the claimed received count would skew the
        // renormalized mean: rejected, never merged.
        let msg = ShardMsg::ShardVotes { shard: 0, round: 1, received: 2, n: 2, votes: vec![2, 1] };
        let mut frame = encode_shard(&msg);
        // patch votes[0] (payload offset 16) to 3 > received = 2
        frame[5 + 16..5 + 20].copy_from_slice(&3u32.to_le_bytes());
        assert!(decode_shard(&frame).is_err());
    }

    #[test]
    fn peer_round_roundtrip_and_rejects_malformed_frames() {
        let msg = ServerMsg::PeerRound { round: 4, participants: vec![0, 2, 5] };
        let frame = encode_server(&msg);
        assert_eq!(decode_server(&frame).unwrap(), msg);
        // fixed wire size: header + 8-byte preamble + 4 bytes per id
        assert_eq!(frame.len(), 5 + 8 + 3 * 4);
        assert_eq!(peek_server_frame(&frame).unwrap(), ServerFrameKind::PeerRound);
        // an empty participant set is legal (a fully-skipped round)
        let empty = ServerMsg::PeerRound { round: 0, participants: vec![] };
        assert_eq!(decode_server(&encode_server(&empty)).unwrap(), empty);
        // truncated body (patched length) errors
        let mut bad = frame[..frame.len() - 2].to_vec();
        let plen = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_server(&bad).is_err());
        // a forged count must be rejected before any allocation
        let mut forged = frame.clone();
        forged[5 + 4..5 + 8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_server(&forged).is_err());
        // non-ascending (or duplicate) ids are rejected: not canonical
        for ids in [vec![2u32, 0, 5], vec![0, 2, 2]] {
            let bad = encode_server(&ServerMsg::PeerRound { round: 4, participants: ids });
            assert!(decode_server(&bad).is_err());
        }
    }

    #[test]
    fn peer_report_roundtrip_and_rejects_poisoned_values() {
        let msg = ClientMsg::Report {
            round: 7,
            client: 2,
            loss: 0.125,
            probs: vec![0.0, 0.5, 1.0],
        };
        let frame = encode_client(&msg, MaskCodec::Raw);
        assert_eq!(decode_client(&frame).unwrap(), msg);
        assert_eq!(frame.len(), 5 + 20 + 3 * 4);
        assert_eq!(peek_client_frame(&frame).unwrap(), (ClientFrameKind::Report, 2));
        // truncated body (patched length) errors
        let mut bad = frame[..frame.len() - 2].to_vec();
        let plen = (bad.len() - 5) as u32;
        bad[1..5].copy_from_slice(&plen.to_le_bytes());
        assert!(decode_client(&bad).is_err());
        // forged n rejected before allocation
        let mut forged = frame.clone();
        forged[5 + 8..5 + 12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_client(&forged).is_err());
        // loss is advisory telemetry: carried verbatim, even non-finite
        // (an honestly diverging peer must not be ejected as malicious)
        let mut inf_loss = frame.clone();
        inf_loss[5 + 12..5 + 20].copy_from_slice(&f64::INFINITY.to_le_bytes());
        let ClientMsg::Report { loss, .. } = decode_client(&inf_loss).unwrap() else {
            panic!("expected a Report");
        };
        assert_eq!(loss, f64::INFINITY);
        // a probability outside [0, 1] would skew the consensus mean
        for poison in [2.0f32, -0.5, f32::NAN] {
            let mut bad = frame.clone();
            bad[5 + 20..5 + 24].copy_from_slice(&poison.to_le_bytes());
            assert!(decode_client(&bad).is_err(), "accepted prob {poison}");
        }
        // server/shard decoders reject the report tag
        assert!(decode_server(&frame).is_err());
        assert!(decode_shard(&frame).is_err());
    }

    #[test]
    fn raw_mask_wire_size_is_the_papers_n_bits() {
        // n = 8331 (MnistFc m/32): payload body must be ⌈n/64⌉·8 bytes.
        let mask = vec![true; 8331];
        let msg = ClientMsg::Mask { round: 0, client: 0, n: 8331, mask };
        let bytes = encode_client(&msg, MaskCodec::Raw).len();
        assert_eq!(bytes, 5 + 12 + 8331usize.div_ceil(64) * 8);
    }
}
