//! Wire protocol: framed messages shared by the in-process simulator and
//! the TCP transport.
//!
//! Frame layout: `[tag: u8][len: u32 le][payload: len bytes]`.
//! The byte counts the ledger records are exactly `frame_len(msg)`.

use crate::comm::{arith, BitPack, FloatVec};
use crate::util::error::Result;
use crate::{anyhow, bail};

/// How the client mask is encoded on the uplink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskCodec {
    /// Raw packed bits: exactly `⌈n/64⌉·8` bytes — the paper's "n bits".
    Raw,
    /// Adaptive arithmetic coding (≈ H(p̂)·n bits — the Isik-style rate).
    Arithmetic,
}

/// Server → client.
#[derive(Clone, Debug, PartialEq)]
pub enum ServerMsg {
    /// Start round `round` with the current global probabilities.
    Round { round: u32, probs: Vec<f32> },
    /// Training is over; workers exit.
    Shutdown,
}

/// Client → server.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMsg {
    /// The sampled mask for `round` (encoded per `codec`).
    Mask { round: u32, client: u32, n: usize, mask: Vec<bool> },
    /// Worker greets with its client id (TCP handshake).
    Hello { client: u32 },
}

const TAG_ROUND: u8 = 1;
const TAG_SHUTDOWN: u8 = 2;
const TAG_MASK_RAW: u8 = 3;
const TAG_MASK_ARITH: u8 = 4;
const TAG_HELLO: u8 = 5;

fn frame(tag: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(tag);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Encode a server message.
pub fn encode_server(msg: &ServerMsg) -> Vec<u8> {
    match msg {
        ServerMsg::Round { round, probs } => {
            let mut payload = Vec::with_capacity(4 + probs.len() * 4);
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&FloatVec::encode(probs));
            frame(TAG_ROUND, &payload)
        }
        ServerMsg::Shutdown => frame(TAG_SHUTDOWN, &[]),
    }
}

/// Encode a client message with the chosen mask codec.
pub fn encode_client(msg: &ClientMsg, codec: MaskCodec) -> Vec<u8> {
    match msg {
        ClientMsg::Mask { round, client, n, mask } => {
            debug_assert_eq!(mask.len(), *n);
            let (tag, body) = match codec {
                MaskCodec::Raw => (TAG_MASK_RAW, BitPack::encode(mask)),
                MaskCodec::Arithmetic => (TAG_MASK_ARITH, arith::encode(mask)),
            };
            let mut payload = Vec::with_capacity(12 + body.len());
            payload.extend_from_slice(&round.to_le_bytes());
            payload.extend_from_slice(&client.to_le_bytes());
            payload.extend_from_slice(&(*n as u32).to_le_bytes());
            payload.extend_from_slice(&body);
            frame(tag, &payload)
        }
        ClientMsg::Hello { client } => frame(TAG_HELLO, &client.to_le_bytes()),
    }
}

/// Split one frame off the front of `buf`; returns `(tag, payload)`.
fn split_frame(buf: &[u8]) -> Result<(u8, &[u8])> {
    if buf.len() < 5 {
        bail!("truncated frame header ({} bytes)", buf.len());
    }
    let tag = buf[0];
    let len = u32::from_le_bytes(buf[1..5].try_into().unwrap()) as usize;
    let payload = buf.get(5..5 + len).ok_or_else(|| anyhow!("truncated frame payload"))?;
    Ok((tag, payload))
}

pub fn decode_server(buf: &[u8]) -> Result<ServerMsg> {
    let (tag, p) = split_frame(buf)?;
    match tag {
        TAG_ROUND => {
            if p.len() < 4 || (p.len() - 4) % 4 != 0 {
                bail!("bad Round payload length {}", p.len());
            }
            let round = u32::from_le_bytes(p[..4].try_into().unwrap());
            Ok(ServerMsg::Round { round, probs: FloatVec::decode(&p[4..]) })
        }
        TAG_SHUTDOWN => Ok(ServerMsg::Shutdown),
        t => bail!("unexpected server tag {t}"),
    }
}

pub fn decode_client(buf: &[u8]) -> Result<ClientMsg> {
    let (tag, p) = split_frame(buf)?;
    match tag {
        TAG_MASK_RAW | TAG_MASK_ARITH => {
            if p.len() < 12 {
                bail!("bad Mask payload length {}", p.len());
            }
            let round = u32::from_le_bytes(p[0..4].try_into().unwrap());
            let client = u32::from_le_bytes(p[4..8].try_into().unwrap());
            let n = u32::from_le_bytes(p[8..12].try_into().unwrap()) as usize;
            let mask = if tag == TAG_MASK_RAW {
                if p.len() - 12 != BitPack::wire_bytes(n) {
                    bail!("raw mask body {} bytes, want {}", p.len() - 12, BitPack::wire_bytes(n));
                }
                BitPack::decode(&p[12..], n)
            } else {
                arith::decode(&p[12..], n)
            };
            Ok(ClientMsg::Mask { round, client, n, mask })
        }
        TAG_HELLO => {
            if p.len() != 4 {
                bail!("bad Hello payload");
            }
            Ok(ClientMsg::Hello { client: u32::from_le_bytes(p.try_into().unwrap()) })
        }
        t => bail!("unexpected client tag {t}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn server_roundtrip() {
        let msg = ServerMsg::Round { round: 7, probs: vec![0.25, 0.5, 1.0] };
        assert_eq!(decode_server(&encode_server(&msg)).unwrap(), msg);
        assert_eq!(decode_server(&encode_server(&ServerMsg::Shutdown)).unwrap(), ServerMsg::Shutdown);
    }

    #[test]
    fn client_roundtrip_both_codecs() {
        let mut rng = Xoshiro256pp::seed_from(3);
        let mask: Vec<bool> = (0..517).map(|_| rng.bernoulli(0.3)).collect();
        let msg = ClientMsg::Mask { round: 2, client: 9, n: 517, mask };
        for codec in [MaskCodec::Raw, MaskCodec::Arithmetic] {
            assert_eq!(decode_client(&encode_client(&msg, codec)).unwrap(), msg);
        }
        let hello = ClientMsg::Hello { client: 4 };
        assert_eq!(decode_client(&encode_client(&hello, MaskCodec::Raw)).unwrap(), hello);
    }

    #[test]
    fn arithmetic_uplink_is_smaller_on_skewed_masks() {
        let mut rng = Xoshiro256pp::seed_from(4);
        let mask: Vec<bool> = (0..20_000).map(|_| rng.bernoulli(0.05)).collect();
        let msg = ClientMsg::Mask { round: 0, client: 0, n: mask.len(), mask };
        let raw = encode_client(&msg, MaskCodec::Raw).len();
        let arith = encode_client(&msg, MaskCodec::Arithmetic).len();
        assert!(arith < raw / 2, "arith {arith} raw {raw}");
    }

    #[test]
    fn rejects_malformed_frames() {
        assert!(decode_server(&[]).is_err());
        assert!(decode_server(&[9, 0, 0, 0, 0]).is_err());
        assert!(decode_client(&[3, 2, 0, 0, 0, 1, 2]).is_err());
        // truncated payload
        let good = encode_server(&ServerMsg::Round { round: 0, probs: vec![1.0] });
        assert!(decode_server(&good[..good.len() - 1]).is_err());
    }

    #[test]
    fn raw_mask_wire_size_is_the_papers_n_bits() {
        // n = 8331 (MnistFc m/32): payload body must be ⌈n/64⌉·8 bytes.
        let mask = vec![true; 8331];
        let msg = ClientMsg::Mask { round: 0, client: 0, n: 8331, mask };
        let bytes = encode_client(&msg, MaskCodec::Raw).len();
        assert_eq!(bytes, 5 + 12 + 8331usize.div_ceil(64) * 8);
    }
}
