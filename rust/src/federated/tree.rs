//! The shard tree on the wire: `repro serve-shard` nodes and the root's
//! [`WireTreeTransport`] — the multi-process generalization of
//! [`ShardedTransport`](super::transport::ShardedTransport)'s two-level
//! in-process merge to **arbitrary-depth aggregation trees**.
//!
//! ## Topology
//!
//! A [`ShardTree`] (from `federated.tree-parents`, or flat when the
//! table is empty) arranges the `S` shard leaders of a [`ShardPlan`]
//! into an ordered forest under the root process.  Every shard leader
//! is its own OS process (`repro serve-shard --shard-id s`):
//!
//! * it runs a full [`Leader`] for the clients `ShardPlan::range(s)`
//!   owns (same accept/reconnect/deadline machinery as the TCP leader);
//! * it accepts one **merge link** per child shard (the child announces
//!   itself with the existing `Hello` frame, carrying its *shard* id);
//! * it dials its parent's merge port (the root's `--listen` address
//!   for top-level shards) and speaks the existing `ShardVotes` frame
//!   (tag 8) upward — no new wire tags.
//!
//! Per round the node receives the encoded `Round` frame from its
//! parent, forwards it to its children *first* (so every subtree's
//! round overlaps its own), broadcasts to its own workers, folds their
//! masks into a streaming vote sum ([`Leader::collect_votes`]), merges
//! each child's `ShardVotes` partial sum into it (`u32` adds are exact
//! and associative — property-tested in
//! `tests/shard_merge_properties.rs`), and ships one `ShardVotes` frame
//! upward whose `received` spans its whole subtree.
//!
//! ## Byte-identicality
//!
//! Shard processes derive each round's participants locally from the
//! shared seed ([`RoundPlan::for_round`] is pure), which is why the
//! config layer restricts `sharded-wire` to the uniform policy and the
//! raw mask codec: the root can bill per-client uplink from the fixed
//! raw frame size without ever seeing a mask.  A depth-2 tree (root +
//! leaf shard processes) produces `final_probs` and ledgers
//! **byte-identical** to the in-process
//! [`ShardedSimTransport`](super::ShardedSimTransport) twin at the same
//! seed — including a whole subtree killed mid-run on a chaos schedule
//! (`--fail-at-round`), which the twin models as a shard outage.  At
//! depth ≥ 3 the root's shard table aggregates each *direct child's
//! subtree* into one row (the per-hop splits live in the shard nodes'
//! logs); the round table and `final_probs` stay byte-identical at any
//! depth.
//!
//! ## Fault model
//!
//! Merge links fail by EOF: a dead child (or a chaos self-exit via
//! `--fail-at-round`, which quits *before* forwarding or broadcasting,
//! so the kill round is deterministic) is discovered at the read and
//! its whole subtree is treated as failed for the rest of the run —
//! participants dropped, zero billed traffic, aggregation renormalized
//! by whatever arrived, exactly like the simulator's failed shards.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use crate::comm::ShardCost;
use crate::config::{tree_addresses, validate_tree_parents, FedConfig};
use crate::rng::SeedTree;
use crate::util::error::{Context, Result};
use crate::zampling::DenseExecutor;
use crate::{anyhow, bail, ensure};

use super::engine::{
    Contribution, DeadlinePolicy, RoundCtx, RoundPlan, RoundTraffic, ShardPlan, Transport,
};
use super::protocol::{
    decode_server, decode_shard, encode_client, encode_server, encode_shard, peek_client_frame,
    peek_server_frame, wire_u32, ClientFrameKind, ClientMsg, MaskCodec, ServerFrameKind, ServerMsg,
    ShardMsg,
};
use super::transport::{read_frame, write_frame, Leader, Worker};
use super::Server;

/// How long a shard node retries dialing its parent's merge port before
/// giving up — generous because a parent only starts accepting merge
/// links after its own workers finish their `Hello` handshakes.
const PARENT_DIAL_TIMEOUT: Duration = Duration::from_secs(30);

/// The ordered aggregation forest over a [`ShardPlan`]'s shard ids.
///
/// Validated shape (see `config::validate_tree_parents`): `parent[s]`
/// is `None` (a direct child of the root process) or an earlier shard
/// id, and every subtree covers a contiguous shard-id interval starting
/// at its own id — a preorder labeling.  Contiguous shard intervals
/// over a `ShardPlan`'s contiguous client ranges give contiguous
/// *client* spans per subtree, which is what keeps the root's
/// contributions globally ascending (the engine's invariant).
#[derive(Clone, Debug)]
pub struct ShardTree {
    parents: Vec<Option<usize>>,
    children: Vec<Vec<usize>>,
    root_children: Vec<usize>,
    /// Subtree size in shards, including the shard itself.
    subtree: Vec<usize>,
}

impl ShardTree {
    /// Build from a validated parent table (`parents[s]` = the shard id
    /// `s` merges into, `None` for direct children of the root).
    pub fn from_parents(parents: &[Option<usize>]) -> Result<ShardTree> {
        validate_tree_parents(parents).map_err(|e| anyhow!("{e}"))?;
        let shards = parents.len();
        let mut children = vec![Vec::new(); shards];
        let mut root_children = Vec::new();
        for (s, p) in parents.iter().enumerate() {
            match p {
                Some(p) => children[*p].push(s),
                None => root_children.push(s),
            }
        }
        let mut subtree = vec![1usize; shards];
        for s in (0..shards).rev() {
            if let Some(p) = parents[s] {
                subtree[p] += subtree[s];
            }
        }
        Ok(ShardTree { parents: parents.to_vec(), children, root_children, subtree })
    }

    /// The flat (depth-2) tree: every shard a direct child of the root
    /// — the topology `ShardedTransport` runs in-process.
    pub fn flat(shards: usize) -> ShardTree {
        // A flat table is always valid, so this cannot fail.
        match Self::from_parents(&vec![None; shards]) {
            Ok(t) => t,
            Err(_) => unreachable!("a flat parent table is always valid"), // lint: allow(panic) — `vec![None; s]` trivially satisfies every tree invariant
        }
    }

    /// The tree a config describes: `federated.tree-parents` when set,
    /// otherwise flat over `cfg.shards`.
    pub fn from_cfg(cfg: &FedConfig) -> Result<ShardTree> {
        if cfg.tree_parents.is_empty() {
            Ok(Self::flat(cfg.shards))
        } else {
            ensure!(
                cfg.tree_parents.len() == cfg.shards,
                "tree-parents has {} entries for {} shards",
                cfg.tree_parents.len(),
                cfg.shards
            );
            Self::from_parents(&cfg.tree_parents)
        }
    }

    /// Shard count.
    pub fn shards(&self) -> usize {
        self.parents.len()
    }

    /// The shard `s` merges into, `None` for direct children of the
    /// root process.
    pub fn parent(&self, s: usize) -> Option<usize> {
        self.parents[s]
    }

    /// Shard ids that merge into shard `s`, ascending.
    pub fn children(&self, s: usize) -> &[usize] {
        &self.children[s]
    }

    /// Shard ids that merge directly into the root process, ascending.
    pub fn root_children(&self) -> &[usize] {
        &self.root_children
    }

    /// The contiguous shard-id interval rooted at `s` (including `s`).
    pub fn subtree_shards(&self, s: usize) -> std::ops::Range<usize> {
        s..s + self.subtree[s]
    }

    /// The contiguous client-id span shard `s`'s whole subtree owns
    /// under `plan` — the bound on the `received` count a `ShardVotes`
    /// frame from `s` may claim.
    pub fn subtree_clients(&self, plan: &ShardPlan, s: usize) -> std::ops::Range<usize> {
        let shards = self.subtree_shards(s);
        plan.range(shards.start).start..plan.range(shards.end - 1).end
    }

    /// Merge-hop depth: 1 for a flat tree (shard → root), plus one per
    /// additional ancestor on the longest chain.
    pub fn depth(&self) -> usize {
        (0..self.shards())
            .map(|mut s| {
                let mut d = 1usize;
                while let Some(p) = self.parents[s] {
                    d += 1;
                    s = p;
                }
                d
            })
            .max()
            .unwrap_or(0)
    }
}

/// The encoded size, in bits, of one raw-codec `Mask` uplink frame for
/// an `n`-entry model — a pure function of `n`, which is what lets the
/// tree root bill per-client uplink without ever seeing a mask (the
/// config layer pins `sharded-wire` to the raw codec for exactly this
/// reason).
pub fn mask_frame_bits(n: usize) -> u64 {
    let frame =
        encode_client(&ClientMsg::Mask { round: 0, client: 0, n, mask: vec![false; n] }, MaskCodec::Raw);
    frame.len() as u64 * 8
}

/// One parent→child merge link; `None` once the child's process died
/// (EOF or write failure) — its whole subtree is failed from then on.
struct MergeLink {
    shard: usize,
    stream: Option<TcpStream>,
}

/// Accept `expected` merge links on `listener`: each child announces
/// itself with a `Hello` frame carrying its shard id.  Shared by the
/// root transport and the shard nodes.
fn accept_merge_links(listener: &TcpListener, expected: &[usize]) -> Result<Vec<MergeLink>> {
    let mut links: Vec<MergeLink> =
        expected.iter().map(|&s| MergeLink { shard: s, stream: None }).collect();
    for _ in 0..expected.len() {
        let (mut stream, peer) =
            listener.accept().with_context(|| "accepting a merge link".to_string())?;
        stream.set_nodelay(true).ok();
        let hello = read_frame(&mut stream)
            .with_context(|| format!("reading the merge-link Hello from {peer}"))?;
        let (kind, id) = peek_client_frame(&hello)?;
        ensure!(
            matches!(kind, ClientFrameKind::Hello),
            "merge link from {peer} opened with {kind:?}, expected Hello"
        );
        let id = id as usize;
        let slot = links
            .iter_mut()
            .find(|l| l.shard == id)
            .ok_or_else(|| anyhow!("merge link announced unexpected shard id {id}"))?;
        ensure!(slot.stream.is_none(), "duplicate merge link for shard {id}");
        slot.stream = Some(stream);
    }
    Ok(links)
}

/// Root [`Transport`] for the wire shard tree: the engine's round loop
/// over one merge link per direct child of the root, each a
/// `repro serve-shard` process aggregating its whole subtree.
///
/// `exchange` forwards the engine's encoded round frame to every live
/// child, then reads one `ShardVotes` frame per link; `aggregate`
/// merges the decoded partial sums (`Server::merge_votes`) and
/// renormalizes.  Costs are derived, not measured: with the raw codec
/// pinned, every mask frame is [`mask_frame_bits`] and every broadcast
/// is `ctx.frame` — so a live child's subtree bills exactly what the
/// in-process twin bills and the ledgers match byte-for-byte at depth
/// 2.  Root→child `Round` forwarding and merge-link `Hello`s are not
/// billed (the simulator has no counterpart for either).
pub struct WireTreeTransport {
    plan: ShardPlan,
    tree: ShardTree,
    children: Vec<MergeLink>,
    exec: Box<dyn DenseExecutor>,
    /// Decoded `(votes, received)` per live child this round, consumed
    /// by `aggregate` — decoding (and every validation that can fail)
    /// happens in `exchange`, where errors can propagate as `Result`.
    pending: Vec<(Vec<u32>, u32)>,
    /// Cached raw mask-frame size for the current model size.
    mask_bits: Option<(usize, u64)>,
}

impl WireTreeTransport {
    /// Bind `listen` and accept one merge link per direct child of the
    /// root (the whole subtree below each child is already connected by
    /// the time it dials, so returning means the full tree is up).
    pub fn accept(listen: &str, cfg: &FedConfig, exec: Box<dyn DenseExecutor>) -> Result<Self> {
        let tree = ShardTree::from_cfg(cfg)?;
        let plan = ShardPlan::new(cfg.clients, cfg.shards);
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let children = accept_merge_links(&listener, tree.root_children())?;
        Ok(Self { plan, tree, children, exec, pending: Vec::new(), mask_bits: None })
    }

    /// The client-space partition the tree aggregates.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The aggregation forest over the shard ids.
    pub fn tree(&self) -> &ShardTree {
        &self.tree
    }

    fn mask_bits_for(&mut self, n: usize) -> u64 {
        match self.mask_bits {
            Some((cached_n, bits)) if cached_n == n => bits,
            _ => {
                let bits = mask_frame_bits(n);
                self.mask_bits = Some((n, bits));
                bits
            }
        }
    }
}

impl Transport for WireTreeTransport {
    fn exchange(&mut self, ctx: &RoundCtx<'_>) -> Result<RoundTraffic> {
        let mask_bits = self.mask_bits_for(ctx.n);
        let frame_bits = ctx.frame.len() as u64 * 8;

        // Each direct child's participants are a contiguous window of
        // the ascending participant list (subtree client spans are
        // contiguous and ascending in child order).
        let mut windows: Vec<&[usize]> = Vec::with_capacity(self.children.len());
        let mut cursor = 0usize;
        for link in &self.children {
            let span = self.tree.subtree_clients(&self.plan, link.shard);
            let start = cursor;
            while cursor < ctx.participants.len() && ctx.participants[cursor] < span.end {
                let k = ctx.participants[cursor];
                ensure!(k >= span.start, "participant {k} below shard {}'s subtree", link.shard);
                cursor += 1;
            }
            windows.push(&ctx.participants[start..cursor]);
        }
        ensure!(cursor == ctx.participants.len(), "participant outside every subtree");

        // Forward the round frame to every live child first, so all
        // subtrees run the round concurrently; a failed write means the
        // child died earlier — treat its subtree as failed from now on.
        for link in &mut self.children {
            if let Some(stream) = link.stream.as_mut() {
                if write_frame(stream, ctx.frame).is_err() {
                    link.stream = None;
                }
            }
        }

        // One ShardVotes frame per live child, in child order (child 0's
        // reply is read while the later subtrees still compute).  EOF
        // here is the chaos path: the child quit before responding, so
        // this round already bills it as failed.
        let mut replies: Vec<Option<(Vec<u32>, u32, u64)>> =
            Vec::with_capacity(self.children.len());
        for link in &mut self.children {
            let Some(stream) = link.stream.as_mut() else {
                replies.push(None);
                continue;
            };
            let Ok(frame) = read_frame(stream) else {
                link.stream = None;
                replies.push(None);
                continue;
            };
            let ShardMsg::ShardVotes { shard, round, received, n, votes } = decode_shard(&frame)?;
            ensure!(
                shard as usize == link.shard,
                "merge link for shard {} sent a frame claiming shard {shard}",
                link.shard
            );
            ensure!(
                round == ctx.round,
                "shard {} answered round {round}, expected {}",
                link.shard,
                ctx.round
            );
            ensure!(n == ctx.n, "shard {} vote length {n} != model size {}", link.shard, ctx.n);
            replies.push(Some((votes, received, frame.len() as u64 * 8)));
        }

        // Bill the round.  A live child's subtree looks exactly like the
        // simulator's live shards (received masks at the fixed raw frame
        // size, broadcasts at the round-frame size); a dead child is the
        // simulator's failed shard (participants dropped, zero traffic).
        let mut contributions = Vec::with_capacity(ctx.participants.len());
        let mut dropped = Vec::new();
        let mut down_bits = 0u64;
        let mut shard_costs = Vec::with_capacity(self.children.len());
        self.pending.clear();
        for (i, link) in self.children.iter().enumerate() {
            let parts = windows[i];
            match replies[i].take() {
                None => {
                    dropped.extend_from_slice(parts);
                    shard_costs.push(ShardCost {
                        shard: wire_u32(link.shard),
                        dropped: wire_u32(parts.len()),
                        ..Default::default()
                    });
                }
                Some((votes, received, merge_bits)) => {
                    let r = received as usize;
                    ensure!(
                        r <= parts.len(),
                        "shard {} claims {r} received masks for {} subtree participants",
                        link.shard,
                        parts.len()
                    );
                    // The root only learns the count, not which subtree
                    // clients contributed; attributing the first `r` ids
                    // keeps contributions ascending and bills identical
                    // per-client bits (raw frames are size-uniform), so
                    // ledger totals and row counts are unaffected.
                    for &k in &parts[..r] {
                        contributions.push(Contribution {
                            client: k,
                            loss: 0.0,
                            up_bits: mask_bits,
                            packed_mask: Vec::new(),
                        });
                    }
                    dropped.extend_from_slice(&parts[r..]);
                    down_bits += u64::from(received) * frame_bits;
                    shard_costs.push(ShardCost {
                        shard: wire_u32(link.shard),
                        uplink_bits: u64::from(received) * mask_bits,
                        downlink_bits: u64::from(received) * frame_bits,
                        merge_bits,
                        received,
                        dropped: wire_u32(parts.len() - r),
                    });
                    self.pending.push((votes, received));
                }
            }
        }
        dropped.sort_unstable();
        Ok(RoundTraffic { contributions, dropped, down_bits, shard_costs, ..Default::default() })
    }

    /// Merge the decoded subtree vote sums and renormalize — the same
    /// algebra as `merge_vote_frames`, but over frames already decoded
    /// and validated in `exchange` (where failure can be a `Result`).
    fn aggregate(&mut self, server: &mut Server, _traffic: &RoundTraffic) -> usize {
        for (votes, received) in self.pending.drain(..) {
            server.merge_votes(&votes, received as usize);
        }
        server.try_aggregate()
    }

    fn eval_executor(&mut self) -> &mut dyn DenseExecutor {
        self.exec.as_mut()
    }

    fn finish(&mut self) -> Result<()> {
        let frame = encode_server(&ServerMsg::Shutdown);
        for link in &mut self.children {
            if let Some(stream) = link.stream.as_mut() {
                let _ = write_frame(stream, &frame);
            }
        }
        Ok(())
    }
}

/// Run one shard-leader process (`repro serve-shard --shard-id s`):
/// lead the clients `ShardPlan::range(s)` owns, aggregate the subtree
/// below `s`, and merge upward until the parent sends `Shutdown`.
///
/// `fail_at_round` is the chaos knob: on receiving that round's frame
/// the node exits **before** forwarding or broadcasting anything, so
/// the subtree's death is deterministic — its workers and children see
/// EOF, and the parent bills the whole subtree as failed from exactly
/// that round (what the in-process twin models as a shard outage).
pub fn serve_shard(
    cfg: &FedConfig,
    shard: usize,
    listen: &str,
    fail_at_round: Option<u32>,
) -> Result<()> {
    ensure!(shard < cfg.shards, "shard-id {shard} ≥ shards {}", cfg.shards);
    let tree = ShardTree::from_cfg(cfg)?;
    let plan = ShardPlan::new(cfg.clients, cfg.shards);
    let addrs = tree_addresses(listen, cfg.shards).map_err(|e| anyhow!("{e}"))?;
    let n = cfg.train.n;
    let own: Vec<usize> = plan.range(shard).collect();

    // Bind both listeners before anything blocks, so workers' and
    // children's retry-dials land in a bound backlog regardless of
    // launch order.
    let worker_listener = TcpListener::bind(&addrs.workers[shard])
        .with_context(|| format!("binding worker port {}", addrs.workers[shard]))?;
    let merge_listener = if tree.children(shard).is_empty() {
        None
    } else {
        Some(
            TcpListener::bind(&addrs.merges[shard])
                .with_context(|| format!("binding merge port {}", addrs.merges[shard]))?,
        )
    };
    println!(
        "[shard {shard}] leading clients {}..{} on {}, {} child shard(s), parent {}",
        plan.range(shard).start,
        plan.range(shard).end,
        addrs.workers[shard],
        tree.children(shard).len(),
        match tree.parent(shard) {
            None => "root".to_string(),
            Some(p) => format!("shard {p}"),
        }
    );

    let mut leader = Leader::from_listener_subset(worker_listener, cfg.clients, &own)?;
    let mut children = match &merge_listener {
        Some(listener) => accept_merge_links(listener, tree.children(shard))?,
        None => Vec::new(),
    };

    // Dial the parent last: by now this whole subtree is connected, so
    // the parent (and transitively the root) learns the tree is up the
    // moment every merge link is in.
    let parent_addr = match tree.parent(shard) {
        None => listen.to_string(),
        Some(p) => addrs.merges[p].clone(),
    };
    let mut parent =
        Worker::connect_retry(&parent_addr, wire_u32(shard), MaskCodec::Raw, PARENT_DIAL_TIMEOUT)?;
    println!("[shard {shard}] merge link up to {parent_addr}");

    let seeds = SeedTree::new(cfg.train.seed);
    let deadline = DeadlinePolicy::from_cfg(cfg);
    loop {
        // A dead parent (e.g. the root killed mid-run and restarted via
        // `repro resume`) surfaces here as a failed read: re-dial its
        // merge port with a fresh `Hello` and keep serving.  The node
        // holds no cross-round state — votes, participants, and worker
        // round state all derive from the frame and the shared seed —
        // so replaying the interrupted round from the resumed parent
        // produces byte-identical merges.  A clean end of run arrives
        // as a `Shutdown` frame before the parent closes.
        let frame = match parent.recv_raw() {
            Ok(frame) => frame,
            Err(e) => {
                println!("[shard {shard}] parent link lost ({e:#}); redialing {parent_addr}");
                parent = Worker::connect_retry(
                    &parent_addr,
                    wire_u32(shard),
                    MaskCodec::Raw,
                    PARENT_DIAL_TIMEOUT,
                )
                .with_context(|| format!("shard {shard}: redialing parent"))?;
                continue;
            }
        };
        match peek_server_frame(&frame)? {
            ServerFrameKind::Shutdown => {
                for link in &mut children {
                    if let Some(stream) = link.stream.as_mut() {
                        let _ = write_frame(stream, &frame);
                    }
                }
                leader.shutdown()?;
                println!("[shard {shard}] shutdown");
                return Ok(());
            }
            ServerFrameKind::PeerRound => {
                bail!("shard {shard}: unexpected gossip PeerRound frame on a merge link")
            }
            ServerFrameKind::Round => {
                let ServerMsg::Round { round, .. } = decode_server(&frame)? else {
                    bail!("shard {shard}: Round peek decoded to a different frame");
                };
                if fail_at_round == Some(round) {
                    println!("[shard {shard}] failing at round {round} (chaos schedule)");
                    return Ok(());
                }
                // Children first, so every subtree's round overlaps ours.
                for link in &mut children {
                    if let Some(stream) = link.stream.as_mut() {
                        if write_frame(stream, &frame).is_err() {
                            link.stream = None;
                        }
                    }
                }
                // This node's own workers: participants are derived
                // locally from the shared seed (`RoundPlan::for_round`
                // is pure), never communicated.
                let rp = RoundPlan::for_round(
                    cfg.clients,
                    cfg.participation,
                    &seeds,
                    round as usize,
                );
                let own_parts: Vec<usize> = rp
                    .participants
                    .iter()
                    .copied()
                    .filter(|k| plan.range(shard).contains(k))
                    .collect();
                let (mut votes, own_received) = if own_parts.is_empty() {
                    (vec![0u32; n], 0usize)
                } else {
                    leader.broadcast_frame(&frame, &own_parts)?;
                    let receipt = leader.collect_votes(round, &own_parts, n, deadline)?;
                    let r = receipt.received.len();
                    (receipt.votes, r)
                };
                // Merge each child subtree's partial sum; EOF means the
                // subtree died — failed for the rest of the run.
                let mut merged = 0usize;
                for link in &mut children {
                    let Some(stream) = link.stream.as_mut() else { continue };
                    let Ok(cframe) = read_frame(stream) else {
                        println!("[shard {shard}] child shard {} link lost at round {round}", link.shard);
                        link.stream = None;
                        continue;
                    };
                    let ShardMsg::ShardVotes { shard: cs, round: cr, received, n: cn, votes: cv } =
                        decode_shard(&cframe)?;
                    ensure!(
                        cs as usize == link.shard,
                        "shard {shard}: child link {} claims shard {cs}",
                        link.shard
                    );
                    ensure!(
                        cr == round,
                        "shard {shard}: child {} answered round {cr}, expected {round}",
                        link.shard
                    );
                    ensure!(
                        cn == n,
                        "shard {shard}: child {} vote length {cn} != model size {n}",
                        link.shard
                    );
                    let limit = tree.subtree_clients(&plan, link.shard).len();
                    ensure!(
                        received as usize <= limit,
                        "shard {shard}: child {} claims {received} received masks but its \
                         subtree owns only {limit} clients",
                        link.shard
                    );
                    for (v, &c) in votes.iter_mut().zip(&cv) {
                        *v = v
                            .checked_add(c)
                            .ok_or_else(|| anyhow!("vote overflow merging shard {}", link.shard))?;
                    }
                    merged += received as usize;
                }
                let total = own_received + merged;
                let up = encode_shard(&ShardMsg::ShardVotes {
                    shard: wire_u32(shard),
                    round,
                    received: wire_u32(total),
                    n,
                    votes,
                });
                println!(
                    "[shard {shard}] round {round:>3}  received {total} (own {own_received}, \
                     merged {merged})  merge {}b up",
                    up.len() * 8
                );
                // A failed merge send is the same fault as a failed
                // read: the parent died holding our link.  Drop this
                // round's frame (the resumed parent replays the round)
                // and reconnect.
                if parent.send_frame(&up).is_err() {
                    println!("[shard {shard}] merge send failed; redialing {parent_addr}");
                    parent = Worker::connect_retry(
                        &parent_addr,
                        wire_u32(shard),
                        MaskCodec::Raw,
                        PARENT_DIAL_TIMEOUT,
                    )
                    .with_context(|| format!("shard {shard}: redialing parent"))?;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_tree_shape() {
        let t = ShardTree::flat(3);
        assert_eq!(t.root_children(), &[0, 1, 2]);
        assert_eq!(t.depth(), 1);
        for s in 0..3 {
            assert!(t.children(s).is_empty());
            assert_eq!(t.parent(s), None);
            assert_eq!(t.subtree_shards(s), s..s + 1);
        }
    }

    #[test]
    fn chain_and_balanced_trees_expose_subtrees() {
        // chain: root ← 0 ← 1 ← 2 (depth 3 merge hops)
        let t = ShardTree::from_parents(&[None, Some(0), Some(1)]).unwrap();
        assert_eq!(t.root_children(), &[0]);
        assert_eq!(t.children(0), &[1]);
        assert_eq!(t.children(1), &[2]);
        assert_eq!(t.depth(), 3);
        assert_eq!(t.subtree_shards(0), 0..3);
        assert_eq!(t.subtree_shards(1), 1..3);
        let plan = ShardPlan::new(6, 3);
        assert_eq!(t.subtree_clients(&plan, 0), 0..6);
        assert_eq!(t.subtree_clients(&plan, 1), 2..6);
        assert_eq!(t.subtree_clients(&plan, 2), 4..6);

        // balanced: root ← {0, 2}; 0 ← 1; 2 ← 3
        let t = ShardTree::from_parents(&[None, Some(0), None, Some(2)]).unwrap();
        assert_eq!(t.root_children(), &[0, 2]);
        assert_eq!(t.children(0), &[1]);
        assert_eq!(t.children(2), &[3]);
        assert_eq!(t.depth(), 2);
        let plan = ShardPlan::new(8, 4);
        assert_eq!(t.subtree_clients(&plan, 0), 0..4);
        assert_eq!(t.subtree_clients(&plan, 2), 4..8);
    }

    #[test]
    fn invalid_parent_tables_are_rejected() {
        assert!(ShardTree::from_parents(&[None, Some(1)]).is_err()); // self/forward
        assert!(ShardTree::from_parents(&[None, None, Some(0)]).is_err()); // non-contiguous
    }

    #[test]
    fn mask_frame_bits_matches_a_real_encoded_frame() {
        for n in [1usize, 8, 64, 1000] {
            let mask = vec![true; n];
            let frame = encode_client(
                &ClientMsg::Mask { round: 7, client: 3, n, mask },
                MaskCodec::Raw,
            );
            assert_eq!(mask_frame_bits(n), frame.len() as u64 * 8, "n = {n}");
        }
    }
}
