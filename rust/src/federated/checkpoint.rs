//! Run-lifecycle checkpoints: everything a killed leader needs to
//! resume a federated run **byte-identically**.
//!
//! The paper's protocol makes this cheap: server and clients share
//! nothing but the small trainable vector `p`, so a checkpoint is just a
//! manifest (run geometry + progress cursor), `p` itself, the evaluation
//! RNG cursor, the straggler history, the metrics log, and the
//! communication ledger.  Client-side state needs no persistence at all
//! — `client_round` reseeds each client's batch sampler from
//! `(seed, client, round)`, so a worker that reconnects after a crash
//! recomputes exactly the mask it would have sent.
//!
//! The on-disk format is little-endian, length-prefixed, and hardened
//! the same way as the wire codec in [`super::protocol`]: every length
//! field is bounds-checked against the remaining bytes *before*
//! allocation, truncated or oversized input returns `Err` (never a
//! panic), version drift is rejected, and trailing garbage fails the
//! load so a torn write cannot restore silently.  Writes go through a
//! temp-file + rename so a crash mid-write leaves the previous
//! checkpoint intact.

use std::fs;
use std::path::Path;

use crate::comm::CommLedger;
use crate::metrics::{RoundRecord, RunLog};
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use super::protocol::MAX_MASK_LEN;

/// Hard cap on a checkpoint file's size.  `p` dominates: even the
/// largest mask the wire protocol admits (`MAX_MASK_LEN` probabilities,
/// 4 bytes each) plus the ledger of a very long run fits comfortably.
pub const MAX_CHECKPOINT_LEN: usize = 80 * 1024 * 1024;

/// Cap on the embedded run-log name (a CLI-chosen artifact stem).
const MAX_NAME_LEN: usize = 256;

/// `b"zckp"` little-endian — rejects files that are not checkpoints at
/// all before any length field is trusted.
const CKPT_MAGIC: u32 = u32::from_le_bytes(*b"zckp");

/// Current format version; any drift is a hard decode error because a
/// resumed run must not guess at missing or re-interpreted fields.
const CKPT_VERSION: u32 = 1;

/// Bytes per serialized [`RoundRecord`] (7 little-endian u64 words).
const RECORD_BYTES: usize = 56;

/// Run geometry and progress cursor.  The geometry fields are
/// cross-checked against the config at resume time — a checkpoint from
/// a different run (different seed, mask length, roster, or schedule)
/// must be rejected, not silently blended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointManifest {
    /// Root seed of the run's `SeedTree`.
    pub seed: u64,
    /// Mask length `n` (number of trainable probabilities).
    pub n: u32,
    /// Clients present at launch.
    pub clients: u32,
    /// Roster ceiling for elastic membership (`federated.max-clients`).
    pub max_clients: u32,
    /// Total rounds the run is scheduled for.
    pub rounds: u32,
    /// Shard count (1 for a flat run).
    pub shards: u32,
    /// Live population when the checkpoint was written (grows as late
    /// clients join; never exceeds `max_clients`).
    pub population: u32,
    /// First round the resumed engine must execute.  Rounds
    /// `0..next_round` are complete and their effects are captured in
    /// the probabilities, history, log, and ledger below.
    pub next_round: u32,
    /// Evaluation cadence the engine was running with.
    pub eval_every: u32,
    /// Monte-Carlo samples per evaluation.
    pub eval_samples: u32,
    /// Participation fraction, stored as `f64::to_bits` so the manifest
    /// equality check is exact.
    pub participation_bits: u64,
}

/// A complete run snapshot at a round boundary.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Run geometry + progress cursor.
    pub manifest: CheckpointManifest,
    /// The server's trainable probability vector `p` (length `n`).
    pub probs: Vec<f32>,
    /// Exported cursor of the engine's evaluation RNG — the only
    /// cross-round generator state; all other determinism-path streams
    /// are re-derived from `(seed, stream, round)`.
    pub eval_rng: [u64; 4],
    /// Straggler history (`RoundHistory::misses`), one counter per
    /// population slot.
    pub misses: Vec<u32>,
    /// Run-log artifact stem (e.g. `federated`).
    pub log_name: String,
    /// Per-evaluation metric rows logged so far.
    pub records: Vec<RoundRecord>,
    /// Communication ledger rows logged so far (round, shard, and edge
    /// tables — all derived totals recompute from these).
    pub ledger: CommLedger,
}

/// Checked `usize -> u32` for length prefixes; counts beyond `u32` can
/// only arise from a corrupted in-memory state and must fail loudly.
fn ckpt_u32(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| anyhow!("checkpoint {what} count {v} exceeds u32"))
}

/// Bounds-checked little-endian reader over the checkpoint buffer.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, len: usize, what: &str) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(len).ok_or_else(|| {
            anyhow!("checkpoint {what}: length overflows the address space")
        })?;
        if end > self.buf.len() {
            bail!(
                "checkpoint truncated in {what}: need {len} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes(b.try_into()?))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into()?))
    }

    /// Read a `u32` element count and reject it *before* allocation if
    /// even `count * min_entry_bytes` cannot fit in the remaining bytes
    /// — a forged length field must not become a memory bomb.
    fn count(&mut self, what: &str, min_entry_bytes: usize) -> Result<usize> {
        let raw = self.u32(what)?;
        let count = raw as usize;
        let remaining = self.buf.len() - self.pos;
        if count.saturating_mul(min_entry_bytes) > remaining {
            bail!(
                "checkpoint {what} count {count} exceeds the {remaining} bytes remaining"
            );
        }
        Ok(count)
    }
}

impl Checkpoint {
    /// Serialize the snapshot.  Fails only if a collection is too large
    /// for its `u32` length prefix.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let m = &self.manifest;
        let ledger = self.ledger.to_bytes();
        let mut out = Vec::with_capacity(
            128 + self.probs.len() * 4 + self.misses.len() * 4
                + self.records.len() * RECORD_BYTES
                + ledger.len(),
        );
        out.extend_from_slice(&CKPT_MAGIC.to_le_bytes());
        out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        out.extend_from_slice(&m.seed.to_le_bytes());
        out.extend_from_slice(&m.participation_bits.to_le_bytes());
        for word in [
            m.n,
            m.clients,
            m.max_clients,
            m.rounds,
            m.shards,
            m.population,
            m.next_round,
            m.eval_every,
            m.eval_samples,
        ] {
            out.extend_from_slice(&word.to_le_bytes());
        }
        out.extend_from_slice(&ckpt_u32(self.probs.len(), "probs")?.to_le_bytes());
        for p in &self.probs {
            out.extend_from_slice(&p.to_le_bytes());
        }
        for word in self.eval_rng {
            out.extend_from_slice(&word.to_le_bytes());
        }
        out.extend_from_slice(&ckpt_u32(self.misses.len(), "misses")?.to_le_bytes());
        for miss in &self.misses {
            out.extend_from_slice(&miss.to_le_bytes());
        }
        let name = self.log_name.as_bytes();
        out.extend_from_slice(&ckpt_u32(name.len(), "log name")?.to_le_bytes());
        out.extend_from_slice(name);
        out.extend_from_slice(&ckpt_u32(self.records.len(), "records")?.to_le_bytes());
        for r in &self.records {
            out.extend_from_slice(&(r.round as u64).to_le_bytes());
            out.extend_from_slice(&r.mean_sampled_acc.to_bits().to_le_bytes());
            out.extend_from_slice(&r.sampled_acc_std.to_bits().to_le_bytes());
            out.extend_from_slice(&r.expected_acc.to_bits().to_le_bytes());
            out.extend_from_slice(&r.train_loss.to_bits().to_le_bytes());
            out.extend_from_slice(&r.uplink_bits.to_le_bytes());
            out.extend_from_slice(&r.downlink_bits.to_le_bytes());
        }
        out.extend_from_slice(&ckpt_u32(ledger.len(), "ledger")?.to_le_bytes());
        out.extend_from_slice(&ledger);
        Ok(out)
    }

    /// Decode a snapshot.  Any malformed input — wrong magic, version
    /// drift, truncation, forged length fields, an oversized manifest,
    /// internal inconsistency, or trailing bytes — returns `Err`.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        if buf.len() > MAX_CHECKPOINT_LEN {
            bail!(
                "checkpoint is {} bytes, beyond the {MAX_CHECKPOINT_LEN}-byte cap",
                buf.len()
            );
        }
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32("magic")?;
        if magic != CKPT_MAGIC {
            bail!("not a checkpoint: bad magic {magic:#010x}");
        }
        let version = r.u32("version")?;
        if version != CKPT_VERSION {
            bail!("unsupported checkpoint version {version} (this build reads {CKPT_VERSION})");
        }
        let seed = r.u64("seed")?;
        let participation_bits = r.u64("participation")?;
        let n = r.u32("n")?;
        let clients = r.u32("clients")?;
        let max_clients = r.u32("max-clients")?;
        let rounds = r.u32("rounds")?;
        let shards = r.u32("shards")?;
        let population = r.u32("population")?;
        let next_round = r.u32("next-round")?;
        let eval_every = r.u32("eval-every")?;
        let eval_samples = r.u32("eval-samples")?;
        if n as usize > MAX_MASK_LEN {
            bail!("oversized manifest: n = {n} exceeds MAX_MASK_LEN = {MAX_MASK_LEN}");
        }
        if clients == 0 || max_clients < clients || population < clients || population > max_clients
        {
            bail!(
                "inconsistent manifest roster: clients {clients}, population {population}, \
                 max-clients {max_clients}"
            );
        }
        if next_round > rounds {
            bail!("inconsistent manifest: next round {next_round} beyond {rounds} rounds");
        }
        let nprobs = r.count("probs", 4)?;
        if nprobs != n as usize {
            bail!("checkpoint carries {nprobs} probabilities but the manifest declares n = {n}");
        }
        let raw = r.take(nprobs * 4, "probs")?;
        let mut probs = Vec::with_capacity(nprobs);
        for chunk in raw.chunks_exact(4) {
            probs.push(f32::from_le_bytes(chunk.try_into()?));
        }
        let mut eval_rng = [0u64; 4];
        for word in &mut eval_rng {
            *word = r.u64("eval-rng cursor")?;
        }
        if eval_rng == [0u64; 4] {
            bail!("checkpoint eval-rng cursor is the all-zero state (corrupt)");
        }
        let nmisses = r.count("misses", 4)?;
        if nmisses != population as usize {
            bail!(
                "checkpoint carries {nmisses} straggler counters but population is {population}"
            );
        }
        let mut misses = Vec::with_capacity(nmisses);
        for _ in 0..nmisses {
            misses.push(r.u32("miss counter")?);
        }
        let name_len = r.count("log name", 1)?;
        if name_len > MAX_NAME_LEN {
            bail!("checkpoint log name is {name_len} bytes (cap {MAX_NAME_LEN})");
        }
        let log_name = String::from_utf8(r.take(name_len, "log name")?.to_vec())
            .context("checkpoint log name is not UTF-8")?;
        let nrecords = r.count("records", RECORD_BYTES)?;
        let mut records = Vec::with_capacity(nrecords);
        for _ in 0..nrecords {
            let round = usize::try_from(r.u64("record round")?)
                .context("checkpoint record round exceeds usize")?;
            records.push(RoundRecord {
                round,
                mean_sampled_acc: f64::from_bits(r.u64("record mean acc")?),
                sampled_acc_std: f64::from_bits(r.u64("record acc std")?),
                expected_acc: f64::from_bits(r.u64("record expected acc")?),
                train_loss: f64::from_bits(r.u64("record train loss")?),
                uplink_bits: r.u64("record uplink bits")?,
                downlink_bits: r.u64("record downlink bits")?,
            });
        }
        let ledger_len = r.count("ledger", 1)?;
        let ledger = CommLedger::from_bytes(r.take(ledger_len, "ledger")?)
            .context("checkpoint ledger section")?;
        if r.pos != buf.len() {
            bail!(
                "checkpoint has {} trailing bytes after the ledger section",
                buf.len() - r.pos
            );
        }
        Ok(Checkpoint {
            manifest: CheckpointManifest {
                seed,
                n,
                clients,
                max_clients,
                rounds,
                shards,
                population,
                next_round,
                eval_every,
                eval_samples,
                participation_bits,
            },
            probs,
            eval_rng,
            misses,
            log_name,
            records,
            ledger,
        })
    }

    /// Reconstruct the [`RunLog`] captured by this checkpoint.
    pub fn run_log(&self) -> RunLog {
        RunLog { name: self.log_name.clone(), rounds: self.records.clone() }
    }

    /// Write the snapshot atomically: serialize to `<path>.tmp`, then
    /// rename over `path`.  A crash mid-write leaves the previous
    /// checkpoint (if any) intact; rename on the same filesystem is the
    /// atomicity primitive.
    pub fn write_atomic(&self, path: &Path) -> Result<()> {
        let bytes = self.to_bytes()?;
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        fs::write(&tmp, &bytes)
            .with_context(|| format!("writing checkpoint temp file {}", tmp.display()))?;
        fs::rename(&tmp, path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        Ok(())
    }

    /// Load and decode a checkpoint file, enforcing the size cap before
    /// the buffer is parsed.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let meta = fs::metadata(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        if meta.len() > MAX_CHECKPOINT_LEN as u64 {
            bail!(
                "checkpoint {} is {} bytes, beyond the {MAX_CHECKPOINT_LEN}-byte cap",
                path.display(),
                meta.len()
            );
        }
        let bytes = fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{EdgeCost, RoundCost, ShardCost};

    fn sample() -> Checkpoint {
        let mut ledger = CommLedger::default();
        ledger.record(RoundCost {
            downlink_bits: 4096,
            uplink_bits: 1024,
            clients: 4,
            participants: 4,
            dropped: 0,
            wall_ns: 5_000_000,
        });
        ledger.record_shard_costs(vec![ShardCost {
            shard: 0,
            uplink_bits: 512,
            downlink_bits: 2048,
            merge_bits: 96,
            received: 2,
            dropped: 0,
        }]);
        ledger.record_edge_costs(vec![EdgeCost { from: 1, to: 0, bits: 512 }]);
        Checkpoint {
            manifest: CheckpointManifest {
                seed: 1,
                n: 64,
                clients: 4,
                max_clients: 6,
                rounds: 6,
                shards: 2,
                population: 5,
                next_round: 3,
                eval_every: 1,
                eval_samples: 2,
                participation_bits: 1.0f64.to_bits(),
            },
            probs: (0..64).map(|i| i as f32 / 64.0).collect(),
            eval_rng: [11, 22, 33, 44],
            misses: vec![0, 2, 0, 1, 7],
            log_name: "federated".to_string(),
            records: vec![
                RoundRecord {
                    round: 0,
                    mean_sampled_acc: 0.5,
                    sampled_acc_std: 0.01,
                    expected_acc: 0.52,
                    train_loss: 0.7,
                    uplink_bits: 1024,
                    downlink_bits: 4096,
                },
                RoundRecord {
                    round: 2,
                    mean_sampled_acc: 0.6,
                    sampled_acc_std: 0.02,
                    expected_acc: 0.61,
                    train_loss: 0.6,
                    uplink_bits: 1024,
                    downlink_bits: 4096,
                },
            ],
            ledger,
        }
    }

    #[test]
    fn roundtrip_is_lossless() {
        let ckpt = sample();
        let bytes = ckpt.to_bytes().unwrap();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back.manifest, ckpt.manifest);
        assert_eq!(back.probs, ckpt.probs);
        assert_eq!(back.eval_rng, ckpt.eval_rng);
        assert_eq!(back.misses, ckpt.misses);
        assert_eq!(back.log_name, ckpt.log_name);
        assert_eq!(back.records, ckpt.records);
        assert_eq!(back.ledger.to_csv(), ckpt.ledger.to_csv());
        // Encode is deterministic: the roundtrip is a byte fixed point.
        assert_eq!(back.to_bytes().unwrap(), bytes);
    }

    #[test]
    fn run_log_reconstructs() {
        let ckpt = sample();
        let log = ckpt.run_log();
        assert_eq!(log.name, "federated");
        assert_eq!(log.rounds.len(), 2);
        assert_eq!(log.rounds[1].round, 2);
    }

    #[test]
    fn every_truncation_errors_never_panics() {
        let bytes = sample().to_bytes().unwrap();
        for cut in 0..bytes.len() {
            assert!(
                Checkpoint::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut} of {} decoded",
                bytes.len()
            );
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut bytes = sample().to_bytes().unwrap();
        bytes.push(0);
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn bad_magic_and_version_drift_are_rejected() {
        let good = sample().to_bytes().unwrap();
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let err = Checkpoint::from_bytes(&bad_magic).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        let mut bad_version = good;
        bad_version[4] = 99;
        let err = Checkpoint::from_bytes(&bad_version).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn forged_length_fields_are_rejected_before_allocation() {
        let good = sample().to_bytes().unwrap();
        // The probs count sits right after the 60-byte fixed header.
        let mut forged = good.clone();
        forged[60..64].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Checkpoint::from_bytes(&forged).is_err());
        // Forge the ledger length near the tail too.
        let tail = good.len() - sample().ledger.to_bytes().len() - 4;
        let mut forged = good;
        forged[tail..tail + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Checkpoint::from_bytes(&forged).is_err());
    }

    #[test]
    fn oversized_manifest_is_rejected() {
        let mut ckpt = sample();
        ckpt.manifest.n = u32::MAX; // far beyond MAX_MASK_LEN
        // Encode with a consistent (small) probs vec: the decoder must
        // reject on the manifest bound before the probs mismatch.
        let bytes = ckpt.to_bytes().unwrap();
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("oversized manifest"), "{err}");
    }

    #[test]
    fn inconsistent_roster_and_cursor_are_rejected() {
        let mut ckpt = sample();
        ckpt.manifest.population = 99; // beyond max_clients
        assert!(Checkpoint::from_bytes(&ckpt.to_bytes().unwrap()).is_err());
        let mut ckpt = sample();
        ckpt.manifest.next_round = 7; // beyond rounds
        assert!(Checkpoint::from_bytes(&ckpt.to_bytes().unwrap()).is_err());
        let mut ckpt = sample();
        ckpt.eval_rng = [0; 4]; // the xoshiro fixed point
        assert!(Checkpoint::from_bytes(&ckpt.to_bytes().unwrap()).is_err());
    }

    #[test]
    fn atomic_write_then_load_roundtrips() {
        let dir = std::env::temp_dir().join(format!(
            "ckpt-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("checkpoint.bin");
        let ckpt = sample();
        ckpt.write_atomic(&path).unwrap();
        // No temp file left behind.
        assert!(!dir.join("checkpoint.bin.tmp").exists());
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.manifest, ckpt.manifest);
        assert_eq!(back.probs, ckpt.probs);
        // Overwrite goes through the same rename path.
        let mut second = sample();
        second.manifest.next_round = 5;
        second.misses = vec![1, 1, 1, 1, 1];
        second.write_atomic(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().manifest.next_round, 5);
        std::fs::remove_dir_all(&dir).ok();
    }
}
