//! Cache-blocked, register-tiled f32 GEMM kernels for the dense step.
//!
//! The seed's `MlpRef` ran scalar i-k-j loops; these kernels process
//! `MR × NR` (8×8) output tiles with the accumulator block held in
//! registers, walking `k` innermost so each step is `MR` scalar loads +
//! one `NR`-wide vector load + `MR` fused multiply-add rows — the shape
//! LLVM auto-vectorizes to full-width FMA on AVX2/NEON.  Three variants
//! cover the whole forward/backward pass:
//!
//! * [`gemm_bias_act`] — `C = A·B (+ bias) (then ReLU)`, the forward
//!   layer step with the bias add and activation fused into the tile
//!   write-back (no second pass over `C`).
//! * [`gemm`] — plain `C = A·B`; the backward data gradient uses it as
//!   `ΔX = Δ · Wᵀ` over a transposed-weight layout (see [`transpose`]),
//!   so the backward pass is the *same* row-major kernel.
//! * [`gemm_at_b_acc`] — `G += Aᵀ·Δ`, the weight gradient, tiled over
//!   `G`'s rows with the ReLU-sparsity skip kept from the seed kernel.
//!
//! `*_par` wrappers shard rows across [`runtime::pool`] in `MR`-aligned
//! blocks; every output element is produced by exactly one shard running
//! the identical tile loop, so parallel results are **bit-identical** to
//! serial ones.  The seed's scalar kernels are retained under [`naive`]
//! as the parity oracle (`tests/gemm_properties.rs` checks odd shapes
//! against them within f32-reassociation tolerance).

// Index loops mirror the tile arithmetic (zip chains would obscure it),
// and kernel signatures are long by nature: (a, b, c, m, k, n, …).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use crate::runtime::pool;

/// Register-tile height (output rows per microkernel).
pub const MR: usize = 8;
/// Register-tile width (output columns per microkernel).
pub const NR: usize = 8;

/// Accumulate one `ib × jb` tile (`ib ≤ MR`, `jb ≤ NR`) of `A·B` into
/// `acc`.  `a` points at the tile's first row (leading dimension `lda`),
/// `b` at the tile's first column (leading dimension `ldb`).
#[inline(always)]
fn micro_tile(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    kk: usize,
    ib: usize,
    jb: usize,
    acc: &mut [[f32; NR]; MR],
) {
    if ib == MR && jb == NR {
        // Full tile: fixed trip counts so the compiler keeps the 8×8
        // accumulator in registers and vectorizes the jj loop.
        for p in 0..kk {
            let brow = &b[p * ldb..p * ldb + NR];
            for ii in 0..MR {
                let av = a[ii * lda + p];
                let accr = &mut acc[ii];
                for jj in 0..NR {
                    accr[jj] += av * brow[jj];
                }
            }
        }
    } else {
        for p in 0..kk {
            let brow = &b[p * ldb..p * ldb + jb];
            for ii in 0..ib {
                let av = a[ii * lda + p];
                let accr = &mut acc[ii];
                for (jj, &bv) in brow.iter().enumerate() {
                    accr[jj] += av * bv;
                }
            }
        }
    }
}

/// Serial core over a row range: `c[rows × n] = a[rows × k] · b[k × n]`
/// with optional fused bias add and ReLU.
#[allow(clippy::too_many_arguments)]
fn gemm_rows_into(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    rows: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    debug_assert!(a.len() >= rows * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= rows * n);
    debug_assert!(bias.is_none_or(|bs| bs.len() >= n));
    let mut i0 = 0;
    while i0 < rows {
        let ib = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < n {
            let jb = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            micro_tile(&a[i0 * k..], k, &b[j0..], n, k, ib, jb, &mut acc);
            for ii in 0..ib {
                let row = (i0 + ii) * n + j0;
                let crow = &mut c[row..row + jb];
                for (jj, cv) in crow.iter_mut().enumerate() {
                    let mut v = acc[ii][jj];
                    if let Some(bs) = bias {
                        v += bs[j0 + jj];
                    }
                    if relu && v < 0.0 {
                        v = 0.0;
                    }
                    *cv = v;
                }
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// `c[m × n] = a[m × k] · b[k × n]` (+ `bias` broadcast over rows)
/// (then ReLU), all row-major.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_act(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    assert!(a.len() >= m * k, "a too short: {} < {}", a.len(), m * k);
    assert!(b.len() >= k * n, "b too short: {} < {}", b.len(), k * n);
    assert!(c.len() >= m * n, "c too short: {} < {}", c.len(), m * n);
    gemm_rows_into(a, b, bias, c, m, k, n, relu);
}

/// Plain `c = a · b` (no bias, no activation).
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_bias_act(a, b, None, c, m, k, n, false);
}

/// Pool-parallel [`gemm_bias_act`]: output rows are sharded across the
/// global pool in `MR`-aligned blocks (bit-identical to the serial run).
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_act_par(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) {
    assert!(a.len() >= m * k);
    assert!(b.len() >= k * n);
    assert!(c.len() >= m * n);
    let nt = pool::threads_for(m * k * n / 4);
    if nt <= 1 {
        gemm_rows_into(a, b, bias, c, m, k, n, relu);
        return;
    }
    // MR-aligned row blocks: each chunk's tiling matches the serial
    // pass, so the parallel result is bit-identical.
    let rows_per_t = m.div_ceil(MR).div_ceil(nt) * MR;
    pool::global().run_chunks(nt, &mut c[..m * n], rows_per_t * n, |c_sub, start| {
        let i0 = start / n;
        let rows = c_sub.len() / n;
        gemm_rows_into(&a[i0 * k..(i0 + rows) * k], b, bias, c_sub, rows, k, n, relu);
    });
}

/// Pool-parallel [`gemm`].
pub fn gemm_par(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    gemm_bias_act_par(a, b, None, c, m, k, n, false);
}

/// Serial core for the weight gradient over a row range of `g`:
/// `g[rows × n] += aᵀ · d` restricted to `a`'s columns
/// `[col0, col0 + rows)`.  `a` is `[m × k]`, `d` is `[m × n]`.
#[allow(clippy::too_many_arguments)]
fn at_b_acc_rows(
    a: &[f32],
    d: &[f32],
    g: &mut [f32],
    col0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert!(a.len() >= m * k);
    debug_assert!(d.len() >= m * n);
    debug_assert!(g.len() >= rows * n);
    let mut i0 = 0;
    while i0 < rows {
        let ib = MR.min(rows - i0);
        let mut j0 = 0;
        while j0 < n {
            let jb = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            for r in 0..m {
                let abase = r * k + col0 + i0;
                let arow = &a[abase..abase + ib];
                let drow = &d[r * n + j0..r * n + j0 + jb];
                for (ii, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue; // ReLU sparsity: dead activations add nothing
                    }
                    let accr = &mut acc[ii];
                    for (jj, &dv) in drow.iter().enumerate() {
                        accr[jj] += av * dv;
                    }
                }
            }
            for ii in 0..ib {
                let row = (i0 + ii) * n + j0;
                let grow = &mut g[row..row + jb];
                for (jj, gv) in grow.iter_mut().enumerate() {
                    *gv += acc[ii][jj];
                }
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// Weight gradient: `g[k × n] += aᵀ · d` where `a` is `[m × k]` (batch
/// activations) and `d` is `[m × n]` (batch deltas), all row-major.
pub fn gemm_at_b_acc(a: &[f32], d: &[f32], g: &mut [f32], m: usize, k: usize, n: usize) {
    assert!(a.len() >= m * k);
    assert!(d.len() >= m * n);
    assert!(g.len() >= k * n);
    at_b_acc_rows(a, d, g, 0, k, m, k, n);
}

/// Pool-parallel [`gemm_at_b_acc`]: `g`'s rows (the fan-in dimension)
/// are sharded in `MR`-aligned blocks (bit-identical to serial).
pub fn gemm_at_b_acc_par(a: &[f32], d: &[f32], g: &mut [f32], m: usize, k: usize, n: usize) {
    assert!(a.len() >= m * k);
    assert!(d.len() >= m * n);
    assert!(g.len() >= k * n);
    let nt = pool::threads_for(m * k * n / 4);
    if nt <= 1 {
        at_b_acc_rows(a, d, g, 0, k, m, k, n);
        return;
    }
    let rows_per_t = k.div_ceil(MR).div_ceil(nt) * MR;
    pool::global().run_chunks(nt, &mut g[..k * n], rows_per_t * n, |g_sub, start| {
        at_b_acc_rows(a, d, g_sub, start / n, g_sub.len() / n, m, k, n);
    });
}

/// `dst[cols × rows] = srcᵀ` for row-major `src[rows × cols]`, blocked
/// 32×32 so both sides stream through cache.  The backward pass
/// transposes each layer's `W[fan_in × fan_out]` once per step (O(k·n),
/// amortized by the O(b·k·n) GEMM it enables).
pub fn transpose(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    assert!(src.len() >= rows * cols);
    assert!(dst.len() >= rows * cols);
    const TB: usize = 32;
    let mut i0 = 0;
    while i0 < rows {
        let i1 = (i0 + TB).min(rows);
        let mut j0 = 0;
        while j0 < cols {
            let j1 = (j0 + TB).min(cols);
            for i in i0..i1 {
                for j in j0..j1 {
                    dst[j * rows + i] = src[i * cols + j];
                }
            }
            j0 += TB;
        }
        i0 += TB;
    }
}

/// The seed's scalar kernels, retained verbatim as the parity oracle for
/// the blocked implementations (and for `bench_perf_round`'s
/// blocked-vs-naive comparison).
pub mod naive {
    /// Scalar i-k-j forward: bias init, ReLU-sparsity skip, activation
    /// pass at the end — exactly the seed's `MlpRef::forward_internal`
    /// inner loop.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_bias_act(
        a: &[f32],
        b: &[f32],
        bias: Option<&[f32]>,
        c: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) {
        for r in 0..m {
            let row_in = &a[r * k..(r + 1) * k];
            let row_out = &mut c[r * n..(r + 1) * n];
            match bias {
                Some(bs) => row_out.copy_from_slice(&bs[..n]),
                None => row_out.fill(0.0),
            }
            for (i, &xi) in row_in.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let brow = &b[i * n..(i + 1) * n];
                for (o, &bv) in brow.iter().enumerate() {
                    row_out[o] += xi * bv;
                }
            }
            if relu {
                for v in row_out.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
        }
    }

    /// Scalar `c = a · b`.
    pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
        gemm_bias_act(a, b, None, c, m, k, n, false);
    }

    /// Scalar `g += aᵀ · d` — the seed's grad-W loop.
    pub fn gemm_at_b_acc(a: &[f32], d: &[f32], g: &mut [f32], m: usize, k: usize, n: usize) {
        for r in 0..m {
            let arow = &a[r * k..(r + 1) * k];
            let drow = &d[r * n..(r + 1) * n];
            for (i, &ai) in arow.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let gr = &mut g[i * n..(i + 1) * n];
                for (o, &dv) in drow.iter().enumerate() {
                    gr[o] += ai * dv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn randv(len: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from(seed);
        (0..len).map(|_| r.next_f32() - 0.5).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tag: &str) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-4 * (1.0 + x.abs()),
                "{tag}[{i}]: blocked {y} vs naive {x}"
            );
        }
    }

    #[test]
    fn blocked_matches_naive_on_tile_multiples() {
        let (m, k, n) = (16, 32, 24);
        let a = randv(m * k, 1);
        let b = randv(k * n, 2);
        let mut c_ref = vec![0.0; m * n];
        let mut c = vec![0.0; m * n];
        naive::gemm(&a, &b, &mut c_ref, m, k, n);
        gemm(&a, &b, &mut c, m, k, n);
        assert_close(&c_ref, &c, "gemm");
    }

    #[test]
    fn fused_bias_relu_matches_naive() {
        let (m, k, n) = (5, 7, 10);
        let a = randv(m * k, 3);
        let b = randv(k * n, 4);
        let bias = randv(n, 5);
        let mut c_ref = vec![0.0; m * n];
        let mut c = vec![0.0; m * n];
        naive::gemm_bias_act(&a, &b, Some(&bias), &mut c_ref, m, k, n, true);
        gemm_bias_act(&a, &b, Some(&bias), &mut c, m, k, n, true);
        assert_close(&c_ref, &c, "bias_relu");
        assert!(c.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn at_b_accumulates_on_top() {
        let (m, k, n) = (9, 11, 13);
        let a = randv(m * k, 6);
        let d = randv(m * n, 7);
        let mut g_ref = randv(k * n, 8);
        let mut g = g_ref.clone();
        naive::gemm_at_b_acc(&a, &d, &mut g_ref, m, k, n);
        gemm_at_b_acc(&a, &d, &mut g, m, k, n);
        assert_close(&g_ref, &g, "at_b");
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let (m, k, n) = (64, 96, 80);
        let a = randv(m * k, 9);
        let b = randv(k * n, 10);
        let mut c_ser = vec![0.0; m * n];
        let mut c_par = vec![0.0; m * n];
        gemm(&a, &b, &mut c_ser, m, k, n);
        // Force a 2-lane parallel split regardless of the work heuristic
        // and the host's core count (private pool with one worker).
        let two_lane = pool::ThreadPool::new(1);
        let rows_per_t = m.div_ceil(MR).div_ceil(2) * MR;
        two_lane.run_chunks(2, &mut c_par, rows_per_t * n, |c_sub, start| {
            let i0 = start / n;
            let rows = c_sub.len() / n;
            gemm_bias_act(&a[i0 * k..(i0 + rows) * k], &b, None, c_sub, rows, k, n, false);
        });
        assert_eq!(c_ser, c_par);
    }

    #[test]
    fn transpose_roundtrip() {
        let (r, c) = (37, 53);
        let src = randv(r * c, 11);
        let mut t = vec![0.0; r * c];
        let mut back = vec![0.0; r * c];
        transpose(&src, &mut t, r, c);
        transpose(&t, &mut back, c, r);
        assert_eq!(src, back);
        assert_eq!(t[5 * r + 3], src[3 * c + 5]);
    }
}
