//! Neural-network architecture description + a pure-Rust reference MLP.
//!
//! The flat weight layout (per-layer `W[fan_in × fan_out]` row-major, then
//! `b[fan_out]`) is the contract shared with the L2 JAX model
//! (`python/compile/model.py::Arch.slices`) and with the AOT artifacts —
//! index `i` of the flat vector means the same weight on both sides, so
//! the per-weight fan-in `n_ℓ` used by the σ_i of Eq. (1) lines up.
//!
//! The pure-Rust forward/backward ([`MlpRef`]) is an XLA-free fallback and
//! the oracle the runtime integration tests compare PJRT results against.

pub mod gemm;
pub mod mlp;

pub use mlp::{one_hot_into, MlpRef};

/// Feedforward architecture: `layers = (in, h1, ..., out)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArchSpec {
    pub name: String,
    pub layers: Vec<usize>,
}

/// One layer's slice of the flat parameter vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerSlice {
    /// Offset of `W` in the flat vector.
    pub offset: usize,
    pub fan_in: usize,
    pub fan_out: usize,
    /// `fan_in * fan_out`.
    pub w_len: usize,
    /// `fan_out`.
    pub b_len: usize,
}

impl ArchSpec {
    pub fn new(name: &str, layers: &[usize]) -> Self {
        assert!(layers.len() >= 2, "need at least input and output layers");
        Self { name: name.to_string(), layers: layers.to_vec() }
    }

    /// The paper's SMALL ARCHITECTURE: 784-20-20-10 (§3, two hidden layers
    /// of twenty neurons).
    pub fn small() -> Self {
        Self::new("small", &[784, 20, 20, 10])
    }

    /// The paper's MNISTFC: 784-300-100-10 ("exactly as the one in Zhou"),
    /// m = 266,610 (§3.2).
    pub fn mnistfc() -> Self {
        Self::new("mnistfc", &[784, 300, 100, 10])
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "small" => Some(Self::small()),
            "mnistfc" => Some(Self::mnistfc()),
            _ => None,
        }
    }

    /// Total number of parameters `m`.
    pub fn num_params(&self) -> usize {
        self.slices().map(|s| s.w_len + s.b_len).sum()
    }

    pub fn input_dim(&self) -> usize {
        self.layers[0]
    }

    pub fn output_dim(&self) -> usize {
        *self.layers.last().unwrap()
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len() - 1
    }

    /// Iterate the per-layer slices of the flat vector.
    pub fn slices(&self) -> impl Iterator<Item = LayerSlice> + '_ {
        let mut offset = 0usize;
        self.layers.windows(2).map(move |w| {
            let (fan_in, fan_out) = (w[0], w[1]);
            let s = LayerSlice { offset, fan_in, fan_out, w_len: fan_in * fan_out, b_len: fan_out };
            offset += s.w_len + s.b_len;
            s
        })
    }

    /// Fan-in of the neuron that flat parameter `i` feeds — the `n_ℓ` in
    /// the σ_i² = 6/(d·n_ℓ) of Eq. (1).  Biases take their layer's fan-in
    /// (they target the same neuron as the layer's weights).
    pub fn fan_in_of(&self, i: usize) -> usize {
        for s in self.slices() {
            if i < s.offset + s.w_len + s.b_len {
                return s.fan_in;
            }
        }
        panic!("parameter index {i} out of range ({})", self.num_params());
    }

    /// Materialize the per-parameter fan-in table (used hot by the Q
    /// generator; O(m) once instead of O(layers) per lookup).
    pub fn fan_in_table(&self) -> Vec<u32> {
        let mut t = Vec::with_capacity(self.num_params());
        for s in self.slices() {
            t.extend(std::iter::repeat(s.fan_in as u32).take(s.w_len + s.b_len));
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_paper() {
        assert_eq!(ArchSpec::mnistfc().num_params(), 266_610); // §3.2
        assert_eq!(ArchSpec::small().num_params(), 16_330);
    }

    #[test]
    fn slices_tile_the_flat_vector_exactly() {
        for arch in [ArchSpec::small(), ArchSpec::mnistfc()] {
            let mut expected_offset = 0;
            for s in arch.slices() {
                assert_eq!(s.offset, expected_offset);
                expected_offset += s.w_len + s.b_len;
            }
            assert_eq!(expected_offset, arch.num_params());
        }
    }

    #[test]
    fn fan_in_table_matches_point_lookup() {
        let arch = ArchSpec::small();
        let table = arch.fan_in_table();
        assert_eq!(table.len(), arch.num_params());
        for i in [0usize, 783, 784 * 20, 784 * 20 + 19, 784 * 20 + 20, 16_329] {
            assert_eq!(table[i] as usize, arch.fan_in_of(i), "i={i}");
        }
    }

    #[test]
    fn fan_in_boundaries() {
        let arch = ArchSpec::small();
        // First layer weights + biases: fan_in 784.
        assert_eq!(arch.fan_in_of(0), 784);
        assert_eq!(arch.fan_in_of(784 * 20 + 19), 784); // last bias of layer 0
        // Second layer starts right after: fan_in 20.
        assert_eq!(arch.fan_in_of(784 * 20 + 20), 20);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(ArchSpec::by_name("small").unwrap(), ArchSpec::small());
        assert_eq!(ArchSpec::by_name("mnistfc").unwrap(), ArchSpec::mnistfc());
        assert!(ArchSpec::by_name("nope").is_none());
    }
}
