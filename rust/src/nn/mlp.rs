//! Pure-Rust reference MLP: forward, softmax-CE loss, and full backward.
//!
//! Mirrors `python/compile/model.py` exactly (same flat layout, same
//! padding-aware weighted loss) so it can serve as (a) the oracle that the
//! PJRT-loaded artifacts are integration-tested against, and (b) an
//! XLA-free execution path (`Backend::Native`) for environments without
//! the PJRT shared library.
//!
//! The layer products run on the blocked GEMM kernels of [`super::gemm`]
//! (fused bias+ReLU forward, transposed-weight backward), which shard
//! across the process pool at MnistFc scale; the seed's scalar loops
//! survive as `gemm::naive`, the parity oracle.

use super::{gemm, ArchSpec};

/// Scratch-buffer MLP evaluator over a flat weight vector.
pub struct MlpRef {
    arch: ArchSpec,
    /// Per-layer activations (pre-allocated; `acts[0]` is the input copy).
    acts: Vec<Vec<f32>>,
    /// Per-layer pre-activation gradients (backward scratch).
    deltas: Vec<Vec<f32>>,
    /// Transposed-weight scratch (`Wᵀ` of the widest layer): lets the
    /// backward data gradient `Δ·Wᵀ` run as a plain row-major GEMM.
    wt: Vec<f32>,
    batch_cap: usize,
}

/// Output of one train/eval step (matches the artifact tuple).
#[derive(Clone, Debug)]
pub struct StepOut {
    pub loss: f32,
    pub correct: f32,
}

impl MlpRef {
    pub fn new(arch: ArchSpec, batch_cap: usize) -> Self {
        let mut acts = Vec::with_capacity(arch.layers.len());
        let mut deltas = Vec::with_capacity(arch.layers.len());
        for &width in &arch.layers {
            acts.push(vec![0.0; batch_cap * width]);
            deltas.push(vec![0.0; batch_cap * width]);
        }
        // Backward never transposes layer 0 (no delta_prev at the input),
        // so the scratch is sized by the widest *later* layer.
        let wt_len = arch.slices().skip(1).map(|s| s.w_len).max().unwrap_or(0);
        Self { arch, acts, deltas, wt: vec![0.0; wt_len], batch_cap }
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }

    /// Forward pass: fills internal activations, returns logits slice len.
    /// `x` is `[b, in_dim]` row-major, `b ≤ batch_cap`.
    fn forward_internal(&mut self, w: &[f32], x: &[f32], b: usize) {
        let in_dim = self.arch.input_dim();
        debug_assert_eq!(x.len(), b * in_dim);
        debug_assert!(b <= self.batch_cap);
        self.acts[0][..b * in_dim].copy_from_slice(x);

        let slices: Vec<_> = self.arch.slices().collect();
        for (l, s) in slices.iter().enumerate() {
            let is_last = l + 1 == slices.len();
            // acts[l+1] = act(acts[l] @ W + b) — fused blocked GEMM.
            let (prev, rest) = self.acts.split_at_mut(l + 1);
            let a_in = &prev[l][..b * s.fan_in];
            let a_out = &mut rest[0][..b * s.fan_out];
            let wmat = &w[s.offset..s.offset + s.w_len];
            let bias = &w[s.offset + s.w_len..s.offset + s.w_len + s.b_len];
            gemm::gemm_bias_act_par(a_in, wmat, Some(bias), a_out, b, s.fan_in, s.fan_out, !is_last);
        }
    }

    /// Logits for a batch (copies out of the scratch buffer).
    pub fn forward(&mut self, w: &[f32], x: &[f32], b: usize) -> Vec<f32> {
        self.forward_internal(w, x, b);
        let out_dim = self.arch.output_dim();
        self.acts.last().unwrap()[..b * out_dim].to_vec()
    }

    /// Eval step: padding-aware weighted CE loss + correct count.
    /// Rows whose one-hot sums to zero are padding.
    pub fn eval_step(&mut self, w: &[f32], x: &[f32], y1h: &[f32], b: usize) -> StepOut {
        self.forward_internal(w, x, b);
        let out_dim = self.arch.output_dim();
        let logits = &self.acts.last().unwrap()[..b * out_dim];
        let (mut loss_sum, mut denom, mut correct) = (0.0f64, 0.0f64, 0.0f64);
        for r in 0..b {
            let lr = &logits[r * out_dim..(r + 1) * out_dim];
            let yr = &y1h[r * out_dim..(r + 1) * out_dim];
            let roww: f32 = yr.iter().sum();
            if roww == 0.0 {
                continue;
            }
            denom += roww as f64;
            let max = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = max + lr.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>().ln() as f32;
            let (mut amax_l, mut amax_y) = (0usize, 0usize);
            for o in 0..out_dim {
                if lr[o] > lr[amax_l] {
                    amax_l = o;
                }
                if yr[o] > yr[amax_y] {
                    amax_y = o;
                }
                loss_sum += (yr[o] * (lse - lr[o])) as f64;
            }
            if amax_l == amax_y {
                correct += roww as f64;
            }
        }
        StepOut { loss: (loss_sum / denom.max(1.0)) as f32, correct: correct as f32 }
    }

    /// Train step: loss, `grad_w` (accumulated into `grad`, which is
    /// zeroed first), correct count.  Matches
    /// `jax.value_and_grad(loss_and_correct)` numerics.
    pub fn train_step(
        &mut self,
        w: &[f32],
        x: &[f32],
        y1h: &[f32],
        b: usize,
        grad: &mut [f32],
    ) -> StepOut {
        assert_eq!(grad.len(), w.len());
        self.forward_internal(w, x, b);
        grad.fill(0.0);
        let out_dim = self.arch.output_dim();
        let slices: Vec<_> = self.arch.slices().collect();
        let last = slices.len() - 1;

        // Softmax-CE gradient at the head: delta = (softmax - y) * roww/denom.
        let mut denom = 0.0f32;
        for r in 0..b {
            let roww: f32 = y1h[r * out_dim..(r + 1) * out_dim].iter().sum();
            denom += roww;
        }
        let denom = denom.max(1.0);

        let (mut loss_sum, mut correct) = (0.0f64, 0.0f64);
        {
            let logits = &self.acts[last + 1][..b * out_dim];
            let dl = &mut self.deltas[last + 1][..b * out_dim];
            for r in 0..b {
                let lr = &logits[r * out_dim..(r + 1) * out_dim];
                let yr = &y1h[r * out_dim..(r + 1) * out_dim];
                let roww: f32 = yr.iter().sum();
                let drow = &mut dl[r * out_dim..(r + 1) * out_dim];
                if roww == 0.0 {
                    drow.fill(0.0);
                    continue;
                }
                let max = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let sum_exp: f64 = lr.iter().map(|&v| ((v - max) as f64).exp()).sum();
                let lse = max as f64 + sum_exp.ln();
                let (mut amax_l, mut amax_y) = (0usize, 0usize);
                for o in 0..out_dim {
                    let p = (((lr[o] as f64) - lse).exp()) as f32;
                    drow[o] = (p * roww - yr[o]) / denom;
                    if lr[o] > lr[amax_l] {
                        amax_l = o;
                    }
                    if yr[o] > yr[amax_y] {
                        amax_y = o;
                    }
                    loss_sum += (yr[o] as f64) * (lse - lr[o] as f64);
                }
                if amax_l == amax_y {
                    correct += roww as f64;
                }
            }
        }

        // Backward through the layers.
        for (l, s) in slices.iter().enumerate().rev() {
            let b_in = &self.acts[l];
            let (dcur, dprev_all) = {
                let (lo, hi) = self.deltas.split_at_mut(l + 1);
                (&mut hi[0], lo)
            };
            let dcur = &dcur[..b * s.fan_out];
            // grad_W = a_inᵀ @ delta (blocked, sharded over fan_in rows).
            let gw = &mut grad[s.offset..s.offset + s.w_len];
            gemm::gemm_at_b_acc_par(&b_in[..b * s.fan_in], dcur, gw, b, s.fan_in, s.fan_out);
            // grad_b[o] += delta[r,o]
            let gb = &mut grad[s.offset + s.w_len..s.offset + s.w_len + s.b_len];
            for r in 0..b {
                let drow = &dcur[r * s.fan_out..(r + 1) * s.fan_out];
                for (o, &dv) in drow.iter().enumerate() {
                    gb[o] += dv;
                }
            }
            // delta_prev = (delta @ Wᵀ) ⊙ relu'(a_in)   (skip for input
            // layer).  W is transposed once into the scratch so the data
            // gradient runs as a plain row-major blocked GEMM.
            if l > 0 {
                let wmat = &w[s.offset..s.offset + s.w_len];
                let wt = &mut self.wt[..s.w_len];
                gemm::transpose(wmat, wt, s.fan_in, s.fan_out);
                let dprev = &mut dprev_all[l][..b * s.fan_in];
                gemm::gemm_par(dcur, wt, dprev, b, s.fan_out, s.fan_in);
                let a_gate = &b_in[..b * s.fan_in];
                for (pv, &av) in dprev.iter_mut().zip(a_gate) {
                    if av <= 0.0 {
                        *pv = 0.0; // ReLU gate (a_in == post-ReLU act)
                    }
                }
            }
        }

        StepOut { loss: (loss_sum / denom as f64) as f32, correct: correct as f32 }
    }
}

/// One-hot encode labels into a reusable `[b, classes]` buffer.
pub fn one_hot_into(labels: &[u8], classes: usize, out: &mut [f32]) {
    assert!(out.len() >= labels.len() * classes);
    out[..labels.len() * classes].fill(0.0);
    for (r, &y) in labels.iter().enumerate() {
        out[r * classes + y as usize] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Normal, Rng, Xoshiro256pp};

    fn random_weights(arch: &ArchSpec, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256pp::seed_from(seed);
        let mut nrm = Normal::new();
        let mut w = vec![0.0f32; arch.num_params()];
        for s in arch.slices() {
            let std = (2.0 / s.fan_in as f64).sqrt();
            for i in 0..s.w_len {
                w[s.offset + i] = (nrm.sample(&mut r) * std) as f32;
            }
        }
        w
    }

    fn random_batch(arch: &ArchSpec, b: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut r = Xoshiro256pp::seed_from(seed);
        let x: Vec<f32> = (0..b * arch.input_dim()).map(|_| r.next_f32()).collect();
        let labels: Vec<u8> = (0..b).map(|_| r.next_below(10) as u8).collect();
        let mut y = vec![0.0f32; b * 10];
        one_hot_into(&labels, 10, &mut y);
        (x, y)
    }

    #[test]
    fn forward_shape_and_determinism() {
        let arch = ArchSpec::small();
        let w = random_weights(&arch, 0);
        let (x, _) = random_batch(&arch, 4, 1);
        let mut mlp = MlpRef::new(arch.clone(), 8);
        let a = mlp.forward(&w, &x, 4);
        let b = mlp.forward(&w, &x, 4);
        assert_eq!(a.len(), 4 * 10);
        assert_eq!(a, b);
    }

    #[test]
    fn loss_decreases_under_gradient_step() {
        let arch = ArchSpec::small();
        let mut w = random_weights(&arch, 2);
        let (x, y) = random_batch(&arch, 16, 3);
        let mut mlp = MlpRef::new(arch.clone(), 16);
        let mut g = vec![0.0f32; w.len()];
        let before = mlp.train_step(&w, &x, &y, 16, &mut g).loss;
        for (wi, gi) in w.iter_mut().zip(&g) {
            *wi -= 0.05 * gi;
        }
        let after = mlp.eval_step(&w, &x, &y, 16).loss;
        assert!(after < before, "before={before} after={after}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let arch = ArchSpec::new("tiny", &[6, 5, 3]);
        let mut r = Xoshiro256pp::seed_from(4);
        let mut nrm = Normal::new();
        let mut w: Vec<f32> =
            (0..arch.num_params()).map(|_| (nrm.sample(&mut r) * 0.5) as f32).collect();
        let x: Vec<f32> = (0..4 * 6).map(|_| r.next_f32() - 0.5).collect();
        let labels = [0u8, 1, 2, 1];
        let mut y = vec![0.0f32; 4 * 3];
        one_hot_into(&labels, 3, &mut y);
        let mut mlp = MlpRef::new(arch.clone(), 4);
        let mut g = vec![0.0f32; w.len()];
        mlp.train_step(&w, &x, &y, 4, &mut g);
        let eps = 1e-3f32;
        for idx in [0usize, 7, 13, arch.num_params() - 1] {
            let orig = w[idx];
            w[idx] = orig + eps;
            let lp = mlp.eval_step(&w, &x, &y, 4).loss;
            w[idx] = orig - eps;
            let lm = mlp.eval_step(&w, &x, &y, 4).loss;
            w[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[idx]).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx={idx} fd={fd} analytic={}",
                g[idx]
            );
        }
    }

    #[test]
    fn padding_rows_change_nothing() {
        let arch = ArchSpec::small();
        let w = random_weights(&arch, 5);
        let (x, y) = random_batch(&arch, 8, 6);
        let mut mlp = MlpRef::new(arch.clone(), 16);
        let a = mlp.eval_step(&w, &x, &y, 8);
        // pad to 16 rows with zero x / zero one-hot
        let mut xp = x.clone();
        xp.resize(16 * arch.input_dim(), 0.0);
        let mut yp = y.clone();
        yp.resize(16 * 10, 0.0);
        let b = mlp.eval_step(&w, &xp, &yp, 16);
        assert!((a.loss - b.loss).abs() < 1e-6);
        assert_eq!(a.correct, b.correct);
    }

    #[test]
    fn one_hot_basics() {
        let mut out = vec![9.0f32; 6];
        one_hot_into(&[2, 0], 3, &mut out);
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
    }
}
