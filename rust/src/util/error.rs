//! Minimal `anyhow`-compatible error plumbing (the real crate is
//! unavailable offline; see Cargo.toml).
//!
//! Provides the subset the crate actually uses: a string-backed [`Error`],
//! `Result<T>` defaulting to it, a [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros (exported at the crate root, so
//! call sites use `crate::anyhow!` or import them with
//! `use crate::{anyhow, bail}`).

use std::fmt;

/// String-backed error: contexts are folded into the message eagerly
/// (`"context: source"`), which is all the CLI and tests ever render.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like `anyhow`, `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket `From` can absorb every std error
// type without overlapping `From<Error> for Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` subset: attach a message to the error of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Result<u32> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_absorbs_std_errors() {
        fn inner() -> Result<u32> {
            let v = io_err().context("reading thing")?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("reading thing"), "{e}");
        assert!(e.to_string().contains("gone"), "{e}");
    }

    #[test]
    fn macros_format_and_bail() {
        fn inner(x: usize) -> Result<usize> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert!(inner(12).unwrap_err().to_string().contains("x too big: 12"));
        assert!(inner(3).unwrap_err().to_string().contains("right out"));
        let e = crate::anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn with_context_is_lazy_formatting() {
        let r: std::result::Result<(), &str> = Err("boom");
        let e = r.with_context(|| format!("step {}", 4)).unwrap_err();
        assert_eq!(e.to_string(), "step 4: boom");
    }
}
