//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [--flag] [--key value]...`.  Flags may be
//! given as `--key=value` or `--key value`; unknown keys are an error so
//! typos never silently fall back to defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                args.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.kv.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.kv.insert(body.to_string(), iter.next().unwrap());
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Error if any provided `--key` was never consumed by the command.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_kv_and_flags() {
        // NOTE: boolean flags must not directly precede positionals —
        // `--verbose pos1` would parse as verbose=pos1 (same ambiguity
        // clap resolves via declarations, which we don't have).
        let a = parse("train-local pos1 --config c.toml --seed=7 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train-local"));
        assert_eq!(a.str_or("config", ""), "c.toml");
        assert_eq!(a.u64_or("seed", 0), 7);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn unknown_option_is_rejected() {
        let a = parse("run --oops 1");
        let _ = a.str_or("config", "");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("x --dry-run --seed 3");
        assert!(a.flag("dry-run"));
        assert_eq!(a.u64_or("seed", 0), 3);
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize_or("rounds", 100), 100);
        assert_eq!(a.f64_or("lr", 0.1), 0.1);
    }
}
