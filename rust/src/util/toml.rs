//! TOML-subset parser for the config system (`configs/*.toml`).
//!
//! Supported grammar — the subset the configs actually use:
//! `[section]` headers (one level), `key = value` with string / integer /
//! float / bool / homogeneous scalar array values, `#` comments, blank
//! lines.  Dotted keys, inline tables, arrays-of-tables and multi-line
//! strings are out of scope and rejected loudly.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: `table["section.key"] = value`; top-level keys have no
/// section prefix.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err("arrays of tables are not supported"));
                }
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                    return Err(err("bad section name (one level, [a-zA-Z0-9_-])"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
                return Err(err("bad key (dotted keys unsupported)"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(err(&format!("duplicate key '{full}'")));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        TomlDoc::parse(&src).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Typo guard: every present key must be in `known` (exact match).
    pub fn check_known_keys(&self, known: &[&str]) -> Result<(), String> {
        for k in self.entries.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown config key '{k}' (known: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a basic string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("escapes/embedded quotes unsupported".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<TomlValue>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig3"          # inline comment
seed = 42
lr = 0.001
big = 266_610
flag = true

[zampling]
d-values = [1, 5, 10, 50, 100]
factors = [1.0, 2.0]
arch = "small"
"#;

    #[test]
    fn parses_sample() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.str_or("name", ""), "fig3");
        assert_eq!(doc.usize_or("seed", 0), 42);
        assert!((doc.f64_or("lr", 0.0) - 0.001).abs() < 1e-12);
        assert_eq!(doc.usize_or("big", 0), 266_610);
        assert!(doc.bool_or("flag", false));
        assert_eq!(doc.str_or("zampling.arch", ""), "small");
        let ds: Vec<usize> = doc
            .get("zampling.d-values")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(ds, vec![1, 5, 10, 50, 100]);
    }

    #[test]
    fn rejects_unsupported_and_malformed() {
        for bad in [
            "[[table]]",
            "a.b = 1",
            "x = ",
            "x = \"unterminated",
            "[sec\nx=1",
            "x = 1\nx = 2",
            "x = nope",
        ] {
            assert!(TomlDoc::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn unknown_key_guard() {
        let doc = TomlDoc::parse("a = 1\n[s]\nb = 2").unwrap();
        assert!(doc.check_known_keys(&["a", "s.b"]).is_ok());
        assert!(doc.check_known_keys(&["a"]).is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"x = "a#b""##).unwrap();
        assert_eq!(doc.str_or("x", ""), "a#b");
    }
}
