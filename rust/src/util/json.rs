//! Minimal JSON: a recursive-descent parser + a writer.
//!
//! Used to read `artifacts/manifest.json` (shapes the AOT step lowered
//! with) and to emit experiment records.  Supports the full JSON grammar
//! except `\u` surrogate pairs beyond the BMP; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for emitting records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(v: I) -> Json {
    Json::Arr(v.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"fused":[{"arch":"mnistfc","c":448,"n":8331}],"x":[true,false,null,1.25]}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", r#"{"a" 1}"#, "tru", "1.2.3", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn reads_real_manifest_shape() {
        let src = r#"{"train_batch":128,"eval_batch":500,
            "archs":{"small":{"layers":[784,20,20,10],"num_params":16330,
            "train":{"path":"train_step_small.hlo.txt","bytes":1}}},
            "fused":[{"arch":"small","n":2041,"d":4,"c":88,"path":"f.hlo.txt"}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("train_batch").unwrap().as_usize(), Some(128));
        let small = j.get("archs").unwrap().get("small").unwrap();
        assert_eq!(small.get("num_params").unwrap().as_usize(), Some(16_330));
        assert_eq!(j.get("fused").unwrap().as_arr().unwrap()[0].get("c").unwrap().as_usize(), Some(88));
    }
}
