//! In-tree micro/macro-benchmark harness (criterion is unavailable
//! offline).  Emits the same kind of rows: warmup, N timed iterations,
//! mean ± stddev, median, and optional throughput.  Benches are
//! `harness = false` binaries that call [`Bencher::run`] per case and
//! [`table`]/[`row`] helpers for paper-table reproduction output.
//!
//! Perf benches additionally persist machine-readable baselines:
//! [`update_bench_json`] merges a bench binary's section into the
//! repo-root `BENCH_perf.json` (read–modify–write, one section per
//! bench), so the perf trajectory is tracked across PRs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::{self, Json};

/// Result statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub median: Duration,
    pub min: Duration,
    /// Optional bytes processed per iteration → throughput line.
    pub bytes_per_iter: Option<u64>,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.mean.as_secs_f64() / 1e9)
    }
}

/// Benchmark driver with criterion-like defaults.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Target total measurement time.
    pub target: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1_000,
            target: Duration::from_secs(2),
        }
    }
}

impl Bencher {
    /// Quick profile for heavy end-to-end cases.
    pub fn heavy() -> Self {
        Self { warmup_iters: 1, min_iters: 3, max_iters: 20, target: Duration::from_secs(5) }
    }

    /// Run `f` repeatedly; prints and returns the stats row.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        self.run_with_bytes(name, None, &mut f)
    }

    /// Run with a bytes-per-iteration annotation for throughput reporting.
    pub fn run_bytes<F: FnMut()>(&self, name: &str, bytes: u64, mut f: F) -> Stats {
        self.run_with_bytes(name, Some(bytes), &mut f)
    }

    fn run_with_bytes(&self, name: &str, bytes: Option<u64>, f: &mut dyn FnMut()) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        // Estimate a per-iter cost from one timed call, derive iter count.
        let probe = {
            let t = Instant::now();
            f();
            t.elapsed()
        };
        let per_iter = probe.max(Duration::from_nanos(1));
        let iters = ((self.target.as_secs_f64() / per_iter.as_secs_f64()) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let sum: Duration = samples.iter().sum();
        let mean = sum / iters as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / iters as f64;
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean,
            stddev: Duration::from_secs_f64(var.sqrt()),
            median: samples[iters / 2],
            min: samples[0],
            bytes_per_iter: bytes,
        };
        print_stats(&stats);
        stats
    }
}

fn print_stats(s: &Stats) {
    let tp = s
        .throughput_gbps()
        .map(|g| format!("  thrpt: {g:.3} GB/s"))
        .unwrap_or_default();
    println!(
        "bench {:<44} time: [{} ± {}]  median: {}  min: {}  ({} iters){tp}",
        s.name,
        fmt_dur(s.mean),
        fmt_dur(s.stddev),
        fmt_dur(s.median),
        fmt_dur(s.min),
        s.iters,
    );
}

/// Human duration like criterion's.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Paper-table output helpers: fixed-width aligned rows under a header.
pub fn table(title: &str, header: &[&str]) {
    println!("\n=== {title} ===");
    row(header);
    println!("{}", "-".repeat(header.len() * 16));
}

pub fn row<S: AsRef<str>>(cells: &[S]) {
    let line: Vec<String> = cells.iter().map(|c| format!("{:<15}", c.as_ref())).collect();
    println!("{}", line.join(" "));
}

/// Target for [`update_bench_json`]: `$BENCH_JSON` if set (for perf
/// hosts running a relocated binary), else the repo-root
/// `BENCH_perf.json` next to the workspace manifest (compile-time path —
/// correct when the bench runs from the checkout that built it).
pub fn bench_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("BENCH_JSON") {
        return PathBuf::from(p);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_perf.json")
}

fn stats_json(s: &Stats) -> Json {
    let mut fields = vec![
        ("name", json::s(&s.name)),
        ("iters", json::num(s.iters as f64)),
        ("mean_ns", json::num(s.mean.as_nanos() as f64)),
        ("stddev_ns", json::num(s.stddev.as_nanos() as f64)),
        ("median_ns", json::num(s.median.as_nanos() as f64)),
        ("min_ns", json::num(s.min.as_nanos() as f64)),
    ];
    if let Some(g) = s.throughput_gbps() {
        fields.push(("throughput_gbps", json::num(g)));
    }
    json::obj(fields)
}

/// Merge one bench binary's results into `path` as `section`, keeping
/// every other section intact (each `bench_perf_*` owns one section).
/// `extra` carries derived headline numbers (e.g. speedups) that a perf
/// gate can read without re-deriving them from the raw rows.
pub fn update_bench_json(
    path: &Path,
    section: &str,
    stats: &[Stats],
    extra: &[(&str, f64)],
) -> std::io::Result<()> {
    // Only a genuinely absent file starts fresh.  A present-but-bad file
    // (unparseable, non-object) or a failing read is an error, not a
    // reset: silently replacing it would wipe the other benches'
    // sections.
    let mut root = match std::fs::read_to_string(path) {
        Ok(src) => match Json::parse(&src) {
            Ok(Json::Obj(m)) => m,
            Ok(_) | Err(_) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{} exists but is not a JSON object; fix or delete it", path.display()),
                ))
            }
        },
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Default::default(),
        Err(e) => return Err(e),
    };
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut fields = vec![
        ("status", json::s("measured")),
        ("threads", json::num(threads as f64)),
        ("cases", json::arr(stats.iter().map(stats_json))),
    ];
    let extras: Vec<(&str, Json)> = extra.iter().map(|&(k, v)| (k, json::num(v))).collect();
    if !extras.is_empty() {
        fields.push(("derived", json::obj(extras)));
    }
    root.insert(section.to_string(), json::obj(fields));
    std::fs::write(path, Json::Obj(root).to_string() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_sane() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 10,
            target: Duration::from_millis(10),
        };
        let mut acc = 0u64;
        let s = b.run("spin", || {
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
        });
        assert!(s.iters >= 5 && s.iters <= 10);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_secs(1),
            stddev: Duration::ZERO,
            median: Duration::from_secs(1),
            min: Duration::from_secs(1),
            bytes_per_iter: Some(2_000_000_000),
        };
        assert!((s.throughput_gbps().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bench_json_merges_sections() {
        let dir = std::env::temp_dir().join(format!("zampling-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let s = Stats {
            name: "case-a".into(),
            iters: 3,
            mean: Duration::from_micros(10),
            stddev: Duration::ZERO,
            median: Duration::from_micros(10),
            min: Duration::from_micros(9),
            bytes_per_iter: Some(1000),
        };
        update_bench_json(&path, "alpha", &[s.clone()], &[("speedup", 2.5)]).unwrap();
        update_bench_json(&path, "beta", &[s], &[]).unwrap();
        let root = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap())
            .expect("valid json");
        let alpha = root.get("alpha").expect("alpha kept after beta merge");
        assert_eq!(
            alpha.get("derived").and_then(|d| d.get("speedup")).and_then(|v| v.as_f64()),
            Some(2.5)
        );
        let beta_cases = root.get("beta").and_then(|b| b.get("cases")).and_then(|c| c.as_arr());
        assert_eq!(beta_cases.map(|c| c.len()), Some(1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(5)), "5 ns");
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
