//! In-tree utility layer replacing crates that are unavailable offline
//! (serde/serde_json, toml, clap, criterion, proptest — see Cargo.toml).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod toml;
