//! Minimal property-testing driver (proptest is unavailable offline).
//!
//! [`for_all`] runs a property over `cases` randomly-generated inputs from
//! a deterministic seed; on failure it reports the failing case index and
//! seed so the exact input can be re-derived.  Generators are plain
//! closures over [`Gen`], which wraps the crate RNG with convenience
//! samplers.  No shrinking — failures print the generated value instead
//! (inputs here are small enough to eyeball).

use crate::rng::{Rng, Xoshiro256pp};

/// Generator context handed to strategies.
pub struct Gen {
    pub rng: Xoshiro256pp,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        debug_assert!(lo <= hi_inclusive);
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| lo + self.rng.next_f32() * (hi - lo)).collect()
    }

    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

/// Run `prop` over `cases` generated inputs.  `make` draws an input from
/// the generator; `prop` returns `Err(reason)` on violation.
pub fn for_all<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    seed: u64,
    mut make: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut g = Gen { rng: Xoshiro256pp::seed_from(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) };
        let input = make(&mut g);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case}/{cases} (seed {seed}):\n  \
                 input: {input:?}\n  reason: {reason}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        for_all(
            "reverse-reverse-id",
            50,
            7,
            |g| {
                let len = g.usize_in(0, 20);
                g.f32_vec(len, -1.0, 1.0)
            },
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                if r == *v {
                    Ok(())
                } else {
                    Err("reverse twice changed the vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        for_all("always-fails", 3, 0, |g| g.usize_in(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn generation_is_deterministic_per_case() {
        let mut first: Vec<usize> = Vec::new();
        for_all(
            "capture",
            5,
            99,
            |g| g.usize_in(0, 1000),
            |&x| {
                first.push(x);
                Ok(())
            },
        );
        let mut second: Vec<usize> = Vec::new();
        for_all(
            "capture",
            5,
            99,
            |g| g.usize_in(0, 1000),
            |&x| {
                second.push(x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
