//! Multi-process test fleets from declarative scenario files
//! (`repro testnet --scenario configs/testnet/<name>.toml`).
//!
//! A scenario describes one wire-transport run end to end: which
//! federated config to use, where the root listens, which processes to
//! kill when (the chaos schedule), and how strictly the finished run
//! must match its **in-process twin** — the simulator transport that
//! produces byte-identical results by construction
//! (`ShardedSimTransport` and friends in `federated::sim`).  The
//! orchestrator spawns the whole fleet (root + `serve-shard` +
//! `serve-client` / `serve-peer` processes) from the current `repro`
//! binary, collects every process's log under
//! `<out>/<scenario-name>/`, waits for the root, and then replays the
//! same run in process to diff `final_probs.bin` and `ledger.csv`.
//!
//! Scenario TOML schema (see `docs/TESTNET.md` for the full story):
//!
//! ```toml
//! [scenario]
//! name         = "tree-depth2"         # output directory name
//! config       = "fed_tree_depth2.toml" # relative to the scenario file
//! listen       = "127.0.0.1:7757"      # root bind address (port plan base)
//! timeout-secs = 120                   # whole-scenario wall clock cap
//! compare      = "full"                # full | rounds | probs | none
//! chaos        = ["kill-shard:1@2"]    # optional kill/restart schedule
//! expect-log   = ["shard-1:merge"]     # optional "<log>:<substring>" greps
//! ```
//!
//! Chaos grammar — each entry maps onto one process's chaos flag:
//!
//! * `kill-shard:S@R` — shard leader `S` exits cleanly the moment round
//!   `R`'s frame arrives (`serve-shard --fail-at-round R`); its whole
//!   subtree goes dark for the rest of the run and the root
//!   renormalizes over the survivors.
//! * `kill-client:K@R` — worker `K` exits at round `R`
//!   (`serve-client --fail-at-round R`).  Append `+restart` and the
//!   orchestrator respawns the worker (without the flag) as soon as it
//!   observes the exit; the fresh process re-derives all state from the
//!   shared seed and rejoins via the leader's reconnect path.
//! * `kill-peer:I@R` — gossip node `I` exits right after reporting
//!   round `R` (`serve-peer --die-after-round R`).
//! * `kill-root:R+resume` — the *root* errors out at the start of round
//!   `R` (`train-federated --fail-at-round R`); the orchestrator
//!   respawns it as `repro resume --checkpoint <root>/checkpoint.bin`
//!   (logged to `root-restart.log`), which replays the interrupted
//!   round from the last checkpoint boundary while the workers (and
//!   shard processes) reconnect.  The config must set
//!   `federated.checkpoint-every` so a checkpoint exists by round `R`;
//!   the finished run is byte-identical to the uninterrupted twin, so
//!   `compare = "full"` is the natural pairing.
//! * `join:K@R` — spawn worker `K` (a *new* id:
//!   `clients <= K < max-clients`) once the root's log reports round
//!   `R`, exercising elastic admission at the next round boundary.  The
//!   twin replays the root's observed `round R  joined clients [..]`
//!   lines through `run_federated_elastic`, so `compare = "full"` holds
//!   despite the join round depending on connect timing.
//!
//! Compare modes, strongest first:
//!
//! * `full`   — `ledger.csv` and `final_probs.bin` byte-equal to the twin.
//! * `rounds` — the per-round ledger section and `final_probs.bin`
//!   byte-equal (per-shard rows may legitimately differ: at tree depth
//!   ≥ 3 the root bills per-direct-child *subtree* totals, while the
//!   flat simulator bills per leaf shard).
//! * `probs`  — `final_probs.bin` only (used where drop billing depends
//!   on reconnect timing, e.g. kill-and-restart).
//! * `none`   — completion only (gossip has no centralized twin ledger).
//!
//! The kill-and-restart twin replays the *observed* drop schedule: the
//! exact rounds a client missed depend on reconnect timing, so the
//! orchestrator parses the root's verbose `round R  dropped clients
//! [..]` lines and hands them to `run_federated_with_drop_schedule`,
//! which resets the replayed client exactly like the real restart does.
//!
//! Every child is armed with `PR_SET_PDEATHSIG` (SIGKILL on orchestrator
//! death) *and* tracked in a `Fleet` guard whose `Drop` kills and reaps the
//! whole fleet — a failing or panicking scenario cannot leak processes.
//! Spawned pids are appended to `<out>/<name>/pids.txt` so tests can
//! assert the reaping from outside.

use std::fs::{self, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::config::{FedConfig, TransportKind};
use crate::data::Dataset;
use crate::federated::{
    run_federated, run_federated_elastic, run_federated_sharded_outages,
    run_federated_with_drop_schedule, FedOutcome,
};
use crate::rng::SeedTree;
use crate::util::error::{Context, Result};
use crate::util::toml::TomlDoc;
use crate::zampling::NativeExecutor;
use crate::{anyhow, bail, ensure};

/// How often the orchestrator polls the fleet for exits and respawns.
const POLL_INTERVAL: Duration = Duration::from_millis(20);

/// Grace period after a successful root exit for the rest of the fleet
/// to drain (workers exit on the `Shutdown` frame); stragglers are
/// killed and reported, not failed.
const DRAIN_GRACE: Duration = Duration::from_secs(10);

/// How strictly the wire run must match its in-process twin.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompareMode {
    /// `ledger.csv` + `final_probs.bin` byte-equal.
    Full,
    /// Per-round ledger section + `final_probs.bin` byte-equal.
    Rounds,
    /// `final_probs.bin` byte-equal only.
    Probs,
    /// Completion only — no twin run.
    None,
}

impl CompareMode {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "full" => Ok(CompareMode::Full),
            "rounds" => Ok(CompareMode::Rounds),
            "probs" => Ok(CompareMode::Probs),
            "none" => Ok(CompareMode::None),
            other => Err(anyhow!("unknown compare mode '{other}' (full|rounds|probs|none)")),
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            CompareMode::Full => "full",
            CompareMode::Rounds => "rounds",
            CompareMode::Probs => "probs",
            CompareMode::None => "none",
        }
    }
}

/// One entry of a scenario's kill/restart schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosEvent {
    /// `kill-shard:S@R` — shard leader `S` exits when round `R` arrives.
    KillShard {
        /// Shard id of the doomed `serve-shard` process.
        shard: usize,
        /// Round whose arrival triggers the exit.
        round: u32,
    },
    /// `kill-client:K@R[+restart]` — worker `K` exits at round `R`.
    KillClient {
        /// Client id of the doomed `serve-client` process.
        client: usize,
        /// Round whose arrival triggers the exit.
        round: u32,
        /// Respawn the worker (without the chaos flag) once its exit is
        /// observed.
        restart: bool,
    },
    /// `kill-peer:I@R` — gossip node `I` exits after reporting round `R`.
    KillPeer {
        /// Node id of the doomed `serve-peer` process.
        peer: usize,
        /// Last round the peer reports before exiting.
        round: u32,
    },
    /// `kill-root:R+resume` — the root errors out at the start of round
    /// `R` and is respawned from its last checkpoint via `repro resume`.
    KillRoot {
        /// Round whose start triggers the root's exit.
        round: u32,
    },
    /// `join:K@R` — spawn worker `K` (a fresh id beyond the starting
    /// roster) once the root reports round `R`; the engine admits it at
    /// the next round boundary.
    Join {
        /// Client id of the late worker (`clients <= K < max-clients`).
        client: usize,
        /// Root-reported round that triggers the spawn.
        round: u32,
    },
}

impl ChaosEvent {
    /// Parse one chaos spec string (the grammar in the module docs).
    pub fn parse(spec: &str) -> Result<Self> {
        let (kind, rest) =
            spec.split_once(':').ok_or_else(|| anyhow!("chaos '{spec}': missing ':'"))?;
        if kind == "kill-root" {
            // The root has no id, and a dead root without a resume can
            // never pass — the suffix is mandatory so the intent is
            // explicit in the scenario file.
            let round_s = rest.strip_suffix("+resume").ok_or_else(|| {
                anyhow!("chaos '{spec}': kill-root takes 'kill-root:R+resume'")
            })?;
            let round: u32 = round_s
                .parse()
                .map_err(|_| anyhow!("chaos '{spec}': bad round '{round_s}'"))?;
            return Ok(ChaosEvent::KillRoot { round });
        }
        let (id_s, round_s) =
            rest.split_once('@').ok_or_else(|| anyhow!("chaos '{spec}': missing '@round'"))?;
        let restart = round_s.ends_with("+restart");
        let round_s = round_s.trim_end_matches("+restart");
        let id: usize =
            id_s.parse().map_err(|_| anyhow!("chaos '{spec}': bad id '{id_s}'"))?;
        let round: u32 =
            round_s.parse().map_err(|_| anyhow!("chaos '{spec}': bad round '{round_s}'"))?;
        match kind {
            "kill-shard" if !restart => Ok(ChaosEvent::KillShard { shard: id, round }),
            "kill-client" => Ok(ChaosEvent::KillClient { client: id, round, restart }),
            "kill-peer" if !restart => Ok(ChaosEvent::KillPeer { peer: id, round }),
            "join" if !restart => Ok(ChaosEvent::Join { client: id, round }),
            _ => Err(anyhow!(
                "chaos '{spec}': unknown kind '{kind}' \
                 (kill-shard:S@R | kill-client:K@R[+restart] | kill-peer:I@R | \
                  kill-root:R+resume | join:K@R)"
            )),
        }
    }
}

/// A parsed scenario file.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Scenario name — the per-scenario output directory under `--out`.
    pub name: String,
    /// Resolved path of the federated config every process loads.
    pub config: PathBuf,
    /// Root bind address; every other port derives from it.
    pub listen: String,
    /// Whole-scenario wall-clock cap; overrunning it kills the fleet
    /// and fails the scenario.
    pub timeout: Duration,
    /// How strictly to diff the run against the in-process twin.
    pub compare: CompareMode,
    /// Kill/restart schedule.
    pub chaos: Vec<ChaosEvent>,
    /// Post-run log greps, each `"<log-name>:<substring>"` (e.g.
    /// `"shard-1:merge"` checks `shard-1.log`).
    pub expect_log: Vec<(String, String)>,
}

const SCENARIO_KEYS: &[&str] = &[
    "scenario.name",
    "scenario.config",
    "scenario.listen",
    "scenario.timeout-secs",
    "scenario.compare",
    "scenario.chaos",
    "scenario.expect-log",
];

fn str_array(doc: &TomlDoc, key: &str) -> Result<Vec<String>> {
    let Some(v) = doc.get(key) else { return Ok(Vec::new()) };
    let arr = v.as_arr().ok_or_else(|| anyhow!("{key} must be an array of strings"))?;
    arr.iter()
        .map(|e| {
            e.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("{key} must be an array of strings"))
        })
        .collect()
}

impl Scenario {
    /// Parse a scenario document; relative `config` paths resolve
    /// against `base` (the scenario file's directory).
    pub fn from_doc(doc: &TomlDoc, base: &Path) -> Result<Self> {
        doc.check_known_keys(SCENARIO_KEYS).map_err(|e| anyhow!("{e}"))?;
        let name = doc.str_or("scenario.name", "");
        ensure!(!name.is_empty(), "scenario.name is required");
        ensure!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
            "scenario.name '{name}' must be [a-zA-Z0-9_-] (it becomes a directory)"
        );
        let config_raw = doc.str_or("scenario.config", "");
        ensure!(!config_raw.is_empty(), "scenario.config is required");
        let config_path = Path::new(&config_raw);
        let config =
            if config_path.is_absolute() { config_path.into() } else { base.join(config_path) };
        let listen = doc.str_or("scenario.listen", "");
        ensure!(!listen.is_empty(), "scenario.listen is required");
        let timeout = Duration::from_secs(doc.usize_or("scenario.timeout-secs", 120) as u64);
        ensure!(!timeout.is_zero(), "scenario.timeout-secs must be > 0");
        let compare = CompareMode::parse(&doc.str_or("scenario.compare", "full"))?;
        let chaos = str_array(doc, "scenario.chaos")?
            .iter()
            .map(|s| ChaosEvent::parse(s))
            .collect::<Result<Vec<_>>>()?;
        let expect_log = str_array(doc, "scenario.expect-log")?
            .iter()
            .map(|s| {
                s.split_once(':')
                    .map(|(f, n)| (f.to_string(), n.to_string()))
                    .ok_or_else(|| anyhow!("expect-log '{s}': want '<log-name>:<substring>'"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Scenario { name, config, listen, timeout, compare, chaos, expect_log })
    }

    /// Load and parse a scenario file.
    pub fn load(path: &Path) -> Result<Self> {
        let doc = TomlDoc::load(path).map_err(|e| anyhow!("{e}"))?;
        let base = path.parent().unwrap_or(Path::new("."));
        Self::from_doc(&doc, base)
    }

    /// Chaos entries must name processes the transport actually spawns
    /// and ids/rounds inside the config's ranges — a typo'd schedule
    /// silently testing nothing is worse than an error.
    fn validate_chaos(&self, cfg: &FedConfig) -> Result<()> {
        for ev in &self.chaos {
            match *ev {
                ChaosEvent::KillShard { shard, round } => {
                    ensure!(
                        cfg.transport == TransportKind::ShardedWire,
                        "kill-shard needs transport sharded-wire (shard leaders are \
                         in-process threads elsewhere)"
                    );
                    ensure!(shard < cfg.shards, "kill-shard: shard {shard} ≥ {}", cfg.shards);
                    ensure!(
                        (round as usize) < cfg.rounds,
                        "kill-shard: round {round} ≥ {}",
                        cfg.rounds
                    );
                }
                ChaosEvent::KillClient { client, round, .. } => {
                    ensure!(
                        cfg.transport == TransportKind::Tcp,
                        "kill-client is only supported under transport tcp (the drop-schedule \
                         twin replays single-leader logs)"
                    );
                    ensure!(client < cfg.clients, "kill-client: client {client} ≥ {}", cfg.clients);
                    ensure!(
                        (round as usize) < cfg.rounds,
                        "kill-client: round {round} ≥ {}",
                        cfg.rounds
                    );
                }
                ChaosEvent::KillPeer { peer, round } => {
                    ensure!(
                        cfg.transport == TransportKind::GossipTcp,
                        "kill-peer needs transport gossip-tcp"
                    );
                    ensure!(peer < cfg.clients, "kill-peer: peer {peer} ≥ {}", cfg.clients);
                    ensure!(
                        (round as usize) < cfg.rounds,
                        "kill-peer: round {round} ≥ {}",
                        cfg.rounds
                    );
                }
                ChaosEvent::KillRoot { round } => {
                    ensure!(
                        matches!(
                            cfg.transport,
                            TransportKind::Tcp
                                | TransportKind::Sharded
                                | TransportKind::ShardedWire
                        ),
                        "kill-root needs a leader transport (tcp, sharded, or sharded-wire)"
                    );
                    ensure!(
                        cfg.checkpoint_every > 0,
                        "kill-root: the config must set federated.checkpoint-every > 0 \
                         (resume needs a checkpoint to load)"
                    );
                    ensure!(
                        cfg.checkpoint_every <= round as usize,
                        "kill-root: round {round} precedes the first checkpoint boundary \
                         (checkpoint-every = {})",
                        cfg.checkpoint_every
                    );
                    ensure!(
                        (round as usize) < cfg.rounds,
                        "kill-root: round {round} ≥ {}",
                        cfg.rounds
                    );
                    ensure!(
                        self.chaos
                            .iter()
                            .filter(|e| matches!(e, ChaosEvent::KillRoot { .. }))
                            .count()
                            == 1,
                        "at most one kill-root event per scenario (one checkpoint, one resume)"
                    );
                }
                ChaosEvent::Join { client, round } => {
                    ensure!(
                        cfg.transport == TransportKind::Tcp,
                        "join is only supported under transport tcp (the elastic twin \
                         replays single-leader admission logs)"
                    );
                    ensure!(
                        client >= cfg.clients && client < cfg.max_clients,
                        "join: client {client} must be a new id in {}..{} \
                         (clients..max-clients)",
                        cfg.clients,
                        cfg.max_clients
                    );
                    ensure!(
                        (round as usize) < cfg.rounds,
                        "join: round {round} ≥ {}",
                        cfg.rounds
                    );
                }
            }
        }
        Ok(())
    }
}

/// One spawned fleet member.
struct Proc {
    name: String,
    child: Child,
    /// `Some(args)` = respawn with these args when the exit is observed
    /// (the `+restart` chaos flavor); taken on use so it fires once.
    respawn: Option<Vec<String>>,
}

/// The spawned processes of one scenario run.  Dropping the fleet —
/// normally, on error, or during a panic unwind — kills and reaps every
/// child; `PR_SET_PDEATHSIG` covers even SIGKILL of the orchestrator.
struct Fleet {
    dir: PathBuf,
    exe: PathBuf,
    procs: Vec<Proc>,
    /// Index of the process whose exit decides the scenario.  Starts at
    /// 0 (the first spawn is always the root); moves to the respawned
    /// process when a `kill-root:R+resume` schedule replaces the root.
    root: usize,
}

/// A worker the orchestrator spawns only once the root's log reports
/// `round` — the `join:K@R` chaos flavor.
struct PendingJoin {
    round: u32,
    name: String,
    args: Vec<String>,
}

impl Fleet {
    fn new(dir: PathBuf) -> Result<Self> {
        let exe = std::env::current_exe().context("locating the repro binary")?;
        Ok(Fleet { dir, exe, procs: Vec::new(), root: 0 })
    }

    /// Spawn one `repro` child with stdout+stderr appended to
    /// `<dir>/<name>.log` and its pid recorded in `<dir>/pids.txt`.
    fn spawn(&mut self, name: &str, args: &[String], respawn: Option<Vec<String>>) -> Result<()> {
        let log_path = self.dir.join(format!("{name}.log"));
        let log = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&log_path)
            .with_context(|| format!("opening {}", log_path.display()))?;
        let err_log = log.try_clone().context("cloning log handle")?;
        let mut cmd = Command::new(&self.exe);
        cmd.args(args).stdin(Stdio::null()).stdout(Stdio::from(log)).stderr(Stdio::from(err_log));
        arm_pdeathsig(&mut cmd);
        let child = cmd.spawn().with_context(|| format!("spawning {name}"))?;
        let pids_path = self.dir.join("pids.txt");
        let mut pids = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&pids_path)
            .with_context(|| format!("opening {}", pids_path.display()))?;
        writeln!(pids, "{} {name}", child.id())
            .with_context(|| format!("writing {}", pids_path.display()))?;
        self.procs.push(Proc { name: name.to_string(), child, respawn });
        Ok(())
    }

    /// Poll the fleet until the root exits.  Fires pending respawns
    /// along the way — a root that dies with a respawn armed (the
    /// `kill-root:R+resume` schedule, a deliberately nonzero exit) hands
    /// the root role to its `resume` replacement — and spawns `joins`
    /// entries once the root's log reports their trigger round.  A
    /// nonzero exit of the *final* root, or blowing `timeout`, fails the
    /// scenario (the `Drop` reaps everything).
    fn drive(&mut self, timeout: Duration, mut joins: Vec<PendingJoin>) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            let mut respawns = Vec::new();
            for (i, p) in self.procs.iter_mut().enumerate() {
                if p.child.try_wait().ok().flatten().is_some() {
                    if let Some(args) = p.respawn.take() {
                        respawns.push((i, format!("{}-restart", p.name), args));
                    }
                }
            }
            for (i, name, args) in respawns {
                self.spawn(&name, &args, None)?;
                if i == self.root {
                    self.root = self.procs.len() - 1;
                }
            }
            if !joins.is_empty() {
                let log_name = format!("{}.log", self.procs[self.root].name);
                let log = fs::read_to_string(self.dir.join(log_name)).unwrap_or_default();
                if let Some(seen) = last_reported_round(&log) {
                    let mut i = 0;
                    while i < joins.len() {
                        if joins[i].round <= seen {
                            let j = joins.remove(i);
                            self.spawn(&j.name, &j.args, None)?;
                        } else {
                            i += 1;
                        }
                    }
                }
            }
            let root = self.root;
            if let Some(status) = self.procs[root].child.try_wait().context("waiting on root")? {
                if self.procs[root].respawn.is_some() {
                    // Scheduled kill observed between the respawn scan
                    // and here; the next iteration fires the resume.
                } else if status.success() {
                    return Ok(());
                } else {
                    let name = self.procs[root].name.clone();
                    bail!(
                        "{name} exited with {status}; last lines of {name}.log:\n{}",
                        tail(&self.dir.join(format!("{name}.log")), 15)
                    );
                }
            }
            if Instant::now() > deadline {
                bail!("scenario timed out after {}s (fleet killed)", timeout.as_secs());
            }
            std::thread::sleep(POLL_INTERVAL);
        }
    }

    /// After a successful root exit, give the rest of the fleet a grace
    /// period to drain on the `Shutdown` frames, then kill stragglers.
    /// Returns the names of anything that had to be killed.
    fn drain(&mut self, grace: Duration) -> Vec<String> {
        let deadline = Instant::now() + grace;
        loop {
            let mut alive = Vec::new();
            for p in &mut self.procs {
                if matches!(p.child.try_wait(), Ok(None)) {
                    alive.push(p.name.clone());
                }
            }
            if alive.is_empty() {
                return Vec::new();
            }
            if Instant::now() > deadline {
                for p in &mut self.procs {
                    let _ = p.child.kill();
                }
                return alive;
            }
            std::thread::sleep(POLL_INTERVAL);
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for p in &mut self.procs {
            let _ = p.child.kill();
        }
        for p in &mut self.procs {
            let _ = p.child.wait();
        }
    }
}

/// Arm a child so the kernel SIGKILLs it if the orchestrator dies —
/// the backstop for orchestrator SIGKILL, where `Fleet::drop` never
/// runs.  (`prctl` is declared by hand; the crate is dependency-free.)
#[cfg(target_os = "linux")]
fn arm_pdeathsig(cmd: &mut Command) {
    use std::os::unix::process::CommandExt;
    extern "C" {
        fn prctl(option: i32, arg2: u64, arg3: u64, arg4: u64, arg5: u64) -> i32;
    }
    const PR_SET_PDEATHSIG: i32 = 1;
    const SIGKILL: u64 = 9;
    // SAFETY: `pre_exec` runs after fork, before exec, in the child;
    // the closure only makes the `prctl` syscall, which is
    // async-signal-safe and touches no parent state.
    unsafe {
        cmd.pre_exec(|| {
            // SAFETY: plain value-argument syscall wrapper, no pointers.
            let rc = unsafe { prctl(PR_SET_PDEATHSIG, SIGKILL, 0, 0, 0) };
            if rc != 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(())
        });
    }
}

#[cfg(not(target_os = "linux"))]
fn arm_pdeathsig(_cmd: &mut Command) {}

/// Highest round number any `round {r:>3}  ...` verbose line in `log`
/// reports — the trigger signal for `join:K@R` spawns.  `None` until
/// the first round line appears.
fn last_reported_round(log: &str) -> Option<u32> {
    let mut last = None;
    for line in log.lines() {
        let Some(rest) = line.strip_prefix("round ") else { continue };
        let Some(num) = rest.split_whitespace().next() else { continue };
        if let Ok(r) = num.parse::<u32>() {
            last = Some(last.map_or(r, |l: u32| l.max(r)));
        }
    }
    last
}

/// Last `n` lines of a log file (best effort, for error messages).
fn tail(path: &Path, n: usize) -> String {
    let text = fs::read_to_string(path).unwrap_or_default();
    let lines: Vec<&str> = text.lines().collect();
    let start = lines.len().saturating_sub(n);
    lines[start..].join("\n")
}

/// The chaos flags one process spawns with.
fn chaos_flags(chaos: &[ChaosEvent], role: &ChaosRole) -> Vec<String> {
    for ev in chaos {
        match (*ev, role) {
            (ChaosEvent::KillShard { shard, round }, ChaosRole::Shard(s)) if shard == *s => {
                return vec!["--fail-at-round".into(), round.to_string()];
            }
            (ChaosEvent::KillClient { client, round, .. }, ChaosRole::Client(k))
                if client == *k =>
            {
                return vec!["--fail-at-round".into(), round.to_string()];
            }
            (ChaosEvent::KillPeer { peer, round }, ChaosRole::Peer(i)) if peer == *i => {
                return vec!["--die-after-round".into(), round.to_string()];
            }
            _ => {}
        }
    }
    Vec::new()
}

enum ChaosRole {
    Shard(usize),
    Client(usize),
    Peer(usize),
}

/// Does this client's chaos entry ask for a respawn?
fn wants_restart(chaos: &[ChaosEvent], client: usize) -> bool {
    chaos.iter().any(|ev| match *ev {
        ChaosEvent::KillClient { client: c, restart, .. } => c == client && restart,
        _ => false,
    })
}

/// Owned argv from a borrowed slice (the expected `&[&str]` type makes
/// every element a coercion site, so `&String` members just work).
fn argv(parts: &[&str]) -> Vec<String> {
    parts.iter().map(|s| s.to_string()).collect()
}

/// Run one scenario end to end; returns a human-readable pass report.
/// Any failure — spawn error, timeout, nonzero root, missing expected
/// log line, twin divergence — returns `Err` with the fleet reaped.
pub fn run_scenario(scenario_path: &Path, out_root: &Path) -> Result<String> {
    let scn = Scenario::load(scenario_path)?;
    let doc = TomlDoc::load(&scn.config).map_err(|e| anyhow!("{e}"))?;
    let cfg = FedConfig::from_toml(&doc).map_err(|e| anyhow!("{}: {e}", scn.config.display()))?;
    ensure!(
        matches!(
            cfg.transport,
            TransportKind::Tcp
                | TransportKind::Sharded
                | TransportKind::ShardedWire
                | TransportKind::GossipTcp
        ),
        "scenario '{}': transport {} spawns no processes — use the in-process drivers directly",
        scn.name,
        cfg.transport.as_str()
    );
    scn.validate_chaos(&cfg)?;

    let out_dir = out_root.join(&scn.name);
    fs::create_dir_all(&out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    // Stale logs/pids from a previous run would poison the drop-schedule
    // parse and the reap assertions.
    for entry in fs::read_dir(&out_dir).with_context(|| format!("listing {}", out_dir.display()))?
    {
        let p = entry.context("reading out dir entry")?.path();
        if p.extension().is_some_and(|e| e == "log") || p.ends_with("pids.txt") {
            let _ = fs::remove_file(&p);
        }
    }
    // A checkpoint left by a previous run matches this config (same
    // seed), so a resume could silently load stale state and still
    // pass — remove it so only this run's checkpoint exists.
    let _ = fs::remove_file(out_dir.join("root").join("checkpoint.bin"));
    let config_arg = scn
        .config
        .canonicalize()
        .with_context(|| format!("resolving {}", scn.config.display()))?
        .display()
        .to_string();

    let mut fleet = Fleet::new(out_dir.clone())?;
    let root_out = out_dir.join("root").display().to_string();
    let mut root_args = argv(&[
        "train-federated",
        "--config",
        &config_arg,
        "--listen",
        &scn.listen,
        "--out",
        &root_out,
        "--eval-samples",
        "2",
    ]);
    let kill_root = scn.chaos.iter().find_map(|ev| match *ev {
        ChaosEvent::KillRoot { round } => Some(round),
        _ => None,
    });
    let root_respawn = kill_root.map(|round| {
        root_args.extend(argv(&["--fail-at-round", &round.to_string()]));
        // `resume` restores eval cadence/samples and the log name from
        // the checkpoint manifest and rejects unknown flags, so the
        // respawn argv carries only the run identity.
        let ckpt = out_dir.join("root").join("checkpoint.bin").display().to_string();
        argv(&[
            "resume",
            "--config",
            &config_arg,
            "--checkpoint",
            &ckpt,
            "--listen",
            &scn.listen,
            "--out",
            &root_out,
        ])
    });
    fleet.spawn("root", &root_args, root_respawn)?;

    // Every non-root role dials with retry, so spawn order is free; we
    // still go top-down (shard leaders before workers) to keep startup
    // fast.
    if cfg.transport == TransportKind::ShardedWire {
        for s in 0..cfg.shards {
            let sid = s.to_string();
            let mut args = argv(&[
                "serve-shard",
                "--addr",
                &scn.listen,
                "--shard-id",
                &sid,
                "--config",
                &config_arg,
            ]);
            args.extend(chaos_flags(&scn.chaos, &ChaosRole::Shard(s)));
            fleet.spawn(&format!("shard-{s}"), &args, None)?;
        }
    }
    match cfg.transport {
        TransportKind::Tcp | TransportKind::Sharded | TransportKind::ShardedWire => {
            for k in 0..cfg.clients {
                let kid = k.to_string();
                let base = argv(&[
                    "serve-client",
                    "--addr",
                    &scn.listen,
                    "--client-id",
                    &kid,
                    "--config",
                    &config_arg,
                ]);
                let mut args = base.clone();
                args.extend(chaos_flags(&scn.chaos, &ChaosRole::Client(k)));
                let respawn = wants_restart(&scn.chaos, k).then_some(base);
                fleet.spawn(&format!("worker-{k}"), &args, respawn)?;
            }
        }
        TransportKind::GossipTcp => {
            for i in 0..cfg.clients {
                let nid = i.to_string();
                let mut args = argv(&[
                    "serve-peer",
                    "--addr",
                    &scn.listen,
                    "--node-id",
                    &nid,
                    "--config",
                    &config_arg,
                ]);
                args.extend(chaos_flags(&scn.chaos, &ChaosRole::Peer(i)));
                fleet.spawn(&format!("peer-{i}"), &args, None)?;
            }
        }
        _ => {}
    }

    let pending_joins: Vec<PendingJoin> = scn
        .chaos
        .iter()
        .filter_map(|ev| match *ev {
            ChaosEvent::Join { client, round } => {
                let kid = client.to_string();
                let args = argv(&[
                    "serve-client",
                    "--addr",
                    &scn.listen,
                    "--client-id",
                    &kid,
                    "--config",
                    &config_arg,
                ]);
                Some(PendingJoin { round, name: format!("worker-{client}"), args })
            }
            _ => None,
        })
        .collect();

    let spawned = fleet.procs.len();
    fleet.drive(scn.timeout, pending_joins)?;
    let killed = fleet.drain(DRAIN_GRACE);
    drop(fleet); // reap everything before reading logs

    let mut report = vec![format!(
        "scenario {}: root completed ({spawned} processes, compare={})",
        scn.name,
        scn.compare.as_str()
    )];
    if !killed.is_empty() {
        report.push(format!("  note: killed stragglers after root exit: {}", killed.join(", ")));
    }

    for (log_name, needle) in &scn.expect_log {
        let path = out_dir.join(format!("{log_name}.log"));
        let text =
            fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        ensure!(
            text.contains(needle),
            "expected '{needle}' in {log_name}.log — not found"
        );
        report.push(format!("  expect-log {log_name}:'{needle}' ok"));
    }

    if let Some(twin) = run_twin(&cfg, &scn, &out_dir)? {
        compare_artifacts(scn.compare, &out_dir, &twin, &mut report)?;
    }
    report.push(format!("scenario {}: PASS", scn.name));
    Ok(report.join("\n"))
}

/// Replicate the root's data/split derivation (the same shared-seed
/// rules every process uses) and run the in-process twin transport.
fn run_twin(cfg: &FedConfig, scn: &Scenario, out_dir: &Path) -> Result<Option<FedOutcome>> {
    if scn.compare == CompareMode::None {
        return Ok(None);
    }
    if cfg.transport == TransportKind::GossipTcp {
        bail!("compare={} is not supported for gossip-tcp (use none)", scn.compare.as_str());
    }
    let seeds = SeedTree::new(cfg.train.seed);
    let (train, test) = if cfg.train.train_rows >= 60_000 {
        (Dataset::mnist_or_synthetic(true, &seeds), Dataset::mnist_or_synthetic(false, &seeds))
    } else {
        Dataset::synthetic_pair(cfg.train.train_rows, cfg.train.test_rows, &seeds)
    };
    let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 500);
    // Eval cadence/samples never touch probs or the ledger; keep the
    // twin's evaluation minimal.  (A kill-root run needs no special
    // twin: resume replays the interrupted round from the checkpoint
    // boundary, so the uninterrupted run IS the reference.)
    let eval_every = cfg.rounds.max(1);
    let out = match cfg.transport {
        TransportKind::Tcp => {
            let any_kill = scn
                .chaos
                .iter()
                .any(|ev| matches!(ev, ChaosEvent::KillClient { .. }));
            let any_join = scn.chaos.iter().any(|ev| matches!(ev, ChaosEvent::Join { .. }));
            if any_join {
                // The admission round depends on connect timing, so the
                // twin replays the rounds the root actually reported —
                // the elastic mirror of the drop-schedule replay.
                let log_path = out_dir.join("root.log");
                let log = fs::read_to_string(&log_path)
                    .with_context(|| format!("reading {}", log_path.display()))?;
                let joins = parse_join_schedule(&log)?;
                ensure!(
                    !joins.is_empty(),
                    "join scheduled but the root log reports no joined clients"
                );
                let shards = train.partition_iid(cfg.max_clients, &seeds);
                run_federated_elastic(cfg, &mut exec, &shards, &test, 1, eval_every, &joins)
            } else if any_kill {
                let log_path = out_dir.join("root.log");
                let log = fs::read_to_string(&log_path)
                    .with_context(|| format!("reading {}", log_path.display()))?;
                let schedule = parse_drop_schedule(&log)?;
                ensure!(
                    !schedule.is_empty(),
                    "kill-client scheduled but the root log reports no dropped rounds"
                );
                let shards = train.partition_iid(cfg.clients, &seeds);
                run_federated_with_drop_schedule(
                    cfg, &mut exec, &shards, &test, 1, eval_every, &schedule,
                )
            } else if cfg.max_clients > cfg.clients {
                // Elastic config but nobody joined on schedule: still
                // mirror the wire run's max-clients data split.
                let shards = train.partition_iid(cfg.max_clients, &seeds);
                run_federated_elastic(cfg, &mut exec, &shards, &test, 1, eval_every, &[])
            } else {
                let shards = train.partition_iid(cfg.clients, &seeds);
                run_federated(cfg, &mut exec, &shards, &test, 1, eval_every)
            }
        }
        TransportKind::Sharded | TransportKind::ShardedWire => {
            let outages: Vec<(usize, u32)> = scn
                .chaos
                .iter()
                .filter_map(|ev| match *ev {
                    ChaosEvent::KillShard { shard, round } => Some((shard, round)),
                    _ => None,
                })
                .collect();
            let shards = train.partition_iid(cfg.clients, &seeds);
            run_federated_sharded_outages(
                cfg, &mut exec, &shards, &test, 1, eval_every, cfg.shards, &outages,
            )
        }
        _ => bail!("no twin for transport {}", cfg.transport.as_str()),
    };
    Ok(Some(out))
}

/// Parse the root's verbose drop lines (`round {r:>3}  dropped clients
/// [a, b]`) into a `(round, client)` schedule for the replay twin.
fn parse_drop_schedule(log: &str) -> Result<Vec<(u32, usize)>> {
    let mut schedule = Vec::new();
    for line in log.lines() {
        let Some(rest) = line.strip_prefix("round ") else { continue };
        let Some((round_s, ids)) = rest.split_once("  dropped clients [") else { continue };
        let round: u32 = round_s
            .trim()
            .parse()
            .map_err(|_| anyhow!("unparseable drop line '{line}'"))?;
        let ids = ids
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated drop line '{line}'"))?;
        for id in ids.split(',') {
            let id = id.trim();
            if id.is_empty() {
                continue;
            }
            let client: usize =
                id.parse().map_err(|_| anyhow!("bad client id in drop line '{line}'"))?;
            schedule.push((round, client));
        }
    }
    Ok(schedule)
}

/// Parse the root's verbose admission lines (`round {r:>3}  joined
/// clients [a, b]`) into a `(round, client)` schedule for the elastic
/// replay twin.
fn parse_join_schedule(log: &str) -> Result<Vec<(u32, usize)>> {
    let mut schedule = Vec::new();
    for line in log.lines() {
        let Some(rest) = line.strip_prefix("round ") else { continue };
        let Some((round_s, ids)) = rest.split_once("  joined clients [") else { continue };
        let round: u32 = round_s
            .trim()
            .parse()
            .map_err(|_| anyhow!("unparseable join line '{line}'"))?;
        let ids = ids
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated join line '{line}'"))?;
        for id in ids.split(',') {
            let id = id.trim();
            if id.is_empty() {
                continue;
            }
            let client: usize =
                id.parse().map_err(|_| anyhow!("bad client id in join line '{line}'"))?;
            schedule.push((round, client));
        }
    }
    Ok(schedule)
}

/// Little-endian f32 concatenation — the `final_probs.bin` encoding.
fn probs_bytes(probs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(probs.len() * 4);
    for p in probs {
        out.extend_from_slice(&p.to_le_bytes());
    }
    out
}

/// Diff the root's written artifacts against the twin at the scenario's
/// strictness.  The twin's artifacts are always written next to the
/// root's (`twin.final_probs.bin`, `twin.ledger.csv`) so a divergence
/// leaves both sides on disk to inspect.
fn compare_artifacts(
    mode: CompareMode,
    out_dir: &Path,
    twin: &FedOutcome,
    report: &mut Vec<String>,
) -> Result<()> {
    let twin_probs = probs_bytes(&twin.final_probs);
    let twin_csv = twin.ledger.to_csv();
    fs::write(out_dir.join("twin.final_probs.bin"), &twin_probs)
        .context("writing twin.final_probs.bin")?;
    fs::write(out_dir.join("twin.ledger.csv"), &twin_csv).context("writing twin.ledger.csv")?;

    let probs_path = out_dir.join("root").join("final_probs.bin");
    let wire_probs =
        fs::read(&probs_path).with_context(|| format!("reading {}", probs_path.display()))?;
    ensure!(
        wire_probs == twin_probs,
        "final_probs.bin diverges from the in-process twin \
         ({} vs {} bytes; see twin.final_probs.bin)",
        wire_probs.len(),
        twin_probs.len()
    );
    report.push("  final_probs.bin: byte-identical to the in-process twin".to_string());
    if mode == CompareMode::Probs {
        return Ok(());
    }

    let ledger_path = out_dir.join("root").join("ledger.csv");
    let wire_csv = fs::read_to_string(&ledger_path)
        .with_context(|| format!("reading {}", ledger_path.display()))?;
    match mode {
        CompareMode::Full => {
            ensure!(
                wire_csv == twin_csv,
                "ledger.csv diverges from the in-process twin (see twin.ledger.csv)"
            );
            report.push("  ledger.csv: byte-identical to the in-process twin".to_string());
        }
        CompareMode::Rounds => {
            let twin_rounds = twin.ledger.rounds_csv();
            ensure!(
                wire_csv.starts_with(&twin_rounds),
                "per-round ledger section diverges from the in-process twin \
                 (see twin.ledger.csv)"
            );
            report.push("  ledger.csv rounds section: byte-identical to the twin".to_string());
        }
        CompareMode::Probs | CompareMode::None => {}
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_specs_parse_and_reject() {
        assert_eq!(
            ChaosEvent::parse("kill-shard:1@2").unwrap(),
            ChaosEvent::KillShard { shard: 1, round: 2 }
        );
        assert_eq!(
            ChaosEvent::parse("kill-client:3@2+restart").unwrap(),
            ChaosEvent::KillClient { client: 3, round: 2, restart: true }
        );
        assert_eq!(
            ChaosEvent::parse("kill-client:0@5").unwrap(),
            ChaosEvent::KillClient { client: 0, round: 5, restart: false }
        );
        assert_eq!(
            ChaosEvent::parse("kill-peer:2@1").unwrap(),
            ChaosEvent::KillPeer { peer: 2, round: 1 }
        );
        assert_eq!(
            ChaosEvent::parse("kill-root:3+resume").unwrap(),
            ChaosEvent::KillRoot { round: 3 }
        );
        assert_eq!(
            ChaosEvent::parse("join:5@2").unwrap(),
            ChaosEvent::Join { client: 5, round: 2 }
        );
        for bad in [
            "kill-shard",
            "kill-shard:1",
            "kill-shard:x@2",
            "kill-shard:1@y",
            "kill-shard:1@2+restart", // restart is a client-only flavor
            "kill-peer:0@1+restart",
            "kill-root:3",        // the resume suffix is mandatory
            "kill-root:3+restart",
            "kill-root:x+resume",
            "join:5@2+restart",
            "join:5",
            "explode:1@2",
        ] {
            assert!(ChaosEvent::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn scenario_parses_and_resolves_config_relative_to_base() {
        let doc = TomlDoc::parse(
            r#"
[scenario]
name = "tree-depth2"
config = "fed.toml"
listen = "127.0.0.1:7757"
timeout-secs = 60
compare = "rounds"
chaos = ["kill-shard:1@2"]
expect-log = ["shard-0:merge"]
"#,
        )
        .unwrap();
        let scn = Scenario::from_doc(&doc, Path::new("/tmp/scenarios")).unwrap();
        assert_eq!(scn.name, "tree-depth2");
        assert_eq!(scn.config, Path::new("/tmp/scenarios/fed.toml"));
        assert_eq!(scn.timeout, Duration::from_secs(60));
        assert_eq!(scn.compare, CompareMode::Rounds);
        assert_eq!(scn.chaos, vec![ChaosEvent::KillShard { shard: 1, round: 2 }]);
        assert_eq!(scn.expect_log, vec![("shard-0".to_string(), "merge".to_string())]);
    }

    #[test]
    fn scenario_rejects_missing_and_unknown_fields() {
        let missing = TomlDoc::parse("[scenario]\nconfig = \"x.toml\"").unwrap();
        assert!(Scenario::from_doc(&missing, Path::new(".")).is_err());
        let unknown =
            TomlDoc::parse("[scenario]\nname = \"a\"\nconfig = \"x\"\nlisten = \"h:1\"\ntypo = 1")
                .unwrap();
        assert!(Scenario::from_doc(&unknown, Path::new(".")).is_err());
        let bad_compare = TomlDoc::parse(
            "[scenario]\nname = \"a\"\nconfig = \"x\"\nlisten = \"h:1\"\ncompare = \"maybe\"",
        )
        .unwrap();
        assert!(Scenario::from_doc(&bad_compare, Path::new(".")).is_err());
    }

    #[test]
    fn drop_schedule_parses_verbose_root_logs() {
        let log = "\
[repro] federated zampling: 4 clients, 6 rounds, n=100 d=5 (transport=tcp policy=uniform)
round   0  sampled 0.2500 ± 0.0100  expected 0.2500  (4 of 4 masks)
round   2  dropped clients [3]
round   3  dropped clients [1, 3]
round   3  sampled 0.2500 ± 0.0100  expected 0.2500  (2 of 4 masks)
";
        let schedule = parse_drop_schedule(log).unwrap();
        assert_eq!(schedule, vec![(2, 3), (3, 1), (3, 3)]);
    }

    #[test]
    fn drop_schedule_ignores_logs_without_drop_lines() {
        let schedule = parse_drop_schedule("round   0  sampled 0.5 ± 0.0\n").unwrap();
        assert!(schedule.is_empty());
    }

    #[test]
    fn probs_bytes_is_little_endian_f32_concatenation() {
        let bytes = probs_bytes(&[0.5, 1.0]);
        assert_eq!(bytes.len(), 8);
        assert_eq!(&bytes[..4], &0.5f32.to_le_bytes());
        assert_eq!(&bytes[4..], &1.0f32.to_le_bytes());
    }

    #[test]
    fn chaos_validation_matches_transport_and_ranges() {
        let doc = TomlDoc::parse(
            "arch = \"small\"\ncompression = 8\ntrain-rows = 512\ntest-rows = 128\n\
             [federated]\nclients = 4\nrounds = 4\nshards = 2\ntransport = \"sharded-wire\"",
        )
        .unwrap();
        let cfg = FedConfig::from_toml(&doc).unwrap();
        let mut scn = Scenario {
            name: "t".into(),
            config: PathBuf::from("x"),
            listen: "h:1".into(),
            timeout: Duration::from_secs(1),
            compare: CompareMode::None,
            chaos: vec![ChaosEvent::KillShard { shard: 1, round: 2 }],
            expect_log: Vec::new(),
        };
        assert!(scn.validate_chaos(&cfg).is_ok());
        scn.chaos = vec![ChaosEvent::KillShard { shard: 2, round: 2 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "shard out of range");
        scn.chaos = vec![ChaosEvent::KillShard { shard: 0, round: 9 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "round out of range");
        scn.chaos = vec![ChaosEvent::KillClient { client: 0, round: 1, restart: false }];
        assert!(scn.validate_chaos(&cfg).is_err(), "kill-client needs tcp");
        scn.chaos = vec![ChaosEvent::KillPeer { peer: 0, round: 1 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "kill-peer needs gossip");
    }

    #[test]
    fn kill_root_validation_requires_a_reachable_checkpoint() {
        let doc = TomlDoc::parse(
            "arch = \"small\"\ncompression = 8\ntrain-rows = 512\ntest-rows = 128\n\
             [federated]\nclients = 4\nrounds = 6\ntransport = \"tcp\"\n\
             checkpoint-every = 2",
        )
        .unwrap();
        let cfg = FedConfig::from_toml(&doc).unwrap();
        let mut scn = Scenario {
            name: "t".into(),
            config: PathBuf::from("x"),
            listen: "h:1".into(),
            timeout: Duration::from_secs(1),
            compare: CompareMode::None,
            chaos: vec![ChaosEvent::KillRoot { round: 3 }],
            expect_log: Vec::new(),
        };
        assert!(scn.validate_chaos(&cfg).is_ok());
        scn.chaos = vec![ChaosEvent::KillRoot { round: 1 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "no checkpoint exists before round 2");
        scn.chaos = vec![ChaosEvent::KillRoot { round: 9 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "round out of range");
        scn.chaos =
            vec![ChaosEvent::KillRoot { round: 2 }, ChaosEvent::KillRoot { round: 4 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "one resume per scenario");
        let no_ckpt = TomlDoc::parse(
            "arch = \"small\"\ncompression = 8\ntrain-rows = 512\ntest-rows = 128\n\
             [federated]\nclients = 4\nrounds = 6\ntransport = \"tcp\"",
        )
        .unwrap();
        let cfg_no_ckpt = FedConfig::from_toml(&no_ckpt).unwrap();
        scn.chaos = vec![ChaosEvent::KillRoot { round: 3 }];
        assert!(scn.validate_chaos(&cfg_no_ckpt).is_err(), "checkpoint-every must be set");
    }

    #[test]
    fn join_validation_requires_tcp_and_a_fresh_id() {
        let doc = TomlDoc::parse(
            "arch = \"small\"\ncompression = 8\ntrain-rows = 512\ntest-rows = 128\n\
             [federated]\nclients = 4\nmax-clients = 6\nrounds = 6\ntransport = \"tcp\"",
        )
        .unwrap();
        let cfg = FedConfig::from_toml(&doc).unwrap();
        let mut scn = Scenario {
            name: "t".into(),
            config: PathBuf::from("x"),
            listen: "h:1".into(),
            timeout: Duration::from_secs(1),
            compare: CompareMode::None,
            chaos: vec![ChaosEvent::Join { client: 4, round: 2 }],
            expect_log: Vec::new(),
        };
        assert!(scn.validate_chaos(&cfg).is_ok());
        scn.chaos = vec![ChaosEvent::Join { client: 2, round: 2 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "id already in the starting roster");
        scn.chaos = vec![ChaosEvent::Join { client: 6, round: 2 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "id beyond max-clients");
        scn.chaos = vec![ChaosEvent::Join { client: 4, round: 9 }];
        assert!(scn.validate_chaos(&cfg).is_err(), "round out of range");
    }

    #[test]
    fn join_schedule_parses_verbose_admission_lines() {
        let log = "\
[repro] federated zampling: 4 clients, 6 rounds, n=100 d=5 (transport=tcp policy=uniform)
round   0  sampled 0.2500 ± 0.0100  expected 0.2500  (4 of 4 masks)
round   2  joined clients [4]
round   3  joined clients [5, 6]
round   3  sampled 0.2500 ± 0.0100  expected 0.2500  (6 of 6 masks)
";
        let schedule = parse_join_schedule(log).unwrap();
        assert_eq!(schedule, vec![(2, 4), (3, 5), (3, 6)]);
        assert!(parse_join_schedule("round   0  sampled 0.5 ± 0.0\n").unwrap().is_empty());
    }

    #[test]
    fn last_reported_round_tracks_the_maximum() {
        assert_eq!(last_reported_round(""), None);
        assert_eq!(last_reported_round("booting\n"), None);
        let log = "round   0  sampled 0.5\nround   2  dropped clients [1]\nround   1  x\n";
        assert_eq!(last_reported_round(log), Some(2));
    }
}
