//! Dataset substrate: MNIST IDX loading, a deterministic synthetic
//! MNIST-like fallback, IID partitioning across clients, and batching.
//!
//! **Substitution note** (DESIGN.md §4): this environment has no network
//! access, so [`Dataset::mnist_or_synthetic`] loads real IDX files from
//! `data/mnist/` when present and otherwise generates the synthetic task —
//! 10 smoothed-blob class prototypes + structured noise + shifts — whose
//! difficulty is calibrated so uncompressed accuracies land near the
//! paper's (SmallArch ≈ 86%, MnistFc ≥ 95%), preserving the *relative*
//! compression/accuracy trade-off the paper measures.

mod idx;
mod synthetic;

pub use idx::{load_idx_images, load_idx_labels, IdxError};
pub use synthetic::SyntheticSpec;

use crate::rng::{shuffle, Rng, SeedTree};

/// An in-memory labelled image dataset (f32 features in `[0,1]`).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[num * dim]` row-major features.
    pub x: Vec<f32>,
    /// `[num]` class labels.
    pub y: Vec<u8>,
    pub dim: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Load real MNIST from `dir` (train or t10k pair), normalized to
    /// `[0,1]`.
    pub fn load_mnist(dir: &std::path::Path, train: bool) -> Result<Self, IdxError> {
        let (ix, iy) = if train {
            ("train-images-idx3-ubyte", "train-labels-idx1-ubyte")
        } else {
            ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")
        };
        let (x, dim) = load_idx_images(&dir.join(ix))?;
        let y = load_idx_labels(&dir.join(iy))?;
        if x.len() / dim != y.len() {
            return Err(IdxError::Malformed("image/label count mismatch"));
        }
        Ok(Self { x, y, dim, classes: 10 })
    }

    /// Real MNIST if `data/mnist/` exists, else the synthetic task with
    /// the same split sizes (60k train / 10k test).
    pub fn mnist_or_synthetic(train: bool, seeds: &SeedTree) -> Self {
        let dir = std::path::Path::new("data/mnist");
        if let Ok(ds) = Self::load_mnist(dir, train) {
            return ds;
        }
        let spec = SyntheticSpec::mnist_like();
        if train {
            spec.generate(60_000, seeds, 0)
        } else {
            spec.generate(10_000, seeds, 1)
        }
    }

    /// Scaled-down pair for tests/CI (`train_n`/`test_n` synthetic rows).
    pub fn synthetic_pair(train_n: usize, test_n: usize, seeds: &SeedTree) -> (Self, Self) {
        let spec = SyntheticSpec::mnist_like();
        (spec.generate(train_n, seeds, 0), spec.generate(test_n, seeds, 1))
    }

    /// IID partition into `k` client shards (random split, §3.2): shuffle
    /// indices with the shared seed, deal them round-robin.
    pub fn partition_iid(&self, k: usize, seeds: &SeedTree) -> Vec<Dataset> {
        assert!(k >= 1);
        let mut order: Vec<usize> = (0..self.len()).collect();
        let mut rng = seeds.rng("partition", 0);
        shuffle(&mut rng, &mut order);
        let mut shards: Vec<Dataset> = (0..k)
            .map(|_| Dataset {
                x: Vec::with_capacity(self.len() / k * self.dim + self.dim),
                y: Vec::with_capacity(self.len() / k + 1),
                dim: self.dim,
                classes: self.classes,
            })
            .collect();
        for (pos, &i) in order.iter().enumerate() {
            let s = &mut shards[pos % k];
            s.x.extend_from_slice(self.row(i));
            s.y.push(self.y[i]);
        }
        shards
    }

    /// Deterministic per-epoch batch iterator (shuffles an index vector).
    pub fn batches<'a, R: Rng>(&'a self, batch: usize, rng: &mut R) -> BatchIter<'a> {
        let mut order: Vec<u32> = (0..self.len() as u32).collect();
        shuffle(rng, &mut order);
        BatchIter { ds: self, order, batch, pos: 0 }
    }
}

/// Owned-order batch iterator; the last partial batch is yielded too
/// (padding is the executor's job — the artifacts are padding-aware).
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    order: Vec<u32>,
    batch: usize,
    pos: usize,
}

/// One batch staged into caller-visible buffers.
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<u8>,
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let idxs = &self.order[self.pos..end];
        self.pos = end;
        let mut x = Vec::with_capacity(idxs.len() * self.ds.dim);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(self.ds.row(i as usize));
            y.push(self.ds.y[i as usize]);
        }
        Some(Batch { x, y })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        SyntheticSpec::mnist_like().generate(256, &SeedTree::new(3), 0)
    }

    #[test]
    fn synthetic_shapes_and_ranges() {
        let ds = tiny();
        assert_eq!(ds.len(), 256);
        assert_eq!(ds.dim, 784);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.y.iter().all(|&y| y < 10));
        // All ten classes present in 256 draws (deterministic seed).
        let mut seen = [false; 10];
        for &y in &ds.y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = SyntheticSpec::mnist_like().generate(64, &SeedTree::new(5), 0);
        let b = SyntheticSpec::mnist_like().generate(64, &SeedTree::new(5), 0);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = SyntheticSpec::mnist_like().generate(64, &SeedTree::new(5), 1);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn partition_is_disjoint_and_complete() {
        let ds = tiny();
        let shards = ds.partition_iid(10, &SeedTree::new(7));
        assert_eq!(shards.len(), 10);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, ds.len());
        // shard sizes within ±1
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // labels are a permutation of the originals (multiset equality)
        let mut orig = ds.y.clone();
        let mut got: Vec<u8> = shards.iter().flat_map(|s| s.y.iter().copied()).collect();
        orig.sort_unstable();
        got.sort_unstable();
        assert_eq!(orig, got);
    }

    #[test]
    fn batches_cover_every_row_once() {
        let ds = tiny();
        let mut rng = crate::rng::Xoshiro256pp::seed_from(1);
        let mut count = 0usize;
        let mut last = 0usize;
        for b in ds.batches(100, &mut rng) {
            assert_eq!(b.x.len(), b.y.len() * ds.dim);
            count += b.y.len();
            last = b.y.len();
        }
        assert_eq!(count, 256);
        assert_eq!(last, 56); // final partial batch is yielded
    }
}
