//! Deterministic synthetic MNIST-like task (the no-network substitution).
//!
//! Ten class prototypes are built as mixtures of 2-D Gaussian blobs on the
//! 28×28 grid (digit-ish blotches), then each sample is
//! `clip(prototype_shifted + pixel noise, 0, 1)` with a small random
//! translation.  Shifts + noise make the task non-trivially separable:
//! a linear model plateaus below an MLP, mirroring real MNIST's structure
//! well enough to preserve the paper's *relative* accuracy trends
//! (DESIGN.md §4).  Entirely driven by the [`SeedTree`], so every run and
//! every party sees the same dataset.

use super::Dataset;
use crate::rng::{Normal, Rng, SeedTree};

/// Parameters of the synthetic generator.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub side: usize,
    pub classes: usize,
    /// Gaussian blobs per class prototype.
    pub blobs_per_class: usize,
    /// Max |shift| in pixels applied per sample.
    pub max_shift: i32,
    /// Std-dev of additive pixel noise.
    pub noise: f32,
}

impl SyntheticSpec {
    /// The calibration used everywhere: difficulty tuned so the paper's
    /// architectures land near their reported uncompressed accuracies.
    pub fn mnist_like() -> Self {
        Self { side: 28, classes: 10, blobs_per_class: 4, max_shift: 2, noise: 0.12 }
    }

    pub fn dim(&self) -> usize {
        self.side * self.side
    }

    /// Class prototypes are a pure function of the seed tree (tag
    /// "synthetic-proto"), independent of which split is generated.
    fn prototypes(&self, seeds: &SeedTree) -> Vec<Vec<f32>> {
        let side = self.side as f32;
        (0..self.classes)
            .map(|cls| {
                let mut rng = seeds.rng("synthetic-proto", cls as u64);
                let mut img = vec![0.0f32; self.dim()];
                for _ in 0..self.blobs_per_class {
                    // Blob center biased inward so shifts keep mass on-grid.
                    let cx = 4.0 + rng.next_f32() * (side - 8.0);
                    let cy = 4.0 + rng.next_f32() * (side - 8.0);
                    let sx = 1.5 + rng.next_f32() * 2.5;
                    let sy = 1.5 + rng.next_f32() * 2.5;
                    let amp = 0.6 + rng.next_f32() * 0.4;
                    for r in 0..self.side {
                        for c in 0..self.side {
                            let dx = (c as f32 - cx) / sx;
                            let dy = (r as f32 - cy) / sy;
                            img[r * self.side + c] += amp * (-0.5 * (dx * dx + dy * dy)).exp();
                        }
                    }
                }
                for v in img.iter_mut() {
                    *v = v.min(1.0);
                }
                img
            })
            .collect()
    }

    /// Generate `num` samples for split `split` (0 = train, 1 = test, ...).
    pub fn generate(&self, num: usize, seeds: &SeedTree, split: u64) -> Dataset {
        let protos = self.prototypes(seeds);
        let mut rng = seeds.rng("synthetic-data", split);
        let mut normal = Normal::new();
        let dim = self.dim();
        let mut x = Vec::with_capacity(num * dim);
        let mut y = Vec::with_capacity(num);
        let side = self.side as i32;
        for _ in 0..num {
            let cls = rng.next_below(self.classes as u64) as usize;
            y.push(cls as u8);
            let shift_r = rng.next_below((2 * self.max_shift + 1) as u64) as i32 - self.max_shift;
            let shift_c = rng.next_below((2 * self.max_shift + 1) as u64) as i32 - self.max_shift;
            let proto = &protos[cls];
            let base = x.len();
            x.resize(base + dim, 0.0);
            let img = &mut x[base..base + dim];
            for r in 0..side {
                let sr = r - shift_r;
                if !(0..side).contains(&sr) {
                    continue;
                }
                for c in 0..side {
                    let sc = c - shift_c;
                    if !(0..side).contains(&sc) {
                        continue;
                    }
                    img[(r * side + c) as usize] = proto[(sr * side + sc) as usize];
                }
            }
            for v in img.iter_mut() {
                let noisy = *v + self.noise * normal.sample(&mut rng) as f32;
                *v = noisy.clamp(0.0, 1.0);
            }
        }
        Dataset { x, y, dim, classes: self.classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_are_distinct() {
        let spec = SyntheticSpec::mnist_like();
        let protos = spec.prototypes(&SeedTree::new(0));
        assert_eq!(protos.len(), 10);
        for a in 0..10 {
            for b in (a + 1)..10 {
                let dist: f32 = protos[a]
                    .iter()
                    .zip(&protos[b])
                    .map(|(&u, &v)| (u - v) * (u - v))
                    .sum();
                assert!(dist > 1.0, "classes {a},{b} too similar (d²={dist})");
            }
        }
    }

    #[test]
    fn splits_share_prototypes_but_not_samples() {
        let spec = SyntheticSpec::mnist_like();
        let seeds = SeedTree::new(4);
        let train = spec.generate(128, &seeds, 0);
        let test = spec.generate(128, &seeds, 1);
        assert_ne!(train.x, test.x);
        // Same class geometry: nearest-prototype classification trained on
        // nothing should agree across splits well above chance.
        let protos = spec.prototypes(&seeds);
        let acc = |ds: &Dataset| {
            let mut ok = 0;
            for i in 0..ds.len() {
                let row = ds.row(i);
                let mut best = (f32::INFINITY, 0usize);
                for (c, p) in protos.iter().enumerate() {
                    let d: f32 = row.iter().zip(p).map(|(&u, &v)| (u - v) * (u - v)).sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if best.1 == ds.y[i] as usize {
                    ok += 1;
                }
            }
            ok as f64 / ds.len() as f64
        };
        assert!(acc(&train) > 0.6, "train acc {}", acc(&train));
        assert!(acc(&test) > 0.6, "test acc {}", acc(&test));
    }
}
