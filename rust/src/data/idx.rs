//! IDX (LeCun MNIST) file format reader.
//!
//! Big-endian magic: `0x00 0x00 <dtype> <ndims>` followed by `ndims` u32
//! dimension sizes, then the raw payload.  Only the two shapes MNIST uses
//! are supported: u8 × 3-D (images) and u8 × 1-D (labels).

use std::io::Read;
use std::path::Path;

#[derive(Debug)]
pub enum IdxError {
    Io(std::io::Error),
    Malformed(&'static str),
}

impl std::fmt::Display for IdxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IdxError::Io(e) => write!(f, "idx io error: {e}"),
            IdxError::Malformed(m) => write!(f, "malformed idx file: {m}"),
        }
    }
}

impl std::error::Error for IdxError {}

impl From<std::io::Error> for IdxError {
    fn from(e: std::io::Error) -> Self {
        IdxError::Io(e)
    }
}

fn read_all(path: &Path) -> Result<Vec<u8>, IdxError> {
    let mut buf = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut buf)?;
    Ok(buf)
}

fn be_u32(b: &[u8], off: usize) -> Result<u32, IdxError> {
    b.get(off..off + 4)
        .map(|s| u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
        .ok_or(IdxError::Malformed("truncated header"))
}

/// Load an IDX3 u8 image file → (`[num * rows*cols]` f32 in `[0,1]`, dim).
pub fn load_idx_images(path: &Path) -> Result<(Vec<f32>, usize), IdxError> {
    let b = read_all(path)?;
    if be_u32(&b, 0)? != 0x0000_0803 {
        return Err(IdxError::Malformed("bad image magic (want 0x00000803)"));
    }
    let num = be_u32(&b, 4)? as usize;
    let rows = be_u32(&b, 8)? as usize;
    let cols = be_u32(&b, 12)? as usize;
    let dim = rows * cols;
    let payload = b.get(16..).ok_or(IdxError::Malformed("truncated header"))?;
    if payload.len() != num * dim {
        return Err(IdxError::Malformed("payload size mismatch"));
    }
    let x = payload.iter().map(|&p| p as f32 / 255.0).collect();
    Ok((x, dim))
}

/// Load an IDX1 u8 label file → `[num]` labels.
pub fn load_idx_labels(path: &Path) -> Result<Vec<u8>, IdxError> {
    let b = read_all(path)?;
    if be_u32(&b, 0)? != 0x0000_0801 {
        return Err(IdxError::Malformed("bad label magic (want 0x00000801)"));
    }
    let num = be_u32(&b, 4)? as usize;
    let payload = b.get(8..).ok_or(IdxError::Malformed("truncated header"))?;
    if payload.len() != num {
        return Err(IdxError::Malformed("payload size mismatch"));
    }
    Ok(payload.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("zampling-idx-{name}-{}", std::process::id()));
        std::fs::File::create(&p).unwrap().write_all(bytes).unwrap();
        p
    }

    #[test]
    fn roundtrip_images() {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        b.extend_from_slice(&2u32.to_be_bytes()); // 2 images
        b.extend_from_slice(&2u32.to_be_bytes()); // 2x2
        b.extend_from_slice(&2u32.to_be_bytes());
        b.extend_from_slice(&[0, 51, 102, 255, 255, 204, 153, 0]);
        let p = write_tmp("img", &b);
        let (x, dim) = load_idx_images(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(dim, 4);
        assert_eq!(x.len(), 8);
        assert!((x[3] - 1.0).abs() < 1e-6);
        assert!((x[1] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn roundtrip_labels() {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        b.extend_from_slice(&3u32.to_be_bytes());
        b.extend_from_slice(&[7, 0, 9]);
        let p = write_tmp("lbl", &b);
        let y = load_idx_labels(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(y, vec![7, 0, 9]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let p = write_tmp("bad", &[0, 0, 8, 1, 0, 0]);
        assert!(matches!(load_idx_labels(&p), Err(IdxError::Malformed(_))));
        std::fs::remove_file(&p).ok();

        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        b.extend_from_slice(&5u32.to_be_bytes());
        b.extend_from_slice(&[1, 2]); // claims 5, has 2
        let p = write_tmp("trunc", &b);
        assert!(matches!(load_idx_labels(&p), Err(IdxError::Malformed(_))));
        std::fs::remove_file(&p).ok();
    }
}
