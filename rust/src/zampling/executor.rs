//! Dense-step execution abstraction.
//!
//! The trainers are generic over how the dense `(w, batch) → (loss,
//! grad_w, correct)` step runs: [`NativeExecutor`] uses the pure-Rust MLP
//! oracle; `runtime::PjrtExecutor` (the real path) runs the AOT HLO
//! artifacts through the PJRT CPU client.  Both pad partial batches to
//! their fixed capacity — the artifacts' weighted loss makes padding rows
//! inert (see `python/compile/model.py`).

use crate::nn::{ArchSpec, MlpRef};

/// Result of one dense step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepResult {
    pub loss: f32,
    /// Number of correctly-classified *real* rows in the batch.
    pub correct: f32,
}

/// The dense compute interface the trainers program against.
pub trait DenseExecutor {
    /// `loss, grad_w, correct` on a train batch.  `rows ≤ train_batch()`;
    /// `x` is `[rows, in_dim]`, `y1h` is `[rows, out_dim]`, `grad_out`
    /// has length `m` and is fully overwritten.
    fn train_step(
        &mut self,
        w: &[f32],
        x: &[f32],
        y1h: &[f32],
        rows: usize,
        grad_out: &mut [f32],
    ) -> StepResult;

    /// `loss, correct` on an eval batch.  `rows ≤ eval_batch()`.
    fn eval_step(&mut self, w: &[f32], x: &[f32], y1h: &[f32], rows: usize) -> StepResult;

    /// Fixed train-batch capacity of the backend.
    fn train_batch(&self) -> usize;

    /// Fixed eval-batch capacity of the backend.
    fn eval_batch(&self) -> usize;

    fn arch(&self) -> &ArchSpec;
}

/// Evaluate a full dataset through any executor, chunking to the
/// executor's eval capacity.  Returns `(mean loss, accuracy)` over the
/// `rows` real rows (per-chunk losses are re-weighted by chunk size).
pub fn eval_dataset(
    exec: &mut dyn DenseExecutor,
    w: &[f32],
    x: &[f32],
    y1h: &[f32],
    rows: usize,
) -> (f64, f64) {
    let cap = exec.eval_batch();
    let in_dim = exec.arch().input_dim();
    let out_dim = exec.arch().output_dim();
    let mut correct = 0.0f64;
    let mut loss_weighted = 0.0f64;
    let mut done = 0usize;
    while done < rows {
        let take = cap.min(rows - done);
        let r = exec.eval_step(
            w,
            &x[done * in_dim..(done + take) * in_dim],
            &y1h[done * out_dim..(done + take) * out_dim],
            take,
        );
        loss_weighted += r.loss as f64 * take as f64;
        correct += r.correct as f64;
        done += take;
    }
    (loss_weighted / rows.max(1) as f64, correct / rows.max(1) as f64)
}

/// Pure-Rust executor over [`MlpRef`].
pub struct NativeExecutor {
    mlp: MlpRef,
    arch: ArchSpec,
    train_batch: usize,
    eval_batch: usize,
}

impl NativeExecutor {
    pub fn new(arch: ArchSpec, train_batch: usize, eval_batch: usize) -> Self {
        let cap = train_batch.max(eval_batch);
        Self { mlp: MlpRef::new(arch.clone(), cap), arch, train_batch, eval_batch }
    }
}

impl DenseExecutor for NativeExecutor {
    fn train_step(
        &mut self,
        w: &[f32],
        x: &[f32],
        y1h: &[f32],
        rows: usize,
        grad_out: &mut [f32],
    ) -> StepResult {
        let out = self.mlp.train_step(w, x, y1h, rows, grad_out);
        StepResult { loss: out.loss, correct: out.correct }
    }

    fn eval_step(&mut self, w: &[f32], x: &[f32], y1h: &[f32], rows: usize) -> StepResult {
        let out = self.mlp.eval_step(w, x, y1h, rows);
        StepResult { loss: out.loss, correct: out.correct }
    }

    fn train_batch(&self) -> usize {
        self.train_batch
    }

    fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn arch(&self) -> &ArchSpec {
        &self.arch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn native_executor_runs_both_steps() {
        let arch = ArchSpec::small();
        let mut exec = NativeExecutor::new(arch.clone(), 8, 16);
        let mut r = Xoshiro256pp::seed_from(0);
        let w: Vec<f32> = (0..arch.num_params()).map(|_| (r.next_f32() - 0.5) * 0.1).collect();
        let x: Vec<f32> = (0..8 * 784).map(|_| r.next_f32()).collect();
        let mut y = vec![0.0f32; 8 * 10];
        for row in 0..8 {
            y[row * 10 + (row % 10)] = 1.0;
        }
        let mut g = vec![0.0; w.len()];
        let t = exec.train_step(&w, &x, &y, 8, &mut g);
        let e = exec.eval_step(&w, &x, &y, 8);
        assert!((t.loss - e.loss).abs() < 1e-5);
        assert_eq!(t.correct, e.correct);
        assert!(g.iter().any(|&v| v != 0.0));
    }
}
