//! Optimizers on score space.
//!
//! §3 "Experimental Constant": *"All our training is run using Adam
//! optimizer, with momentum 0.9 and varying learning rate."*  The update
//! is computed as a `delta` vector that [`super::ProbVector::apply_update`]
//! subtracts from the scores (so the optimizer never sees the clip).

use crate::config::Optimizer;

/// Adam moment state.
#[derive(Clone, Debug)]
pub struct AdamState {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
    beta1: f64,
    beta2: f64,
    eps: f64,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// SGD or Adam over the score vector; produces the scaled step `delta`
/// such that the parameter update is `s ← s − delta`.
#[derive(Clone, Debug)]
pub enum ScoreOptimizer {
    Sgd { lr: f64 },
    Adam { lr: f64, state: AdamState },
}

impl ScoreOptimizer {
    pub fn new(kind: Optimizer, lr: f64, n: usize) -> Self {
        match kind {
            Optimizer::Sgd => ScoreOptimizer::Sgd { lr },
            Optimizer::Adam => ScoreOptimizer::Adam { lr, state: AdamState::new(n) },
        }
    }

    /// Compute `delta` from the (already gated) gradient, in place.
    pub fn step(&mut self, grad: &mut [f32]) {
        match self {
            ScoreOptimizer::Sgd { lr } => {
                let lr = *lr as f32;
                for g in grad.iter_mut() {
                    *g *= lr;
                }
            }
            ScoreOptimizer::Adam { lr, state } => {
                state.t += 1;
                let b1 = state.beta1;
                let b2 = state.beta2;
                let bc1 = 1.0 - b1.powi(state.t as i32);
                let bc2 = 1.0 - b2.powi(state.t as i32);
                let lr = *lr;
                for (i, g) in grad.iter_mut().enumerate() {
                    let gi = *g as f64;
                    let m = b1 * state.m[i] as f64 + (1.0 - b1) * gi;
                    let v = b2 * state.v[i] as f64 + (1.0 - b2) * gi * gi;
                    state.m[i] = m as f32;
                    state.v[i] = v as f32;
                    let mhat = m / bc1;
                    let vhat = v / bc2;
                    *g = (lr * mhat / (vhat.sqrt() + state.eps)) as f32;
                }
            }
        }
    }

    pub fn lr(&self) -> f64 {
        match self {
            ScoreOptimizer::Sgd { lr } | ScoreOptimizer::Adam { lr, .. } => *lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_scales_by_lr() {
        let mut o = ScoreOptimizer::new(Optimizer::Sgd, 0.1, 3);
        let mut g = vec![1.0, -2.0, 0.0];
        o.step(&mut g);
        assert_eq!(g, vec![0.1, -0.2, 0.0]);
    }

    #[test]
    fn adam_first_step_is_lr_sign() {
        // With bias correction, the first Adam step is ≈ lr·sign(g).
        let mut o = ScoreOptimizer::new(Optimizer::Adam, 0.01, 2);
        let mut g = vec![0.5, -3.0];
        o.step(&mut g);
        assert!((g[0] - 0.01).abs() < 1e-4, "{g:?}");
        assert!((g[1] + 0.01).abs() < 1e-4, "{g:?}");
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize f(x) = (x − 3)², start at 0.
        let mut o = ScoreOptimizer::new(Optimizer::Adam, 0.1, 1);
        let mut x = 0.0f32;
        for _ in 0..500 {
            let mut g = vec![2.0 * (x - 3.0)];
            o.step(&mut g);
            x -= g[0];
        }
        assert!((x - 3.0).abs() < 0.05, "x={x}");
    }

    #[test]
    fn adam_zero_grad_produces_zero_delta_initially() {
        let mut o = ScoreOptimizer::new(Optimizer::Adam, 0.1, 2);
        let mut g = vec![0.0, 0.0];
        o.step(&mut g);
        assert_eq!(g, vec![0.0, 0.0]);
    }
}
