//! The Local Zampling trainer (§1.3) and the ContinuousModel ablation.
//!
//! Per batch (sampled regime):
//!   1. sample `z ~ Bern(p)`;
//!   2. reconstruct `w = Qz` (sparse row gather);
//!   3. dense step `(w, batch) → (loss, ∇_w L, correct)` via the executor
//!      (PJRT artifact or native oracle);
//!   4. chain rule `∇_s L = (Qᵀ ∇_w L) ⊙ 1{0 < p < 1}`;
//!   5. optimizer step on the scores, clip back to `p`.
//!
//! The ContinuousModel regime (Appendix A / Table 4 "Regular") replaces
//! step 1–2 with `w = Qp` and keeps everything else identical — exactly
//! the paper's description ("the rest is exactly the same - including how
//! the gradients are updated").
//!
//! Early stopping follows §3: up to `epochs` epochs with `patience`
//! epochs of patience and `min_delta` on the validation loss.

use std::sync::Arc;

use super::{evaluate, DenseExecutor, EvalReport, ProbVector, ScoreOptimizer};
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::nn::one_hot_into;
use crate::rng::{SeedTree, Xoshiro256pp};
use crate::sparse::{spmv_bits_par_into, spmv_par_into, spmv_t_par_into, CscView, QMatrix};

/// One epoch's record.
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_acc: f64,
}

/// Outcome of a local training run.
pub struct LocalOutcome {
    pub epochs: Vec<EpochRecord>,
    pub report: EvalReport,
    /// Final probability vector (for sensitivity / zonotope analyses).
    pub probs: Vec<f32>,
}

/// Reusable training state: the paper's (Q, p) pair plus scratch buffers.
/// `Q`/CSC are `Arc`-shared: federated clients all hold the same matrix
/// (generated once from the shared seed), exactly as the protocol assumes.
pub struct LocalZampling {
    pub q: Arc<QMatrix>,
    pub csc: Arc<CscView>,
    pub pv: ProbVector,
    opt: ScoreOptimizer,
    continuous: bool,
    // scratch
    zbits: Vec<u64>,
    w: Vec<f32>,
    grad_w: Vec<f32>,
    grad_s: Vec<f32>,
    y1h: Vec<f32>,
    rng: Xoshiro256pp,
}

impl LocalZampling {
    /// Build from config: generates Q from the seed tree, initializes
    /// `p ~ U(0,1)^n` from the "p-init" stream.
    pub fn new(cfg: &TrainConfig, seeds: &SeedTree) -> Self {
        let q = Arc::new(QMatrix::generate(&cfg.arch, cfg.n, cfg.d, seeds));
        let csc = Arc::new(q.to_csc(None));
        let mut init_rng = seeds.rng("p-init", 0);
        let pv = ProbVector::init_uniform(cfg.n, &mut init_rng);
        Self::from_parts(cfg, q, csc, pv, seeds)
    }

    /// Build with an explicit initial `p` (Beta inits, federated clients).
    pub fn from_parts(
        cfg: &TrainConfig,
        q: Arc<QMatrix>,
        csc: Arc<CscView>,
        pv: ProbVector,
        seeds: &SeedTree,
    ) -> Self {
        let m = q.m;
        let n = q.n;
        Self {
            opt: ScoreOptimizer::new(cfg.optimizer, cfg.lr, n),
            continuous: cfg.continuous,
            zbits: Vec::with_capacity(n.div_ceil(64)),
            w: vec![0.0; m],
            grad_w: vec![0.0; m],
            grad_s: vec![0.0; n],
            y1h: Vec::new(),
            rng: seeds.rng("train-sampler", 0),
            q,
            csc,
            pv,
        }
    }

    /// Reset the optimizer (used by federated clients at round start so
    /// local Adam moments don't leak across the server aggregation).
    pub fn reset_optimizer(&mut self, cfg: &TrainConfig) {
        self.opt = ScoreOptimizer::new(cfg.optimizer, cfg.lr, self.q.n);
    }

    /// Replace the batch-sampler RNG.  Federated clients reseed it from
    /// `(seed, client, round)` at every round start, making a client's
    /// round output a pure function of the broadcast it received — a
    /// worker that crashes and reconnects (or a resumed leader's replay
    /// of an in-flight round) recomputes exactly the same mask.
    pub fn reseed_sampler(&mut self, rng: Xoshiro256pp) {
        self.rng = rng;
    }

    /// Reconstruct the weights for the current regime: `Qz` (sampling a
    /// fresh mask) or `Qp` (continuous).
    ///
    /// Sampled regime: the mask goes straight into a `u64` bitset and
    /// through the branchless `spmv_bits` kernel — no bool→f32 widening,
    /// no float gather of 0/1 values.  Both regimes shard across the
    /// pool at MnistFc scale.
    fn materialize_weights(&mut self) {
        if self.continuous {
            spmv_par_into(&self.q, self.pv.probs(), &mut self.w);
        } else {
            self.pv.sample_mask_bits(&mut self.rng, &mut self.zbits);
            spmv_bits_par_into(&self.q, &self.zbits, &mut self.w);
        }
    }

    /// One optimizer step on one batch; returns (loss, correct).
    pub fn step_batch(
        &mut self,
        exec: &mut dyn DenseExecutor,
        x: &[f32],
        labels: &[u8],
    ) -> (f64, f64) {
        let rows = labels.len();
        let out_dim = exec.arch().output_dim();
        if self.y1h.len() < rows * out_dim {
            self.y1h.resize(rows * out_dim, 0.0);
        }
        one_hot_into(labels, out_dim, &mut self.y1h);
        self.materialize_weights();
        let res = exec.train_step(&self.w, x, &self.y1h[..rows * out_dim], rows, &mut self.grad_w);
        // Chain rule through Q, gate at the clip saturations, step.
        spmv_t_par_into(&self.csc, &self.grad_w, &mut self.grad_s);
        self.pv.gate_gradient(&mut self.grad_s);
        self.opt.step(&mut self.grad_s);
        self.pv.apply_update(&self.grad_s);
        (res.loss as f64, res.correct as f64)
    }

    /// One epoch over `train`; returns mean train loss.
    pub fn run_epoch(&mut self, exec: &mut dyn DenseExecutor, train: &Dataset, batch: usize) -> f64 {
        let mut epoch_rng = {
            // dedicated stream per epoch: reproducible regardless of eval calls
            let s = self.rng.next();
            Xoshiro256pp::seed_from(s)
        };
        let mut loss_sum = 0.0;
        let mut rows_sum = 0usize;
        let cap = exec.train_batch().min(batch);
        for b in train.batches(cap, &mut epoch_rng) {
            let (loss, _) = self.step_batch(exec, &b.x, &b.y);
            loss_sum += loss * b.y.len() as f64;
            rows_sum += b.y.len();
        }
        loss_sum / rows_sum.max(1) as f64
    }
}

/// Train Local Zampling end-to-end per the config; evaluates on `test`
/// with `eval_samples` sampled masks at the end (§3.1 uses 100).
pub fn train_local(
    cfg: &TrainConfig,
    exec: &mut dyn DenseExecutor,
    train: &Dataset,
    test: &Dataset,
    eval_samples: usize,
) -> LocalOutcome {
    train_local_with_init(cfg, exec, train, test, eval_samples, None)
}

/// [`train_local`] with an optional Beta(α, β) initialization of `p(0)`
/// (the Appendix A integrality-gap study; `None` = the paper's uniform).
pub fn train_local_with_init(
    cfg: &TrainConfig,
    exec: &mut dyn DenseExecutor,
    train: &Dataset,
    test: &Dataset,
    eval_samples: usize,
    beta_init: Option<(f64, f64)>,
) -> LocalOutcome {
    let seeds = SeedTree::new(cfg.seed);
    let mut state = match beta_init {
        None => LocalZampling::new(cfg, &seeds),
        Some((alpha, beta)) => {
            let q = Arc::new(QMatrix::generate(&cfg.arch, cfg.n, cfg.d, &seeds));
            let csc = Arc::new(q.to_csc(None));
            let mut init_rng = seeds.rng("p-init", 0);
            let pv = ProbVector::init_beta(cfg.n, alpha, beta, &mut init_rng);
            LocalZampling::from_parts(cfg, q, csc, pv, &seeds)
        }
    };
    let out_dim = exec.arch().output_dim();

    // Stage the test split once.
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);

    let mut records = Vec::new();
    let mut best_val = f64::INFINITY;
    let mut stale = 0usize;
    for epoch in 0..cfg.epochs {
        let train_loss = state.run_epoch(exec, train, cfg.batch);
        // Validation: expected network w = Qp (cheap, deterministic).
        state.q.spmv_into(state.pv.probs(), &mut state.w);
        let (val_loss, val_acc) =
            super::eval_dataset(exec, &state.w, &test.x, &test_y1h, test.len());
        records.push(EpochRecord { epoch, train_loss, val_loss, val_acc });
        // Early stopping (§3: patience 10, delta 1e-4).
        if val_loss < best_val - cfg.min_delta {
            best_val = val_loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    let mut eval_rng = seeds.rng("eval-sampler", 0);
    let report = evaluate(
        exec,
        &state.q,
        &state.pv,
        &test.x,
        &test_y1h,
        test.len(),
        eval_samples,
        &mut eval_rng,
    );
    LocalOutcome { epochs: records, report, probs: state.pv.probs().to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    fn tiny_cfg(continuous: bool) -> TrainConfig {
        let mut cfg = TrainConfig::local(ArchSpec::small(), 4, 5, 0).ci();
        cfg.continuous = continuous;
        // CI-scale runs see ~50 optimizer steps, not the paper's ~47k —
        // a larger lr compensates so learning is visible in the test.
        cfg.lr = 0.05;
        cfg.epochs = 8;
        cfg.train_rows = 768;
        cfg.test_rows = 256;
        cfg
    }

    fn run(cfg: &TrainConfig) -> LocalOutcome {
        let seeds = SeedTree::new(cfg.seed);
        let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
        let mut exec = NativeExecutor::new(cfg.arch.clone(), cfg.batch, 256);
        train_local(cfg, &mut exec, &train, &test, 10)
    }

    #[test]
    fn sampled_training_learns_above_chance() {
        let out = run(&tiny_cfg(false));
        assert!(
            out.report.mean_sampled_acc > 0.3,
            "mean sampled acc {} not above chance",
            out.report.mean_sampled_acc
        );
        // train loss decreased
        let first = out.epochs.first().unwrap().train_loss;
        let last = out.epochs.last().unwrap().train_loss;
        assert!(last < first, "loss {first} → {last}");
    }

    #[test]
    fn continuous_training_learns_expected_network() {
        let out = run(&tiny_cfg(true));
        assert!(
            out.report.expected_acc > 0.3,
            "expected acc {} not above chance",
            out.report.expected_acc
        );
    }

    #[test]
    fn training_is_deterministic() {
        let a = run(&tiny_cfg(false));
        let b = run(&tiny_cfg(false));
        assert_eq!(a.probs, b.probs);
        assert_eq!(a.report.mean_sampled_acc, b.report.mean_sampled_acc);
    }

    #[test]
    fn probs_stay_in_unit_interval() {
        let out = run(&tiny_cfg(false));
        assert!(out.probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn early_stopping_respects_patience() {
        let mut cfg = tiny_cfg(false);
        cfg.epochs = 100;
        cfg.patience = 1;
        cfg.min_delta = 1e9; // nothing ever counts as an improvement
        let out = run(&cfg);
        assert!(out.epochs.len() <= 2, "ran {} epochs", out.epochs.len());
    }
}
