//! The paper's core: training-by-sampling on the zonotope of `Q` (§1.3).
//!
//! * [`ProbVector`] — the trainable `p ∈ [0,1]^n` with its score twin `s`,
//!   the clip `f(x) = min(max(x, 0), 1)`, Bernoulli mask sampling, and the
//!   straight-through gradient gate `1{0 < p < 1}`.
//! * [`ScoreOptimizer`] — SGD / Adam(β₁ = 0.9) on score space (§3 trains
//!   with Adam, momentum 0.9).
//! * [`LocalZampling`] — the centralized trainer (§1.3 Local Zampling):
//!   per batch, sample `z ~ Bern(p)`, reconstruct `w = Qz`, run the dense
//!   train step (PJRT artifact or native oracle), chain the weight
//!   gradient back through `Qᵀ`, and step the scores.
//! * [`ContinuousModel`] — the no-sampling ablation (`w = Qp`, Appendix A
//!   / Table 4's "Regular" column).
//! * [`evaluate`] — mean-sampled / expected / discretized / best-mask
//!   accuracy estimators (§3's metrics).

mod executor;
mod optimizer;
mod trainer;

pub use executor::{eval_dataset, DenseExecutor, NativeExecutor, StepResult};
pub use optimizer::{AdamState, ScoreOptimizer};
pub use trainer::{
    train_local, train_local_with_init, EpochRecord, LocalOutcome, LocalZampling,
};

use crate::rng::{Normal, Rng};
use crate::sparse::{spmv_par_into, QMatrix};

/// Clip to the unit interval — the paper's `f(x) = max(min(x, 1), 0)`
/// ("ReLU clipped at 1"), used instead of Zhou et al.'s sigmoid.
#[inline]
pub fn clip01(x: f32) -> f32 {
    x.clamp(0.0, 1.0)
}

/// The trainable probability vector and its score twin.
///
/// Invariant: `p[i] == clip01(s[i])` after every mutation.
#[derive(Clone, Debug)]
pub struct ProbVector {
    s: Vec<f32>,
    p: Vec<f32>,
}

impl ProbVector {
    /// §1.3 initialization: `p(0) ~ U(0,1)^n`.
    pub fn init_uniform<R: Rng>(n: usize, rng: &mut R) -> Self {
        let p: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        Self { s: p.clone(), p }
    }

    /// Beta(α, β) initialization (Appendix A's integrality-gap study).
    /// Sampled via the Jöhnk/ratio-of-uniforms-free gamma-less method:
    /// for the α = β ≤ 1 cases the appendix sweeps, inverse-CDF sampling
    /// on a fine grid is accurate and dependency-free.
    pub fn init_beta<R: Rng>(n: usize, alpha: f64, beta: f64, rng: &mut R) -> Self {
        let p: Vec<f32> = (0..n).map(|_| sample_beta(alpha, beta, rng) as f32).collect();
        Self { s: p.clone(), p }
    }

    pub fn from_probs(p: Vec<f32>) -> Self {
        debug_assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
        Self { s: p.clone(), p }
    }

    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    pub fn probs(&self) -> &[f32] {
        &self.p
    }

    pub fn scores(&self) -> &[f32] {
        &self.s
    }

    /// Overwrite with server-provided probabilities (client receive path:
    /// "each client calculates s(t) = p(t)").
    pub fn set_probs(&mut self, p: &[f32]) {
        assert_eq!(p.len(), self.p.len());
        self.p.copy_from_slice(p);
        self.s.copy_from_slice(p);
    }

    /// Sample `z ~ Bern(p)` into a bool mask.
    pub fn sample_mask<R: Rng>(&self, rng: &mut R, out: &mut Vec<bool>) {
        out.clear();
        out.extend(self.p.iter().map(|&pi| rng.next_f32() < pi));
    }

    /// Sample `z ~ Bern(p)` directly into a `u64` bitset — the wire
    /// format and the input of the branchless `spmv_bits` kernels, so
    /// the sampled-regime hot path skips the bool→f32 widening entirely.
    /// Consumes the rng stream identically to [`Self::sample_mask`].
    pub fn sample_mask_bits<R: Rng>(&self, rng: &mut R, out: &mut Vec<u64>) {
        out.clear();
        out.resize(self.p.len().div_ceil(64), 0u64);
        for (j, &pi) in self.p.iter().enumerate() {
            if rng.next_f32() < pi {
                out[j >> 6] |= 1 << (j & 63);
            }
        }
    }

    /// Deterministic rounding `p∘ = argmin_{z∈{0,1}} |p − z|` (Appendix A's
    /// discretized network).
    pub fn discretize(&self) -> Vec<bool> {
        self.p.iter().map(|&pi| pi >= 0.5).collect()
    }

    /// Apply an already-scaled score update `delta` (from the optimizer),
    /// then re-clip: `s ← s − delta`, `p ← f(s)`.
    ///
    /// The paper keeps scores and probabilities identified between rounds
    /// (`s(t) = p(t)`), so after clipping we also fold `s` back onto `p`;
    /// this makes the update idempotent at the saturation boundaries and
    /// matches the protocol's per-round reset.
    pub fn apply_update(&mut self, delta: &[f32]) {
        debug_assert_eq!(delta.len(), self.s.len());
        for i in 0..self.s.len() {
            self.s[i] -= delta[i];
            self.p[i] = clip01(self.s[i]);
            self.s[i] = self.p[i];
        }
    }

    /// The straight-through gate of the gradient rule
    /// `∇_s L = (Qᵀ ∇_w L) ⊙ 1{0 < p < 1}`: zero entries whose
    /// probability has saturated.
    pub fn gate_gradient(&self, grad_s: &mut [f32]) {
        debug_assert_eq!(grad_s.len(), self.p.len());
        for (g, &pi) in grad_s.iter_mut().zip(&self.p) {
            if pi <= 0.0 || pi >= 1.0 {
                *g = 0.0;
            }
        }
    }

    /// Count of non-trivial coordinates `τ ≤ p_j ≤ 1 − τ` — the dimension
    /// of the τ-hypercube `C_τ` (Definition 2.2).
    pub fn dim_c_tau(&self, tau: f32) -> usize {
        self.p.iter().filter(|&&pi| pi >= tau && pi <= 1.0 - tau).count()
    }
}

/// Beta(α, β) sampling via two gammas: `X ~ Ga(α), Y ~ Ga(β), X/(X+Y)`.
///
/// Gammas use Marsaglia–Tsang squeeze (α ≥ 1) with the `Ga(α) =
/// Ga(α+1)·U^{1/α}` boost for α < 1 — exact for the whole α = β sweep of
/// Appendix A including the endpoint-concentrated α < 1 cases a
/// grid-inverse-CDF would distort.
fn sample_beta<R: Rng>(alpha: f64, beta: f64, rng: &mut R) -> f64 {
    let x = sample_gamma(alpha, rng);
    let y = sample_gamma(beta, rng);
    if x + y == 0.0 {
        0.5
    } else {
        x / (x + y)
    }
}

fn sample_gamma<R: Rng>(alpha: f64, rng: &mut R) -> f64 {
    debug_assert!(alpha > 0.0);
    if alpha < 1.0 {
        // boost: Ga(α) = Ga(α+1) · U^{1/α}
        let mut u = rng.next_f64();
        while u <= f64::MIN_POSITIVE {
            u = rng.next_f64();
        }
        return sample_gamma(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    // Marsaglia–Tsang (2000).
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    let mut normal = Normal::new();
    loop {
        let xn = normal.sample(rng);
        let v = (1.0 + c * xn).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64();
        if u < 1.0 - 0.0331 * xn.powi(4) {
            return d * v;
        }
        if u > 0.0 && u.ln() < 0.5 * xn * xn + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Mask → f32 vector (for the float `spmv` path).
pub fn mask_to_f32(mask: &[bool], out: &mut Vec<f32>) {
    out.clear();
    out.extend(mask.iter().map(|&b| b as u8 as f32));
}

/// Accuracy estimators over a trained state (§3 metrics).
pub struct EvalReport {
    pub mean_sampled_acc: f64,
    pub sampled_acc_std: f64,
    pub best_sampled_acc: f64,
    pub expected_acc: f64,
    pub discretized_acc: f64,
}

/// Evaluate mean-sampled (over `samples` masks), expected (`w = Qp`), and
/// discretized accuracy on `(x, y1h)` eval data through `exec`.
#[allow(clippy::too_many_arguments)]
pub fn evaluate<R: Rng>(
    exec: &mut dyn DenseExecutor,
    q: &QMatrix,
    pv: &ProbVector,
    x: &[f32],
    y1h: &[f32],
    rows: usize,
    samples: usize,
    rng: &mut R,
) -> EvalReport {
    let mut mask = Vec::with_capacity(pv.len());
    let mut zf = Vec::with_capacity(pv.len());
    let mut w = vec![0.0f32; q.m];
    let mut accs = crate::metrics::Summary::default();
    let mut best = 0.0f64;
    for _ in 0..samples {
        pv.sample_mask(rng, &mut mask);
        mask_to_f32(&mask, &mut zf);
        spmv_par_into(q, &zf, &mut w);
        let (_, acc) = eval_dataset(exec, &w, x, y1h, rows);
        accs.push(acc);
        best = best.max(acc);
    }
    // Expected network: w = Q p.
    spmv_par_into(q, pv.probs(), &mut w);
    let (_, expected) = eval_dataset(exec, &w, x, y1h, rows);
    // Discretized network.
    let disc = pv.discretize();
    mask_to_f32(&disc, &mut zf);
    spmv_par_into(q, &zf, &mut w);
    let (_, discretized) = eval_dataset(exec, &w, x, y1h, rows);
    EvalReport {
        mean_sampled_acc: accs.mean(),
        sampled_acc_std: accs.std(),
        best_sampled_acc: best,
        expected_acc: expected,
        discretized_acc: discretized,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn clip_is_the_papers_f() {
        assert_eq!(clip01(-0.5), 0.0);
        assert_eq!(clip01(0.25), 0.25);
        assert_eq!(clip01(1.5), 1.0);
    }

    #[test]
    fn init_uniform_in_range_and_seeded() {
        let mut r = Xoshiro256pp::seed_from(0);
        let a = ProbVector::init_uniform(1000, &mut r);
        assert!(a.probs().iter().all(|&p| (0.0..1.0).contains(&p)));
        let mean: f32 = a.probs().iter().sum::<f32>() / 1000.0;
        assert!((mean - 0.5).abs() < 0.05);
    }

    #[test]
    fn apply_update_clips_and_gates() {
        let mut pv = ProbVector::from_probs(vec![0.0, 0.5, 1.0]);
        pv.apply_update(&[0.3, -0.2, -0.3]); // s ← s − delta
        assert_eq!(pv.probs(), &[0.0, 0.7, 1.0]);
        let mut g = vec![1.0, 1.0, 1.0];
        pv.gate_gradient(&mut g);
        assert_eq!(g, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn saturated_entries_can_recover() {
        // p hits 0, then a negative-gradient (positive-delta-reversal)
        // update must be able to pull it back into (0,1).
        let mut pv = ProbVector::from_probs(vec![0.2]);
        pv.apply_update(&[0.5]); // 0.2 - 0.5 → clip(−0.3) = 0
        assert_eq!(pv.probs(), &[0.0]);
        pv.apply_update(&[-0.4]); // 0 + 0.4
        assert!((pv.probs()[0] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn mask_sampling_tracks_probabilities() {
        let pv = ProbVector::from_probs(vec![0.0, 1.0, 0.5]);
        let mut r = Xoshiro256pp::seed_from(1);
        let mut mask = Vec::new();
        let mut ones = [0usize; 3];
        for _ in 0..2000 {
            pv.sample_mask(&mut r, &mut mask);
            for (i, &b) in mask.iter().enumerate() {
                ones[i] += b as usize;
            }
        }
        assert_eq!(ones[0], 0);
        assert_eq!(ones[1], 2000);
        assert!((900..1100).contains(&ones[2]), "{ones:?}");
    }

    #[test]
    fn bitset_sampling_matches_bool_sampling() {
        let mut init = Xoshiro256pp::seed_from(9);
        let pv = ProbVector::init_uniform(300, &mut init);
        let mut r1 = Xoshiro256pp::seed_from(5);
        let mut r2 = Xoshiro256pp::seed_from(5);
        let mut mask = Vec::new();
        let mut bits = Vec::new();
        pv.sample_mask(&mut r1, &mut mask);
        pv.sample_mask_bits(&mut r2, &mut bits);
        assert_eq!(bits.len(), 300usize.div_ceil(64));
        for (j, &b) in mask.iter().enumerate() {
            assert_eq!((bits[j >> 6] >> (j & 63)) & 1 == 1, b, "bit {j}");
        }
    }

    #[test]
    fn discretize_rounds_at_half() {
        let pv = ProbVector::from_probs(vec![0.49, 0.5, 0.51]);
        assert_eq!(pv.discretize(), vec![false, true, true]);
    }

    #[test]
    fn dim_c_tau_counts_non_trivial() {
        let pv = ProbVector::from_probs(vec![0.0, 0.05, 0.5, 0.96, 1.0]);
        assert_eq!(pv.dim_c_tau(0.1), 1); // only 0.5
        assert_eq!(pv.dim_c_tau(0.01), 3); // 0.05, 0.5, 0.96
        assert_eq!(pv.dim_c_tau(0.0), 5);
    }

    #[test]
    fn beta_sampler_moments() {
        let mut r = Xoshiro256pp::seed_from(2);
        // Beta(2,2): mean 1/2, var 1/20.
        let xs: Vec<f64> = (0..20_000).map(|_| sample_beta(2.0, 2.0, &mut r)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 0.05).abs() < 0.005, "var={var}");
        // Beta(0.1, 0.1) concentrates near the endpoints.
        let xs: Vec<f64> = (0..5_000).map(|_| sample_beta(0.1, 0.1, &mut r)).collect();
        let extreme = xs.iter().filter(|&&x| !(0.1..=0.9).contains(&x)).count();
        assert!(extreme as f64 / xs.len() as f64 > 0.7);
    }
}
