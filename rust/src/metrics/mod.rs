//! Measurement: accuracy estimators, per-round records, and file sinks.
//!
//! The paper's headline metric is **mean sampled accuracy**: after
//! training, sample `S` masks `z ~ Bern(p*)`, evaluate each sampled
//! network, report mean ± std (§3.1 uses S = 100).  `expected accuracy`
//! evaluates the single network `w = Q p*`; `best mask` (Fig. 6) is the
//! max over the samples.

use std::io::Write;
use std::path::Path;

use crate::util::json::{self, Json};

/// Simple running scalar statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(it: I) -> Summary {
        let mut s = Summary::default();
        for x in it {
            s.push(x);
        }
        s
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// One federated round's record (Fig. 4 series).
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    pub mean_sampled_acc: f64,
    pub sampled_acc_std: f64,
    pub expected_acc: f64,
    pub train_loss: f64,
    pub uplink_bits: u64,
    pub downlink_bits: u64,
}

/// Accumulates round records and writes CSV/JSON artifacts under
/// `results/`.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunLog {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), rounds: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn last_acc(&self) -> Option<f64> {
        self.rounds.last().map(|r| r.mean_sampled_acc)
    }

    /// Best (max) mean-sampled accuracy over the run.
    pub fn best_acc(&self) -> Option<f64> {
        self.rounds.iter().map(|r| r.mean_sampled_acc).fold(None, |acc, x| {
            Some(acc.map_or(x, |a: f64| a.max(x)))
        })
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,mean_sampled_acc,sampled_acc_std,expected_acc,train_loss,uplink_bits,downlink_bits\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{},{}\n",
                r.round,
                r.mean_sampled_acc,
                r.sampled_acc_std,
                r.expected_acc,
                r.train_loss,
                r.uplink_bits,
                r.downlink_bits
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            (
                "rounds",
                json::arr(self.rounds.iter().map(|r| {
                    json::obj(vec![
                        ("round", json::num(r.round as f64)),
                        ("mean_sampled_acc", json::num(r.mean_sampled_acc)),
                        ("sampled_acc_std", json::num(r.sampled_acc_std)),
                        ("expected_acc", json::num(r.expected_acc)),
                        ("train_loss", json::num(r.train_loss)),
                        ("uplink_bits", json::num(r.uplink_bits as f64)),
                        ("downlink_bits", json::num(r.downlink_bits as f64)),
                    ])
                })),
            ),
        ])
    }

    /// Write `results/<name>.csv` and `.json`; creates the directory.
    pub fn save(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.name)))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{}.json", self.name)))?;
        f.write_all(self.to_json().to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_std() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138).abs() < 1e-3);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn summary_degenerate() {
        let mut s = Summary::default();
        assert_eq!(s.std(), 0.0);
        s.push(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn runlog_csv_and_best() {
        let mut log = RunLog::new("t");
        for (i, acc) in [(0usize, 0.5f64), (1, 0.9), (2, 0.8)] {
            log.push(RoundRecord {
                round: i,
                mean_sampled_acc: acc,
                sampled_acc_std: 0.01,
                expected_acc: acc,
                train_loss: 1.0 - acc,
                uplink_bits: 10,
                downlink_bits: 20,
            });
        }
        assert_eq!(log.best_acc(), Some(0.9));
        assert_eq!(log.last_acc(), Some(0.8));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.lines().nth(2).unwrap().starts_with("1,0.9"));
        let j = log.to_json();
        assert_eq!(j.get("rounds").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join(format!("zampling-metrics-{}", std::process::id()));
        let log = RunLog::new("x");
        log.save(&dir).unwrap();
        assert!(dir.join("x.csv").exists());
        assert!(dir.join("x.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
