//! Run-length coding for binary masks.
//!
//! The "other forms of compression when the binary vector has many 0s or
//! 1s" of [13] (paper footnote 4): runs are emitted as LEB128 varints,
//! first run counts 0s (a leading-1 mask starts with a zero-length run).
//! Only wins on highly-skewed masks; the ledger picks the cheaper of
//! RLE / arithmetic / raw per message, like a real wire format would.
#![cfg_attr(
    not(test),
    deny(clippy::cast_possible_truncation, clippy::cast_sign_loss, clippy::unwrap_used)
)]

use crate::bail;
use crate::util::error::Result;

/// Encode: varint run lengths, alternating value starting at 0.
pub fn encode(mask: &[bool]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut current = false;
    let mut run: u64 = 0;
    for &b in mask {
        if b == current {
            run += 1;
        } else {
            write_varint(&mut out, run);
            current = b;
            run = 1;
        }
    }
    write_varint(&mut out, run);
    out
}

/// Decode `n` bits.  Errors — never panics or spins — on malformed
/// input: a stream that ends before its runs cover `n` bits is
/// truncated, and a varint with more value bits than `u64` holds is
/// forged.  Bytes after the run covering bit `n − 1` are ignored (the
/// caller knows `n`; this mirrors how a wire consumer would stop).
pub fn decode(bytes: &[u8], n: usize) -> Result<Vec<bool>> {
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    let mut current = false;
    while out.len() < n {
        let (run, used) = read_varint(&bytes[pos..])?;
        pos += used;
        for _ in 0..run {
            if out.len() == n {
                break;
            }
            out.push(current);
        }
        current = !current;
    }
    Ok(out)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        #[allow(clippy::cast_possible_truncation)]
        // lint: allow(cast) — the low 7 bits are explicitly masked, so
        // the narrowing cannot truncate live value bits.
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read one LEB128 varint; returns `(value, bytes consumed)`.  Errors on
/// an empty/truncated stream (the old `(0, 0)` return here is what let
/// `decode` spin forever on truncated input) and on a continuation
/// sequence whose value bits overflow `u64` (the old unconditional
/// `<< shift` was a debug-build panic at shift ≥ 64).
fn read_varint(bytes: &[u8]) -> Result<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in bytes.iter().enumerate() {
        if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
            bail!("run-length varint overflows u64 at byte {i}");
        }
        v |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            return Ok((v, i + 1));
        }
        shift += 7;
    }
    bail!("truncated run-length varint ({} bytes left)", bytes.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn roundtrip() {
        let mut rng = Xoshiro256pp::seed_from(9);
        for q in [0.5f64, 0.02, 0.98] {
            for n in [0usize, 1, 100, 5000] {
                let mask: Vec<bool> = (0..n).map(|_| rng.bernoulli(q)).collect();
                assert_eq!(decode(&encode(&mask), n).expect("decode"), mask, "q={q} n={n}");
            }
        }
    }

    #[test]
    fn leading_one_handled() {
        let mask = vec![true, true, false, true];
        assert_eq!(decode(&encode(&mask), 4).expect("decode"), mask);
    }

    #[test]
    fn skewed_masks_compress_well() {
        let mut mask = vec![false; 10_000];
        for i in (0..10_000).step_by(500) {
            mask[i] = true;
        }
        let enc = encode(&mask);
        assert!(enc.len() < 10_000 / 64, "rle size {} should beat bitpack", enc.len());
    }

    #[test]
    fn dense_random_masks_do_not_explode() {
        let mut rng = Xoshiro256pp::seed_from(1);
        let mask: Vec<bool> = (0..10_000).map(|_| rng.bernoulli(0.5)).collect();
        // worst case ~1 byte per run, ~2 bits per run → ≤ ~1.1 bytes/bit… just sanity-bound it
        assert!(encode(&mask).len() < 10_000);
    }

    #[test]
    fn varint_boundaries() {
        let mut out = Vec::new();
        for v in [0u64, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            out.clear();
            write_varint(&mut out, v);
            let (got, used) = read_varint(&out).expect("varint");
            assert_eq!(got, v);
            assert_eq!(used, out.len());
        }
    }

    #[test]
    fn truncated_input_errors_instead_of_spinning() {
        // Regression (found by the PR 7 correctness gauntlet): a
        // truncated stream made `read_varint` return `(0, 0)`, so the
        // decode loop advanced by zero bytes, pushed zero bits, and
        // spun forever.
        let mask: Vec<bool> = (0..100).map(|i| i % 7 == 0).collect();
        let enc = encode(&mask);
        for cut in 0..enc.len() {
            assert!(decode(&enc[..cut], mask.len()).is_err(), "cut={cut} decoded");
        }
        assert!(decode(&[], 1).is_err());
        assert_eq!(decode(&[], 0).expect("empty"), Vec::<bool>::new());
    }

    #[test]
    fn overlong_varint_errors_instead_of_overflowing() {
        // Regression (same gauntlet): ten continuation bytes push the
        // varint shift past 63 — formerly a debug-build shift-overflow
        // panic, now a decode error.
        assert!(decode(&[0xff; 16], 5).is_err());
        // The largest encodable value still roundtrips exactly.
        let mut max = Vec::new();
        write_varint(&mut max, u64::MAX);
        assert_eq!(read_varint(&max).expect("u64::MAX"), (u64::MAX, max.len()));
    }

    #[test]
    fn trailing_bytes_after_bit_n_are_ignored() {
        // The caller supplies `n`; once the runs cover it, the decoder
        // stops — extra bytes are not an error (documented contract).
        let mask = vec![true, false, true];
        let mut enc = encode(&mask);
        enc.push(0x03);
        assert_eq!(decode(&enc, 3).expect("decode"), mask);
    }
}
