//! Run-length coding for binary masks.
//!
//! The "other forms of compression when the binary vector has many 0s or
//! 1s" of [13] (paper footnote 4): runs are emitted as LEB128 varints,
//! first run counts 0s (a leading-1 mask starts with a zero-length run).
//! Only wins on highly-skewed masks; the ledger picks the cheaper of
//! RLE / arithmetic / raw per message, like a real wire format would.

/// Encode: varint run lengths, alternating value starting at 0.
pub fn encode(mask: &[bool]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut current = false;
    let mut run: u64 = 0;
    for &b in mask {
        if b == current {
            run += 1;
        } else {
            write_varint(&mut out, run);
            current = b;
            run = 1;
        }
    }
    write_varint(&mut out, run);
    out
}

/// Decode `n` bits.
pub fn decode(bytes: &[u8], n: usize) -> Vec<bool> {
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    let mut current = false;
    while out.len() < n {
        let (run, used) = read_varint(&bytes[pos..]);
        pos += used;
        for _ in 0..run {
            if out.len() == n {
                break;
            }
            out.push(current);
        }
        current = !current;
    }
    out
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(bytes: &[u8]) -> (u64, usize) {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in bytes.iter().enumerate() {
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return (v, i + 1);
        }
        shift += 7;
    }
    (v, bytes.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn roundtrip() {
        let mut rng = Xoshiro256pp::seed_from(9);
        for q in [0.5f64, 0.02, 0.98] {
            for n in [0usize, 1, 100, 5000] {
                let mask: Vec<bool> = (0..n).map(|_| rng.bernoulli(q)).collect();
                assert_eq!(decode(&encode(&mask), n), mask, "q={q} n={n}");
            }
        }
    }

    #[test]
    fn leading_one_handled() {
        let mask = vec![true, true, false, true];
        assert_eq!(decode(&encode(&mask), 4), mask);
    }

    #[test]
    fn skewed_masks_compress_well() {
        let mut mask = vec![false; 10_000];
        for i in (0..10_000).step_by(500) {
            mask[i] = true;
        }
        let enc = encode(&mask);
        assert!(enc.len() < 10_000 / 64, "rle size {} should beat bitpack", enc.len());
    }

    #[test]
    fn dense_random_masks_do_not_explode() {
        let mut rng = Xoshiro256pp::seed_from(1);
        let mask: Vec<bool> = (0..10_000).map(|_| rng.bernoulli(0.5)).collect();
        // worst case ~1 byte per run, ~2 bits per run → ≤ ~1.1 bytes/bit… just sanity-bound it
        assert!(encode(&mask).len() < 10_000);
    }

    #[test]
    fn varint_boundaries() {
        let mut out = Vec::new();
        for v in [0u64, 127, 128, 16_383, 16_384, u32::MAX as u64] {
            out.clear();
            write_varint(&mut out, v);
            let (got, used) = read_varint(&out);
            assert_eq!(got, v);
            assert_eq!(used, out.len());
        }
    }
}
