//! Adaptive binary arithmetic coder.
//!
//! Reproduces the entropy-coded uplink of Isik et al. [13]: a Bernoulli
//! mask whose empirical 1-density is `q` costs ≈ `H(q)` bits per entry
//! (their reported 0.95 bits/param at q ≈ 0.4).  The model is a simple
//! adaptive Krichevsky–Trofimov estimator (counts initialized to 1/2),
//! so encoder and decoder need no side information.
//!
//! Implementation: 32-bit range coder with carry-free renormalization
//! (the classic CACM87 design, 16-bit probability precision).
//!
//! Because encoder and decoder walk the *same* `low`/`range` trajectory,
//! a valid stream is consumed byte-for-byte: the decoder reads exactly
//! `encode(mask).len()` bytes for `n` symbols.  [`decode`] exploits that
//! to reject malformed input — a truncated stream exhausts the bytes
//! mid-decode and an oversized one leaves trailing bytes, and both are
//! surfaced as errors instead of silently decoding garbage.
#![cfg_attr(
    not(test),
    deny(clippy::cast_possible_truncation, clippy::cast_sign_loss, clippy::unwrap_used)
)]

use crate::ensure;
use crate::util::error::Result;

const PRECISION: u32 = 16;
const TOP: u32 = 1 << 24;
const BOT: u32 = 1 << 16;

/// Adaptive bit model: P(1) = ones / total with KT smoothing.
#[derive(Clone, Debug)]
struct BitModel {
    ones: u32,
    total: u32,
}

impl BitModel {
    fn new() -> Self {
        // KT estimator: start at (1/2, 1) scaled by 2 → (1, 2).
        Self { ones: 1, total: 2 }
    }

    /// P(bit = 1) in [1, 2^16 - 1].
    fn p1(&self) -> u32 {
        let p = (u64::from(self.ones) << PRECISION) / u64::from(self.total);
        #[allow(clippy::cast_possible_truncation)]
        // lint: allow(cast) — `ones < total` always (KT counts start at
        // (1, 2) and update by (0|2, 2)), so the quotient is < 2^16.
        let p = p as u32;
        p.clamp(1, (1 << PRECISION) - 1)
    }

    fn update(&mut self, bit: bool) {
        self.ones += 2 * u32::from(bit);
        self.total += 2;
        if self.total >= 1 << 24 {
            // halve counts to stay adaptive on huge streams
            self.ones = (self.ones + 1) / 2;
            self.total = (self.total + 1) / 2;
        }
    }
}

/// Split point `r1 = ⌊range · p1 / 2^16⌋`, clamped into `[1, range-1]`
/// so both subranges stay non-empty — the shared encoder/decoder step
/// that keeps their `low`/`range` trajectories identical.
#[inline]
fn split(range: u32, p1: u32) -> u32 {
    #[allow(clippy::cast_possible_truncation)]
    // lint: allow(cast) — the u64 product is < 2^32 · 2^16, so after
    // the 16-bit shift the quotient fits u32 exactly.
    let r1 = ((u64::from(range) * u64::from(p1)) >> PRECISION) as u32;
    r1.max(1).min(range - 1)
}

/// Top byte of the 32-bit `low` register — the byte the carry-free
/// renormalization emits.
#[inline]
#[allow(clippy::cast_possible_truncation)]
fn top_byte(low: u32) -> u8 {
    // lint: allow(cast) — `>> 24` leaves exactly 8 live bits.
    (low >> 24) as u8
}

/// Encode a bit mask; returns the compressed bytes.
pub fn encode(mask: &[bool]) -> Vec<u8> {
    let mut model = BitModel::new();
    let mut low: u32 = 0;
    let mut range: u32 = u32::MAX;
    let mut out = Vec::with_capacity(mask.len() / 8 + 16);

    for &bit in mask {
        // Split the range: [low, low+r1) codes 1, [low+r1, low+range) codes 0.
        let r1 = split(range, model.p1());
        if bit {
            range = r1;
        } else {
            low = low.wrapping_add(r1);
            range -= r1;
        }
        model.update(bit);
        // Renormalize (carry-free: flush when top byte settled or range small).
        while (low ^ low.wrapping_add(range)) < TOP || {
            if range < BOT {
                range = low.wrapping_neg() & (BOT - 1);
                true
            } else {
                false
            }
        } {
            out.push(top_byte(low));
            low <<= 8;
            range <<= 8;
        }
    }
    for _ in 0..4 {
        out.push(top_byte(low));
        low <<= 8;
    }
    out
}

/// Pull the next stream byte, erroring (instead of substituting zeros)
/// once the input is exhausted — the truncation guard.
#[inline]
fn next_byte(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    match bytes.get(*pos) {
        Some(&b) => {
            *pos += 1;
            Ok(u32::from(b))
        }
        None => Err(crate::anyhow!(
            "arithmetic stream exhausted after {} bytes (truncated payload)",
            bytes.len()
        )),
    }
}

/// Decode `n` bits from `bytes`.
///
/// Errors on truncated input (stream exhausts before `n` symbols are
/// recovered) and on trailing garbage (bytes left over after the `n`-th
/// symbol) — a valid stream is consumed exactly.
pub fn decode(bytes: &[u8], n: usize) -> Result<Vec<bool>> {
    let mut model = BitModel::new();
    let mut low: u32 = 0;
    let mut range: u32 = u32::MAX;
    let mut code: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..4 {
        code = (code << 8) | next_byte(bytes, &mut pos)?;
    }

    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let r1 = split(range, model.p1());
        let bit = code.wrapping_sub(low) < r1;
        if bit {
            range = r1;
        } else {
            low = low.wrapping_add(r1);
            range -= r1;
        }
        model.update(bit);
        out.push(bit);
        while (low ^ low.wrapping_add(range)) < TOP || {
            if range < BOT {
                range = low.wrapping_neg() & (BOT - 1);
                true
            } else {
                false
            }
        } {
            code = (code << 8) | next_byte(bytes, &mut pos)?;
            low <<= 8;
            range <<= 8;
        }
    }
    ensure!(
        pos == bytes.len(),
        "arithmetic stream has {} trailing bytes after {n} symbols",
        bytes.len() - pos
    );
    Ok(out)
}

/// Empirical bits-per-entry of an encoded mask.
pub fn bits_per_entry(mask: &[bool]) -> f64 {
    if mask.is_empty() {
        return 0.0;
    }
    encode(mask).len() as f64 * 8.0 / mask.len() as f64
}

/// Binary entropy H(q) in bits.
pub fn binary_entropy(q: f64) -> f64 {
    if q <= 0.0 || q >= 1.0 {
        return 0.0;
    }
    -q * q.log2() - (1.0 - q) * (1.0 - q).log2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn bern_mask(n: usize, q: f64, seed: u64) -> Vec<bool> {
        let mut rng = Xoshiro256pp::seed_from(seed);
        (0..n).map(|_| rng.bernoulli(q)).collect()
    }

    #[test]
    fn roundtrip_random_masks() {
        for (q, seed) in [(0.5, 1u64), (0.1, 2), (0.9, 3), (0.01, 4)] {
            for n in [1usize, 7, 64, 1000, 10_000] {
                if cfg!(miri) && n > 1000 {
                    continue; // interpreted execution: keep the Miri lane fast
                }
                let mask = bern_mask(n, q, seed);
                let enc = encode(&mask);
                assert_eq!(decode(&enc, n).unwrap(), mask, "q={q} n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_degenerate_masks() {
        for mask in [vec![true; 500], vec![false; 500], vec![]] {
            let enc = encode(&mask);
            assert_eq!(decode(&enc, mask.len()).unwrap(), mask);
        }
    }

    #[test]
    fn valid_streams_are_consumed_exactly() {
        // The decoder mirrors the encoder's renormalization schedule, so
        // every byte of a valid stream is read — the invariant the
        // truncation/trailing checks rely on.
        for n in [0usize, 1, 64, 1000, 10_000] {
            if cfg!(miri) && n > 1000 {
                continue; // interpreted execution: keep the Miri lane fast
            }
            let mask = bern_mask(n, 0.3, n as u64 + 1);
            let enc = encode(&mask);
            assert_eq!(decode(&enc, n).unwrap(), mask, "n={n}");
        }
    }

    #[test]
    fn truncated_stream_is_an_error_not_garbage() {
        let n = if cfg!(miri) { 500 } else { 5000 };
        let mask = bern_mask(n, 0.25, 11);
        let enc = encode(&mask);
        // Any proper prefix must error: the decoder needs every byte.
        for cut in [0usize, 1, 3, enc.len() / 2, enc.len() - 1] {
            assert!(decode(&enc[..cut], mask.len()).is_err(), "cut={cut} decoded");
        }
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mask = bern_mask(1000, 0.4, 12);
        let mut enc = encode(&mask);
        enc.push(0xAA);
        assert!(decode(&enc, mask.len()).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore = "200k-symbol statistical check is far too slow interpreted")]
    fn rate_approaches_entropy() {
        // On a large iid Bernoulli(q) stream the adaptive coder should be
        // within ~5% + header of H(q) bits/entry.
        for q in [0.5f64, 0.25, 0.1, 0.05] {
            let mask = bern_mask(200_000, q, 42);
            let emp_q = mask.iter().filter(|&&b| b).count() as f64 / mask.len() as f64;
            let rate = bits_per_entry(&mask);
            let h = binary_entropy(emp_q);
            assert!(
                rate < h * 1.05 + 0.01,
                "q={q}: rate={rate:.4} vs H={h:.4}"
            );
            assert!(rate > h * 0.95, "q={q}: rate={rate:.4} suspiciously < H={h:.4}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "266k-symbol statistical check is far too slow interpreted")]
    fn isik_bitrate_scenario() {
        // FedPM-like masks (p clusters near ~0.4 after training) compress
        // to < 1 bit/param — the paper's "(*) bit-rate about 0.95".
        let mask = bern_mask(266_610, 0.4, 7);
        let rate = bits_per_entry(&mask);
        assert!(rate < 1.0, "rate={rate}");
        assert!(rate > 0.9, "rate={rate}");
    }
}
