//! Per-round communication accounting → the savings factors of Table 1.
//!
//! Savings are measured exactly as the paper does: *"by what factor the
//! communication cost decreases per round in comparison to the naive
//! protocol that sends all m parameters as floats"* — i.e. naive is
//! `32·m` bits in each direction, per client.

use crate::util::error::Result;
use crate::{anyhow, bail};

/// One round's measured traffic (bits, per direction, totals over clients).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundCost {
    /// Server → clients total.
    pub downlink_bits: u64,
    /// Clients → server total.
    pub uplink_bits: u64,
    /// Clients whose masks actually arrived (what the per-client
    /// averages divide by).
    pub clients: u32,
    /// Clients selected for the round (≥ `clients` once workers drop
    /// out; equals it under full participation with no failures).
    pub participants: u32,
    /// Selected clients whose mask never arrived (disconnect, deadline).
    pub dropped: u32,
    /// Round wall-clock in nanoseconds (broadcast through aggregation),
    /// 0 when the recorder did not measure it.  Turns the bits columns
    /// into bandwidth: see [`CommLedger::round_throughput_bps`].
    pub wall_ns: u64,
}

/// One shard's slice of a round under a sharded (multi-leader)
/// transport: what its leader shipped to / collected from its own
/// workers, plus the cost of the `ShardVotes` merge frame it sent to
/// the root.  Summing `uplink_bits`/`downlink_bits` across a round's
/// shards reproduces the round's [`RoundCost`] columns; `merge_bits` is
/// the extra root-tree traffic the sharded topology pays (~`32n` bits
/// per shard per round, independent of shard size).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardCost {
    /// Shard index (0-based, matching `ShardPlan::range`).
    pub shard: u32,
    /// Mask-frame bits this shard's leader collected from its workers.
    pub uplink_bits: u64,
    /// Broadcast bits this shard's leader delivered to its workers.
    pub downlink_bits: u64,
    /// Encoded `ShardVotes` merge-frame bits shipped shard → root
    /// (0 for a failed shard whose frame never arrived).
    pub merge_bits: u64,
    /// Masks this shard contributed to the merge.
    pub received: u32,
    /// This shard's participants whose mask never arrived.
    pub dropped: u32,
}

/// One directed gossip edge's billed traffic for a round — the
/// decentralized counterpart of [`ShardCost`].  The gossip protocol
/// ships one `n`-bit mask per live directed edge per round
/// (`Topology::num_messages` of them at full participation), so a
/// round's edge rows always sum to its [`RoundCost::uplink_bits`];
/// there is no downlink column because gossip has no broadcast.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeCost {
    /// The sending node.
    pub from: u32,
    /// The receiving node.
    pub to: u32,
    /// Bits shipped over this edge this round (the raw `n`-bit mask).
    pub bits: u64,
}

/// Accumulated ledger over a training run.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    /// One entry per round.
    pub rounds: Vec<RoundCost>,
    /// Per-round per-shard breakdown, 1:1 with `rounds` when recorded by
    /// the round engine (inner vectors are empty for single-leader
    /// transports).  Recorders that bypass the engine (baselines) leave
    /// the table empty.
    pub shard_rounds: Vec<Vec<ShardCost>>,
    /// Per-round per-directed-edge breakdown from gossip transports,
    /// 1:1 with `rounds` when recorded by the round engine (inner
    /// vectors are empty for centralized transports).
    pub edge_rounds: Vec<Vec<EdgeCost>>,
}

/// The Table 1 row: per-round per-client savings factors vs naive.
#[derive(Clone, Copy, Debug)]
pub struct SavingsReport {
    /// Naive bits per direction per client per round (32·m).
    pub naive_bits: u64,
    /// Mean measured uplink bits per client per round.
    pub avg_uplink_bits_per_client: f64,
    /// Mean measured downlink bits per client per round.
    pub avg_downlink_bits_per_client: f64,
    /// `client savings` column: naive / uplink.
    pub client_savings: f64,
    /// `server savings` column: naive / downlink.
    pub server_savings: f64,
}

impl CommLedger {
    /// Append one round's totals.
    pub fn record(&mut self, cost: RoundCost) {
        self.rounds.push(cost);
    }

    /// Append one round's per-shard breakdown (empty for single-leader
    /// transports) — the engine calls this right after [`Self::record`]
    /// so `shard_rounds` stays 1:1 with `rounds`.
    pub fn record_shard_costs(&mut self, costs: Vec<ShardCost>) {
        self.shard_rounds.push(costs);
    }

    /// Append one round's per-directed-edge breakdown (empty for
    /// centralized transports) — the engine calls this right after
    /// [`Self::record`] so `edge_rounds` stays 1:1 with `rounds`.
    pub fn record_edge_costs(&mut self, costs: Vec<EdgeCost>) {
        self.edge_rounds.push(costs);
    }

    /// Total bits shipped over gossip edges across the run (0 unless a
    /// gossip transport ran).
    pub fn total_edge_bits(&self) -> u64 {
        self.edge_rounds.iter().flatten().map(|e| e.bits).sum()
    }

    /// Per-node gossip totals over the run: `(sent, received)` bits per
    /// node id, summed over its out- and in-edges.  `nodes` is the
    /// topology's node count, so isolated or never-selected trailing
    /// nodes still get their (0, 0) row instead of being silently
    /// truncated; the result grows past `nodes` only if the table
    /// somehow names a larger id.
    pub fn node_edge_totals(&self, nodes: usize) -> Vec<(u64, u64)> {
        let nodes = self
            .edge_rounds
            .iter()
            .flatten()
            .map(|e| e.from.max(e.to) as usize + 1)
            .max()
            .unwrap_or(0)
            .max(nodes);
        let mut totals = vec![(0u64, 0u64); nodes];
        for e in self.edge_rounds.iter().flatten() {
            totals[e.from as usize].0 += e.bits;
            totals[e.to as usize].1 += e.bits;
        }
        totals
    }

    /// Total shard→root merge-frame bits over the run (0 unless a
    /// sharded transport ran).
    pub fn total_merge_bits(&self) -> u64 {
        self.shard_rounds.iter().flatten().map(|s| s.merge_bits).sum()
    }

    /// Per-shard totals over the run: `(uplink, downlink, merge,
    /// received, dropped)` summed across rounds, indexed by shard id.
    /// Empty unless a sharded transport ran.
    pub fn shard_totals(&self) -> Vec<(u64, u64, u64, u64, u64)> {
        let shards = self
            .shard_rounds
            .iter()
            .flatten()
            .map(|c| c.shard as usize + 1)
            .max()
            .unwrap_or(0);
        let mut totals = vec![(0u64, 0u64, 0u64, 0u64, 0u64); shards];
        for c in self.shard_rounds.iter().flatten() {
            let t = &mut totals[c.shard as usize];
            t.0 += c.uplink_bits;
            t.1 += c.downlink_bits;
            t.2 += c.merge_bits;
            t.3 += c.received as u64;
            t.4 += c.dropped as u64;
        }
        totals
    }

    /// Convenience: record a round where every one of `clients` clients
    /// received `down_bytes` and sent `up_bytes`.
    pub fn record_symmetric(&mut self, clients: u32, down_bytes: usize, up_bytes: usize) {
        self.record(RoundCost {
            downlink_bits: down_bytes as u64 * 8 * clients as u64,
            uplink_bits: up_bytes as u64 * 8 * clients as u64,
            clients,
            participants: clients,
            dropped: 0,
            wall_ns: 0,
        });
    }

    /// Total clients dropped (deadline or disconnect) over the run.
    pub fn total_dropped(&self) -> u64 {
        self.rounds.iter().map(|r| r.dropped as u64).sum()
    }

    /// Total clients→server bits over the run.
    pub fn total_uplink_bits(&self) -> u64 {
        self.rounds.iter().map(|r| r.uplink_bits).sum()
    }

    /// Total server→clients bits over the run.
    pub fn total_downlink_bits(&self) -> u64 {
        self.rounds.iter().map(|r| r.downlink_bits).sum()
    }

    /// Total measured wall-clock over the run (rounds with `wall_ns = 0`
    /// contribute nothing).
    pub fn total_wall(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.rounds.iter().map(|r| r.wall_ns).sum())
    }

    /// Round `i`'s throughput in bits/sec over both directions, or
    /// `None` when the round exists but its wall clock was not measured
    /// (or it is out of range).  Bits/round says what a round costs;
    /// this says how fast the transport actually moved it.
    pub fn round_throughput_bps(&self, i: usize) -> Option<f64> {
        let r = self.rounds.get(i)?;
        if r.wall_ns == 0 {
            return None;
        }
        Some((r.uplink_bits + r.downlink_bits) as f64 / (r.wall_ns as f64 / 1e9))
    }

    /// Cumulative throughput in bits/sec across every *measured* round
    /// (unmeasured rounds contribute neither bits nor time, so mixing
    /// measured and unmeasured recorders cannot skew the rate).  `None`
    /// when no round carries a wall clock.
    pub fn cumulative_throughput_bps(&self) -> Option<f64> {
        let (mut bits, mut ns) = (0u64, 0u64);
        for r in &self.rounds {
            if r.wall_ns > 0 {
                bits += r.uplink_bits + r.downlink_bits;
                ns += r.wall_ns;
            }
        }
        (ns > 0).then(|| bits as f64 / (ns as f64 / 1e9))
    }

    /// Savings vs the naive protocol for a model with `m` parameters.
    ///
    /// An empty ledger (or one whose every round saw zero clients)
    /// reports a savings factor of exactly 1.0 — "we saved nothing", not
    /// the `naive_bits`× the old `avg.max(1.0)` clamp fabricated from a
    /// 0/1 division.
    pub fn savings(&self, m: usize) -> SavingsReport {
        let naive_bits = 32u64 * m as u64;
        let mut up_per_client = 0.0f64;
        let mut down_per_client = 0.0f64;
        let mut n = 0usize;
        for r in &self.rounds {
            if r.clients == 0 {
                continue;
            }
            up_per_client += r.uplink_bits as f64 / r.clients as f64;
            down_per_client += r.downlink_bits as f64 / r.clients as f64;
            n += 1;
        }
        if n == 0 {
            return SavingsReport {
                naive_bits,
                avg_uplink_bits_per_client: 0.0,
                avg_downlink_bits_per_client: 0.0,
                client_savings: 1.0,
                server_savings: 1.0,
            };
        }
        let rounds = n as f64;
        let avg_up = up_per_client / rounds;
        let avg_down = down_per_client / rounds;
        SavingsReport {
            naive_bits,
            avg_uplink_bits_per_client: avg_up,
            avg_downlink_bits_per_client: avg_down,
            client_savings: naive_bits as f64 / avg_up.max(1.0),
            server_savings: naive_bits as f64 / avg_down.max(1.0),
        }
    }

    /// The `# rounds` CSV section alone — the piece of [`Self::to_csv`]
    /// that stays byte-identical between a wire shard tree and its
    /// in-process twin at **any** tree depth (the shard table aggregates
    /// differently at depth ≥ 3, where the root sees one row per direct
    /// child's whole subtree).
    pub fn rounds_csv(&self) -> String {
        let mut out = String::from("# rounds\nround,downlink_bits,uplink_bits,clients,participants,dropped\n");
        for (i, r) in self.rounds.iter().enumerate() {
            out.push_str(&format!(
                "{i},{},{},{},{},{}\n",
                r.downlink_bits, r.uplink_bits, r.clients, r.participants, r.dropped
            ));
        }
        out
    }

    /// Serialize the whole ledger — every column of every table,
    /// **including** the measured `wall_ns` the CSV deliberately omits —
    /// as the flat little-endian layout the checkpoint embeds.  Unlike
    /// [`Self::to_csv`] this is a faithful round-trip format: a resumed
    /// leader must recompute the *same* totals (edge/shard/throughput)
    /// the pre-kill leader would have, so no column may be dropped.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.rounds.len() * 36);
        out.extend_from_slice(&(self.rounds.len() as u32).to_le_bytes());
        for r in &self.rounds {
            out.extend_from_slice(&r.downlink_bits.to_le_bytes());
            out.extend_from_slice(&r.uplink_bits.to_le_bytes());
            out.extend_from_slice(&r.clients.to_le_bytes());
            out.extend_from_slice(&r.participants.to_le_bytes());
            out.extend_from_slice(&r.dropped.to_le_bytes());
            out.extend_from_slice(&r.wall_ns.to_le_bytes());
        }
        out.extend_from_slice(&(self.shard_rounds.len() as u32).to_le_bytes());
        for costs in &self.shard_rounds {
            out.extend_from_slice(&(costs.len() as u32).to_le_bytes());
            for c in costs {
                out.extend_from_slice(&c.shard.to_le_bytes());
                out.extend_from_slice(&c.uplink_bits.to_le_bytes());
                out.extend_from_slice(&c.downlink_bits.to_le_bytes());
                out.extend_from_slice(&c.merge_bits.to_le_bytes());
                out.extend_from_slice(&c.received.to_le_bytes());
                out.extend_from_slice(&c.dropped.to_le_bytes());
            }
        }
        out.extend_from_slice(&(self.edge_rounds.len() as u32).to_le_bytes());
        for costs in &self.edge_rounds {
            out.extend_from_slice(&(costs.len() as u32).to_le_bytes());
            for c in costs {
                out.extend_from_slice(&c.from.to_le_bytes());
                out.extend_from_slice(&c.to.to_le_bytes());
                out.extend_from_slice(&c.bits.to_le_bytes());
            }
        }
        out
    }

    /// Decode a ledger serialized by [`Self::to_bytes`].  Hardened the
    /// same way the wire decoders are: every count is bounds-checked
    /// against the remaining input *before* allocation (a corrupted
    /// length field must not become a memory bomb), truncated input is
    /// an error (never a panic), and trailing garbage is rejected so a
    /// partially-overwritten checkpoint cannot restore silently.
    pub fn from_bytes(buf: &[u8]) -> Result<CommLedger> {
        let mut r = LedgerReader { buf, pos: 0 };
        let nrounds = r.count("rounds", 36)?;
        let mut rounds = Vec::with_capacity(nrounds);
        for _ in 0..nrounds {
            rounds.push(RoundCost {
                downlink_bits: r.u64()?,
                uplink_bits: r.u64()?,
                clients: r.u32()?,
                participants: r.u32()?,
                dropped: r.u32()?,
                wall_ns: r.u64()?,
            });
        }
        let outer = r.count("shard rounds", 4)?;
        let mut shard_rounds = Vec::with_capacity(outer);
        for _ in 0..outer {
            let inner = r.count("shard costs", 36)?;
            let mut costs = Vec::with_capacity(inner);
            for _ in 0..inner {
                costs.push(ShardCost {
                    shard: r.u32()?,
                    uplink_bits: r.u64()?,
                    downlink_bits: r.u64()?,
                    merge_bits: r.u64()?,
                    received: r.u32()?,
                    dropped: r.u32()?,
                });
            }
            shard_rounds.push(costs);
        }
        let outer = r.count("edge rounds", 4)?;
        let mut edge_rounds = Vec::with_capacity(outer);
        for _ in 0..outer {
            let inner = r.count("edge costs", 16)?;
            let mut costs = Vec::with_capacity(inner);
            for _ in 0..inner {
                costs.push(EdgeCost { from: r.u32()?, to: r.u32()?, bits: r.u64()? });
            }
            edge_rounds.push(costs);
        }
        if r.pos != buf.len() {
            bail!("{} trailing bytes after the ledger tables", buf.len() - r.pos);
        }
        Ok(CommLedger { rounds, shard_rounds, edge_rounds })
    }

    /// Serialize the whole ledger as sectioned CSV (`# rounds`,
    /// `# shards`, `# edges`; the latter two omitted when empty) — the
    /// `ledger.csv` artifact every federated CLI run writes, and the
    /// byte-comparison format `repro testnet` diffs against the
    /// in-process twin.
    ///
    /// `wall_ns` is deliberately excluded: it is the one measured (not
    /// derived) column, so including it would break byte-identicality
    /// between a wire run and its simulator twin.
    pub fn to_csv(&self) -> String {
        let mut out = self.rounds_csv();
        if self.shard_rounds.iter().any(|v| !v.is_empty()) {
            out.push_str("# shards\nround,shard,uplink_bits,downlink_bits,merge_bits,received,dropped\n");
            for (i, costs) in self.shard_rounds.iter().enumerate() {
                for c in costs {
                    out.push_str(&format!(
                        "{i},{},{},{},{},{},{}\n",
                        c.shard, c.uplink_bits, c.downlink_bits, c.merge_bits, c.received, c.dropped
                    ));
                }
            }
        }
        if self.edge_rounds.iter().any(|v| !v.is_empty()) {
            out.push_str("# edges\nround,from,to,bits\n");
            for (i, costs) in self.edge_rounds.iter().enumerate() {
                for c in costs {
                    out.push_str(&format!("{i},{},{},{}\n", c.from, c.to, c.bits));
                }
            }
        }
        out
    }
}

/// Bounds-checked little-endian reader over a serialized ledger.
struct LedgerReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl LedgerReader<'_> {
    fn u32(&mut self) -> Result<u32> {
        match self.buf.get(self.pos..self.pos + 4) {
            Some(b) => {
                let mut a = [0u8; 4];
                a.copy_from_slice(b);
                self.pos += 4;
                Ok(u32::from_le_bytes(a))
            }
            None => Err(anyhow!("truncated ledger u32 at offset {}", self.pos)),
        }
    }

    fn u64(&mut self) -> Result<u64> {
        match self.buf.get(self.pos..self.pos + 8) {
            Some(b) => {
                let mut a = [0u8; 8];
                a.copy_from_slice(b);
                self.pos += 8;
                Ok(u64::from_le_bytes(a))
            }
            None => Err(anyhow!("truncated ledger u64 at offset {}", self.pos)),
        }
    }

    /// Read a table length and check the remaining input can actually
    /// hold `count` entries of at least `min_entry_bytes` each — the
    /// pre-allocation guard against corrupted length fields.
    fn count(&mut self, what: &str, min_entry_bytes: usize) -> Result<usize> {
        let count = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        if count.saturating_mul(min_entry_bytes) > remaining {
            bail!("ledger {what} count {count} exceeds the {remaining} bytes remaining");
        }
        Ok(count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zampling_table1_factors() {
        // MnistFc m = 266,610.  m/n = 32 → n = 8331.
        // Uplink: n bits (mask).  Downlink: 32·n bits (p as floats).
        let m = 266_610usize;
        let n = m / 32;
        let mut ledger = CommLedger::default();
        for _ in 0..100 {
            ledger.record(RoundCost {
                uplink_bits: n as u64 * 10,
                downlink_bits: 32 * n as u64 * 10,
                clients: 10,
                participants: 10,
                dropped: 0,
                wall_ns: 0,
            });
        }
        let rep = ledger.savings(m);
        // client savings = 32m / n = 32 * 32 = 1024 (paper Table 1: 1024)
        assert!((rep.client_savings - 1024.0).abs() / 1024.0 < 0.01, "{rep:?}");
        // server savings = 32m / 32n = m/n = 32 (paper Table 1: 32)
        assert!((rep.server_savings - 32.0).abs() / 32.0 < 0.01, "{rep:?}");
    }

    #[test]
    fn naive_protocol_has_savings_one() {
        let m = 1000usize;
        let mut ledger = CommLedger::default();
        ledger.record_symmetric(4, m * 4, m * 4);
        let rep = ledger.savings(m);
        assert!((rep.client_savings - 1.0).abs() < 1e-9);
        assert!((rep.server_savings - 1.0).abs() < 1e-9);
    }

    #[test]
    fn totals_accumulate() {
        let mut ledger = CommLedger::default();
        ledger.record_symmetric(2, 10, 20);
        ledger.record_symmetric(2, 30, 40);
        assert_eq!(ledger.total_downlink_bits(), (10 + 30) * 8 * 2);
        assert_eq!(ledger.total_uplink_bits(), (20 + 40) * 8 * 2);
    }

    #[test]
    fn empty_ledger_reports_no_savings() {
        // The seed reported `naive_bits`× (3200× here) from 0/1 division
        // + clamp; an empty ledger saved exactly nothing.
        let rep = CommLedger::default().savings(100);
        assert_eq!(rep.naive_bits, 3200);
        assert_eq!(rep.client_savings, 1.0);
        assert_eq!(rep.server_savings, 1.0);
        assert_eq!(rep.avg_uplink_bits_per_client, 0.0);
        assert_eq!(rep.avg_downlink_bits_per_client, 0.0);
    }

    #[test]
    fn csv_sections_cover_rounds_shards_and_edges_without_wall() {
        let mut ledger = CommLedger::default();
        ledger.record(RoundCost {
            downlink_bits: 100,
            uplink_bits: 50,
            clients: 2,
            participants: 3,
            dropped: 1,
            // excluded from the CSV: measured, so never byte-identical
            // between a wire run and its simulator twin
            wall_ns: 123_456,
        });
        ledger.record_shard_costs(vec![ShardCost {
            shard: 1,
            uplink_bits: 50,
            downlink_bits: 100,
            merge_bits: 9,
            received: 2,
            dropped: 1,
        }]);
        ledger.record_edge_costs(vec![EdgeCost { from: 0, to: 1, bits: 7 }]);
        let csv = ledger.to_csv();
        assert!(csv.starts_with("# rounds\n"));
        assert!(csv.contains("0,100,50,2,3,1\n"), "{csv}");
        assert!(csv.contains("# shards\n"));
        assert!(csv.contains("0,1,50,100,9,2,1\n"), "{csv}");
        assert!(csv.contains("# edges\n"));
        assert!(csv.contains("0,0,1,7\n"), "{csv}");
        assert!(!csv.contains("123456"), "wall_ns leaked into the CSV:\n{csv}");
        // the rounds section alone is a prefix of the full document
        assert!(csv.starts_with(&ledger.rounds_csv()));

        // single-leader, non-gossip ledgers emit only the rounds section
        let mut plain = CommLedger::default();
        plain.record(RoundCost::default());
        plain.record_shard_costs(Vec::new());
        plain.record_edge_costs(Vec::new());
        let csv = plain.to_csv();
        assert!(!csv.contains("# shards"));
        assert!(!csv.contains("# edges"));
    }

    #[test]
    fn shard_table_totals_accumulate_per_shard() {
        let shard0 = ShardCost {
            shard: 0,
            uplink_bits: 10,
            downlink_bits: 20,
            merge_bits: 5,
            received: 2,
            dropped: 0,
        };
        let shard1 = ShardCost {
            shard: 1,
            uplink_bits: 1,
            downlink_bits: 2,
            merge_bits: 5,
            received: 1,
            dropped: 1,
        };
        let mut ledger = CommLedger::default();
        ledger.record_shard_costs(vec![shard0, shard1]);
        ledger.record_shard_costs(vec![
            shard0,
            // shard 1 fully failed this round: no merge frame arrived
            ShardCost { shard: 1, dropped: 2, ..Default::default() },
        ]);
        assert_eq!(ledger.total_merge_bits(), 15);
        let totals = ledger.shard_totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0], (20, 40, 10, 4, 0));
        assert_eq!(totals[1], (1, 2, 5, 1, 3));
        // single-leader ledgers report an empty table
        assert!(CommLedger::default().shard_totals().is_empty());
    }

    #[test]
    fn edge_table_totals_accumulate_per_node() {
        let mut ledger = CommLedger::default();
        ledger.record_edge_costs(vec![
            EdgeCost { from: 0, to: 1, bits: 10 },
            EdgeCost { from: 1, to: 0, bits: 10 },
            EdgeCost { from: 2, to: 0, bits: 10 },
        ]);
        // next round: node 2 died, only the 0↔1 edges carried traffic
        ledger.record_edge_costs(vec![
            EdgeCost { from: 0, to: 1, bits: 10 },
            EdgeCost { from: 1, to: 0, bits: 10 },
        ]);
        assert_eq!(ledger.total_edge_bits(), 50);
        let totals = ledger.node_edge_totals(3);
        assert_eq!(totals.len(), 3);
        assert_eq!(totals[0], (20, 30));
        assert_eq!(totals[1], (20, 20));
        assert_eq!(totals[2], (10, 0));
        // an isolated trailing node still gets its zero row
        let totals = ledger.node_edge_totals(5);
        assert_eq!(totals.len(), 5);
        assert_eq!(totals[4], (0, 0));
        // centralized ledgers report an empty table
        assert!(CommLedger::default().node_edge_totals(0).is_empty());
        assert_eq!(CommLedger::default().total_edge_bits(), 0);
    }

    /// A ledger with every table populated — shard rows, edge rows, and
    /// measured wall clocks — the worst case for restore asymmetry.
    fn full_ledger() -> CommLedger {
        let mut ledger = CommLedger::default();
        ledger.record(RoundCost {
            downlink_bits: 640,
            uplink_bits: 320,
            clients: 4,
            participants: 5,
            dropped: 1,
            wall_ns: 250_000_000,
        });
        ledger.record_shard_costs(vec![
            ShardCost {
                shard: 0,
                uplink_bits: 200,
                downlink_bits: 400,
                merge_bits: 64,
                received: 2,
                dropped: 0,
            },
            ShardCost {
                shard: 1,
                uplink_bits: 120,
                downlink_bits: 240,
                merge_bits: 64,
                received: 2,
                dropped: 1,
            },
        ]);
        ledger.record_edge_costs(vec![
            EdgeCost { from: 0, to: 1, bits: 80 },
            EdgeCost { from: 1, to: 0, bits: 80 },
        ]);
        ledger.record(RoundCost {
            downlink_bits: 640,
            uplink_bits: 320,
            clients: 5,
            participants: 5,
            dropped: 0,
            wall_ns: 750_000_000,
        });
        ledger.record_shard_costs(Vec::new());
        ledger.record_edge_costs(vec![EdgeCost { from: 2, to: 0, bits: 80 }]);
        ledger
    }

    #[test]
    fn restored_ledger_recomputes_identical_totals() {
        // The restore-asymmetry regression: every derived total — the
        // shard/edge tables, throughput (which needs the wall clocks the
        // CSV drops), savings, drop counts — must come out of a
        // round-tripped ledger exactly as it would have pre-kill.
        let original = full_ledger();
        let restored = CommLedger::from_bytes(&original.to_bytes()).unwrap();
        assert_eq!(restored.rounds.len(), original.rounds.len());
        assert_eq!(restored.total_uplink_bits(), original.total_uplink_bits());
        assert_eq!(restored.total_downlink_bits(), original.total_downlink_bits());
        assert_eq!(restored.total_dropped(), original.total_dropped());
        assert_eq!(restored.shard_totals(), original.shard_totals());
        assert_eq!(restored.total_merge_bits(), original.total_merge_bits());
        assert_eq!(restored.node_edge_totals(3), original.node_edge_totals(3));
        assert_eq!(restored.total_edge_bits(), original.total_edge_bits());
        assert_eq!(restored.total_wall(), original.total_wall());
        assert_eq!(restored.round_throughput_bps(0), original.round_throughput_bps(0));
        assert_eq!(restored.cumulative_throughput_bps(), original.cumulative_throughput_bps());
        let rep_a = original.savings(100);
        let rep_b = restored.savings(100);
        assert_eq!(rep_a.client_savings, rep_b.client_savings);
        assert_eq!(rep_a.server_savings, rep_b.server_savings);
        // and the CSV artifact a resumed run writes is byte-identical
        assert_eq!(restored.to_csv(), original.to_csv());
        // double round-trip is a fixed point
        assert_eq!(restored.to_bytes(), original.to_bytes());
    }

    #[test]
    fn empty_ledger_roundtrips() {
        let restored = CommLedger::from_bytes(&CommLedger::default().to_bytes()).unwrap();
        assert!(restored.rounds.is_empty());
        assert!(restored.shard_rounds.is_empty());
        assert!(restored.edge_rounds.is_empty());
    }

    #[test]
    fn ledger_decode_rejects_corrupt_input_without_panicking() {
        let bytes = full_ledger().to_bytes();
        // every truncation point errors, never panics
        for cut in 0..bytes.len() {
            assert!(CommLedger::from_bytes(&bytes[..cut]).is_err(), "cut={cut} decoded");
        }
        // trailing garbage is rejected (a partially-overwritten file)
        let mut long = bytes.clone();
        long.push(0);
        assert!(CommLedger::from_bytes(&long).is_err());
        // a corrupted round count cannot become a memory bomb
        let mut forged = bytes.clone();
        forged[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(CommLedger::from_bytes(&forged).is_err());
    }

    #[test]
    fn zero_client_rounds_do_not_fabricate_savings() {
        // Rounds where every participant dropped contribute nothing.
        let mut ledger = CommLedger::default();
        ledger.record(RoundCost {
            downlink_bits: 640,
            uplink_bits: 0,
            clients: 0,
            participants: 2,
            dropped: 2,
            wall_ns: 0,
        });
        let rep = ledger.savings(100);
        assert_eq!(rep.client_savings, 1.0);
        assert_eq!(ledger.total_dropped(), 2);
    }

    #[test]
    fn throughput_derives_bits_per_second_from_measured_rounds_only() {
        let mut ledger = CommLedger::default();
        // Round 0: 1000 bits each way in half a second → 4000 bps.
        ledger.record(RoundCost {
            downlink_bits: 1000,
            uplink_bits: 1000,
            clients: 2,
            participants: 2,
            dropped: 0,
            wall_ns: 500_000_000,
        });
        // Round 1: unmeasured (a baseline recorder) — no rate, and it
        // must not drag the cumulative figure toward zero.
        ledger.record_symmetric(2, 1_000_000, 1_000_000);
        // Round 2: 3000 bits total in 1.5 s → 2000 bps.
        ledger.record(RoundCost {
            downlink_bits: 2000,
            uplink_bits: 1000,
            clients: 2,
            participants: 2,
            dropped: 0,
            wall_ns: 1_500_000_000,
        });

        assert_eq!(ledger.round_throughput_bps(0), Some(4000.0));
        assert_eq!(ledger.round_throughput_bps(1), None);
        assert_eq!(ledger.round_throughput_bps(2), Some(2000.0));
        assert_eq!(ledger.round_throughput_bps(99), None);
        // Cumulative: (2000 + 3000) bits over 2 s = 2500 bps.
        assert_eq!(ledger.cumulative_throughput_bps(), Some(2500.0));
        assert_eq!(ledger.total_wall(), std::time::Duration::from_secs(2));
    }

    #[test]
    fn all_unmeasured_rounds_report_no_throughput() {
        let mut ledger = CommLedger::default();
        ledger.record_symmetric(2, 10, 10);
        assert_eq!(ledger.round_throughput_bps(0), None);
        assert_eq!(ledger.cumulative_throughput_bps(), None);
        assert_eq!(CommLedger::default().cumulative_throughput_bps(), None);
    }
}
