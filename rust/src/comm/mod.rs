//! Communication substrate: wire codecs + the cost ledger behind Table 1.
//!
//! The protocol's entire point is what goes on the wire, so encodings are
//! first-class:
//!
//! * [`BitPack`] — the Zampling uplink: `n` mask bits packed 64/word.
//! * [`FloatVec`] — the naive payload (32 bits/parameter) used by the
//!   FedAvg baseline and by every downlink that ships `p` as floats.
//! * [`rle`] — run-length coding for near-constant masks (the "consecutive
//!   1s or 0s" compression [13] mentions).
//! * [`arith`] — adaptive binary arithmetic coder achieving ≈ H(p) bits
//!   per mask bit — this is how FedPM's 0.95 bits/param bit-rate (Table 1
//!   footnote *) is reproduced.
//! * [`CommLedger`] — per-round uplink/downlink byte accounting and the
//!   savings-vs-naive factors the paper reports, including the
//!   per-shard breakdown ([`ShardCost`]) recorded under the sharded
//!   multi-leader transports and the per-directed-edge breakdown
//!   ([`EdgeCost`]) recorded under the gossip transports.
#![deny(missing_docs)]

pub mod arith;
pub mod rle;

mod ledger;

pub use ledger::{CommLedger, EdgeCost, RoundCost, SavingsReport, ShardCost};

/// Pack a boolean mask into u64 words (LSB-first within each word).
///
/// Branchless word building — each 64-bool chunk is folded with shifts
/// only (§Perf: ~3× over the per-bit branchy form at protocol sizes).
pub fn pack_bits(mask: &[bool]) -> Vec<u64> {
    let mut words = Vec::with_capacity(mask.len().div_ceil(64));
    let mut chunks = mask.chunks_exact(64);
    for chunk in &mut chunks {
        let mut w = 0u64;
        for (b, &bit) in chunk.iter().enumerate() {
            w |= (bit as u64) << b;
        }
        words.push(w);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut w = 0u64;
        for (b, &bit) in rem.iter().enumerate() {
            w |= (bit as u64) << b;
        }
        words.push(w);
    }
    words
}

/// Unpack `n` bits from u64 words.
pub fn unpack_bits(words: &[u64], n: usize) -> Vec<bool> {
    assert!(words.len() * 64 >= n, "not enough words for {n} bits");
    (0..n).map(|i| (words[i >> 6] >> (i & 63)) & 1 == 1).collect()
}

/// The Zampling uplink payload: a packed binary mask.
pub struct BitPack;

impl BitPack {
    /// Wire size in bytes for an `n`-bit mask (8-byte word granularity
    /// matches the TCP frame layout in `federated::transport`).
    pub fn wire_bytes(n: usize) -> usize {
        n.div_ceil(64) * 8
    }

    /// Pack a mask into its wire bytes (little-endian words).
    pub fn encode(mask: &[bool]) -> Vec<u8> {
        pack_bits(mask).iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    /// Unpack `n` bits from wire bytes.
    pub fn decode(bytes: &[u8], n: usize) -> Vec<bool> {
        let words: Vec<u64> = bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        unpack_bits(&words, n)
    }
}

/// The naive float payload (4 bytes per entry, little-endian).
pub struct FloatVec;

impl FloatVec {
    /// Wire size in bytes for `n` floats.
    pub fn wire_bytes(n: usize) -> usize {
        n * 4
    }

    /// Serialize floats to little-endian wire bytes.
    pub fn encode(v: &[f32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    /// Deserialize little-endian wire bytes back to floats.
    pub fn decode(bytes: &[u8]) -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn bitpack_roundtrip_various_lengths() {
        let mut rng = Xoshiro256pp::seed_from(0);
        for n in [0usize, 1, 63, 64, 65, 1000, 8331] {
            let mask: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.3)).collect();
            let bytes = BitPack::encode(&mask);
            assert_eq!(bytes.len(), BitPack::wire_bytes(n));
            assert_eq!(BitPack::decode(&bytes, n), mask);
        }
    }

    #[test]
    fn floatvec_roundtrip() {
        let v = vec![0.0f32, -1.5, f32::MAX, 1e-20];
        assert_eq!(FloatVec::decode(&FloatVec::encode(&v)), v);
        assert_eq!(FloatVec::wire_bytes(4), 16);
    }

    #[test]
    fn bit_for_bit_savings_factor_is_32() {
        // The headline arithmetic: a bit-mask of the same length as a
        // float vector is exactly 32× smaller (modulo word padding).
        let n = 8320; // multiple of 64 → no padding slack
        assert_eq!(FloatVec::wire_bytes(n) / BitPack::wire_bytes(n), 32);
    }
}
