//! `repro` — the Zampling CLI (leader entrypoint).
//!
//! Subcommands:
//!   train-local      — Local Zampling per a TOML config
//!   train-federated  — Federated Zampling (in-process sim, or TCP leader)
//!   resume           — restart a federated run from a checkpoint file,
//!                      byte-identical to the uninterrupted run
//!   serve-client     — TCP worker process (connects to a leader)
//!   serve-shard      — shard-leader process of the wire aggregation tree
//!                      (leads its own clients, merges child shards,
//!                      ships one ShardVotes frame upward per round)
//!   serve-peer       — gossip node process (tiny leader for its
//!                      topology neighbours + dials the coordinator)
//!   testnet          — spawn a whole multi-process fleet from one
//!                      scenario TOML (roles, tree shape, chaos schedule)
//!                      and byte-compare it against the in-process twin
//!   experiment       — regenerate a paper table/figure (fig3|fig4|table1|
//!                      table4|fig5|fig6|dropout|population|theory)
//!   comm-report      — Table 1 savings ledger for a config
//!   info             — artifact manifest + platform probe
//!
//! Backend selection: `--backend pjrt` runs the dense steps through the
//! AOT HLO artifacts on the PJRT CPU client; `--backend native` uses the
//! pure-Rust oracle (the two are integration-tested to agree).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use zampling::config::{
    peer_addresses, shard_addresses, tree_addresses, Backend, FedConfig, PolicyKind, TopologyKind,
    TrainConfig, TransportKind,
};
use zampling::data::Dataset;
use zampling::experiments::{self, Scale};
use zampling::federated::gossip::{run_gossip_wire, run_peer, Topology};
use zampling::federated::protocol::MaskCodec;
use zampling::federated::transport::{Leader, ShardedTransport, TcpTransport, Worker};
use zampling::federated::{
    client_round, make_policy, resume_federated, run_federated, run_federated_elastic,
    run_federated_parallel, Checkpoint, RoundEngine, ShardPlan, ShardTree, WireTreeTransport,
};
use zampling::metrics::RunLog;
use zampling::nn::ArchSpec;
use zampling::rng::SeedTree;
use zampling::util::cli::Args;
use zampling::util::toml::TomlDoc;
use zampling::zampling::{train_local, DenseExecutor, LocalZampling, NativeExecutor, ProbVector};

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("train-local") => cmd_train_local(&args),
        Some("train-federated") => cmd_train_federated(&args),
        Some("resume") => cmd_resume(&args),
        Some("serve-client") => cmd_serve_client(&args),
        Some("serve-shard") => cmd_serve_shard(&args),
        Some("serve-peer") => cmd_serve_peer(&args),
        Some("testnet") => cmd_testnet(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("comm-report") => cmd_comm_report(&args),
        Some("info") => cmd_info(&args),
        Some(other) => Err(format!("unknown subcommand '{other}'\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "usage: repro <subcommand> [options]
  train-local       --config <toml> [--backend pjrt|native] [--eval-samples N]
  train-federated   --config <toml> [--backend ...]
                    [--transport local|pool|tcp|sharded|sharded-wire|gossip-tcp]
                    [--shards S] [--topology complete|ring|star]
                    [--policy uniform|straggler-aware]
                    [--listen host:port] [--eval-every N]
                    [--participation F] [--round-timeout-ms MS]
                    [--round-timeout-max-ms MS] [--fail-at-round R]
  resume            --config <toml> --checkpoint <path> [--backend ...]
                    [--listen host:port] [--out results/]
  serve-client      --addr host:port[,host:port...] --client-id K --config <toml>
                    [--fail-at-round R]
  serve-shard       --addr host:port --shard-id S --config <toml>
                    [--fail-at-round R]
  serve-peer        --addr host:port --node-id K --config <toml>
                    [--die-after-round R]
  testnet           --scenario <toml> [--out results/testnet]
  experiment        --id fig3|fig4|table1|table4|fig5|fig6|dropout|population|theory
                    [--scale ci|paper] [--out results/]
  comm-report       --config <toml>
  info              [--artifacts artifacts/]

transports (one RoundEngine drives them all; see federated::engine):
  local    sequential in-process clients (any backend, incl. pjrt)
  pool     in-process clients sharded across the worker pool, byte-identical
           to local (the default; degrades to local under --backend pjrt)
  tcp      this process is the leader; start workers with serve-client
  sharded  this process is the root of S per-shard leaders; shard s listens
           on --listen's port + s (or federated.shard-addrs), workers dial
           their own shard's address (derived from --client-id)
  sharded-wire  this process is the root of a tree of serve-shard
           *processes* (federated.tree-parents; flat when empty); shard s
           leads workers on --listen's port + 1 + s and merges children on
           port + 1 + shards + s; uniform policy + raw uplink only
  gossip-tcp  decentralized: this process coordinates rounds, each
           serve-peer node (listening on --listen's port + 1 + node-id, or
           federated.peer-addrs) gossips masks with its federated.topology
           neighbours over its own tiny leader
policies: uniform (paper) | straggler-aware (deprioritize clients that
  keep missing --round-timeout-ms; heartbeats can extend deadlines up
  to --round-timeout-max-ms)
checkpoint/resume (federated.checkpoint-every > 0 in the config):
  the leader writes <out>/checkpoint.bin atomically at every K-th round
  boundary; `repro resume` reloads it and replays the remaining rounds
  byte-identically (workers reconnect with a fresh Hello).  With
  federated.max-clients > federated.clients a late `serve-client` with a
  fresh id joins the roster at the next round boundary (elastic
  membership; local/pool/tcp transports only).
chaos knobs (testnet schedules map onto these):
  --fail-at-round R   serve-client / serve-shard exit cleanly the moment
                      round R's frame arrives, before doing any round
                      work; on train-federated the *leader* errors out at
                      the start of round R, simulating a killed root
  --die-after-round R serve-peer exits right after reporting round R";

fn load_train_config(args: &Args) -> Result<TrainConfig, String> {
    let path = args.get("config").ok_or("missing --config <toml>")?.to_string();
    let doc = TomlDoc::load(Path::new(&path))?;
    let mut cfg = TrainConfig::from_toml(&doc)?;
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    Ok(cfg)
}

fn load_fed_config(args: &Args) -> Result<FedConfig, String> {
    let path = args.get("config").ok_or("missing --config <toml>")?.to_string();
    let doc = TomlDoc::load(Path::new(&path))?;
    let mut cfg = FedConfig::from_toml(&doc)?;
    if let Some(b) = args.get("backend") {
        cfg.train.backend = Backend::parse(b)?;
    }
    if let Some(p) = args.get("participation") {
        let p: f64 = p.parse().map_err(|_| format!("bad --participation '{p}'"))?;
        if !(p > 0.0 && p <= 1.0) {
            return Err(format!("--participation {p} must be in (0, 1]"));
        }
        cfg.participation = p;
    }
    if let Some(t) = args.get("round-timeout-ms") {
        cfg.round_timeout_ms = t.parse().map_err(|_| format!("bad --round-timeout-ms '{t}'"))?;
    }
    if let Some(t) = args.get("round-timeout-max-ms") {
        cfg.round_timeout_max_ms =
            t.parse().map_err(|_| format!("bad --round-timeout-max-ms '{t}'"))?;
    }
    if let Some(t) = args.get("transport") {
        cfg.transport = TransportKind::parse(t)?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::parse(p)?;
    }
    if let Some(t) = args.get("topology") {
        cfg.topology = TopologyKind::parse(t)?;
    }
    if let Some(s) = args.get("shards") {
        let s: usize = s.parse().map_err(|_| format!("bad --shards '{s}'"))?;
        if s == 0 || s > cfg.clients {
            return Err(format!("--shards {s} must be in 1..={}", cfg.clients));
        }
        cfg.shards = s;
    }
    // Re-check shard/transport consistency after the CLI overrides: a
    // multi-shard run under a single-leader transport would hang (the
    // root binds one port while workers dial per-shard ports).
    if cfg.shards > 1
        && cfg.transport != TransportKind::Sharded
        && cfg.transport != TransportKind::ShardedWire
    {
        return Err(format!(
            "shards = {} requires --transport sharded or sharded-wire (got {})",
            cfg.shards,
            cfg.transport.as_str()
        ));
    }
    // Same idea for the gossip graph: a topology the CLI overrides must
    // still be well-defined for the client count before any socket opens.
    if cfg.transport == TransportKind::GossipTcp && cfg.clients < cfg.topology.min_nodes() {
        return Err(format!(
            "--topology {} needs at least {} clients, got {}",
            cfg.topology.as_str(),
            cfg.topology.min_nodes(),
            cfg.clients
        ));
    }
    Ok(cfg)
}

/// Pick the executor per config.
fn make_executor(cfg: &TrainConfig) -> Result<Box<dyn DenseExecutor>, String> {
    match cfg.backend {
        Backend::Pjrt => make_pjrt_executor(cfg),
        Backend::Native => {
            println!("[repro] backend: native (pure-rust oracle)");
            Ok(Box::new(NativeExecutor::new(cfg.arch.clone(), cfg.batch, 500)))
        }
    }
}

#[cfg(feature = "pjrt")]
fn make_pjrt_executor(cfg: &TrainConfig) -> Result<Box<dyn DenseExecutor>, String> {
    let rt = zampling::runtime::PjrtRuntime::new(Path::new("artifacts"))
        .map_err(|e| format!("pjrt runtime: {e:#}"))?;
    let exec = rt
        .dense_executor(&cfg.arch.name)
        .map_err(|e| format!("pjrt executor: {e:#}"))?;
    println!("[repro] backend: pjrt ({})", rt.platform());
    Ok(Box::new(exec))
}

#[cfg(not(feature = "pjrt"))]
fn make_pjrt_executor(_cfg: &TrainConfig) -> Result<Box<dyn DenseExecutor>, String> {
    Err("this build has no PJRT support; the 'pjrt' feature also needs the external \
         `xla` crate added to rust/Cargo.toml (see the note there) — use --backend native"
        .into())
}

fn load_splits(cfg: &TrainConfig) -> (Dataset, Dataset) {
    let seeds = SeedTree::new(cfg.seed);
    if cfg.train_rows >= 60_000 {
        (Dataset::mnist_or_synthetic(true, &seeds), Dataset::mnist_or_synthetic(false, &seeds))
    } else {
        Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds)
    }
}

fn cmd_train_local(args: &Args) -> Result<(), String> {
    let cfg = load_train_config(args)?;
    let eval_samples = args.usize_or("eval-samples", 100);
    let out_dir = args.str_or("out", "results");
    args.reject_unknown()?;

    let (train, test) = load_splits(&cfg);
    println!(
        "[repro] local zampling: arch={} m={} n={} (m/n={:.0}) d={} lr={}",
        cfg.arch.name,
        cfg.arch.num_params(),
        cfg.n,
        cfg.compression_factor(),
        cfg.d,
        cfg.lr
    );
    let mut exec = make_executor(&cfg)?;
    let out = train_local(&cfg, exec.as_mut(), &train, &test, eval_samples);
    for e in &out.epochs {
        println!(
            "epoch {:>3}  train_loss {:.4}  val_loss {:.4}  val_acc {:.4}",
            e.epoch, e.train_loss, e.val_loss, e.val_acc
        );
    }
    println!(
        "final: mean_sampled {:.4} ± {:.4}  expected {:.4}  best {:.4}  discretized {:.4}",
        out.report.mean_sampled_acc,
        out.report.sampled_acc_std,
        out.report.expected_acc,
        out.report.best_sampled_acc,
        out.report.discretized_acc
    );
    let mut log = RunLog::new("train_local");
    for e in &out.epochs {
        log.push(zampling::metrics::RoundRecord {
            round: e.epoch,
            mean_sampled_acc: e.val_acc,
            sampled_acc_std: 0.0,
            expected_acc: e.val_acc,
            train_loss: e.train_loss,
            uplink_bits: 0,
            downlink_bits: 0,
        });
    }
    log.save(Path::new(&out_dir)).map_err(|e| format!("saving results: {e}"))?;
    Ok(())
}

fn cmd_train_federated(args: &Args) -> Result<(), String> {
    let cfg = load_fed_config(args)?;
    let eval_every = args.usize_or("eval-every", 1);
    let eval_samples = args.usize_or("eval-samples", 100);
    let listen = args.str_or("listen", "127.0.0.1:7707");
    let out_dir = args.str_or("out", "results");
    let fail_at_round = parse_round_arg(args, "fail-at-round")?;
    args.reject_unknown()?;

    let seeds = SeedTree::new(cfg.train.seed);
    let (train, test) = load_splits(&cfg.train);
    // The data is partitioned over the *maximum* client id space, so a
    // client that joins late trains on the same shard it would have
    // owned from round 0 (and the sim twin agrees byte-for-byte).  With
    // the default max-clients = clients this is the classical split.
    let shards = train.partition_iid(cfg.max_clients, &seeds);
    println!(
        "[repro] federated zampling: {} clients, {} rounds, n={} d={} (transport={} policy={})",
        cfg.clients,
        cfg.rounds,
        cfg.train.n,
        cfg.train.d,
        cfg.transport.as_str(),
        cfg.policy.as_str()
    );

    // The pool transport shards clients across `Native` executors; PJRT
    // handles are not `Send`, so that backend degrades to the sequential
    // in-process transport (the same behavior, minus the parallelism).
    let mut transport = cfg.transport;
    if transport == TransportKind::Pool && cfg.train.backend == Backend::Pjrt {
        println!("[repro] pjrt backend: pool transport degrades to sequential (local)");
        transport = TransportKind::Local;
    }
    // The pool transport's lane split assumes a fixed roster; an elastic
    // id space runs the same math through the sequential transport.
    if transport == TransportKind::Pool && cfg.max_clients > cfg.clients {
        println!("[repro] elastic roster: pool transport degrades to sequential (local)");
        transport = TransportKind::Local;
    }
    if fail_at_round.is_some()
        && transport != TransportKind::Tcp
        && transport != TransportKind::Sharded
        && transport != TransportKind::ShardedWire
    {
        return Err(format!(
            "--fail-at-round on train-federated needs a socket leader transport \
             (tcp, sharded, or sharded-wire; got {})",
            transport.as_str()
        ));
    }
    match transport {
        TransportKind::Local => {
            let mut exec = make_executor(&cfg.train)?;
            let out = if cfg.max_clients > cfg.clients {
                // No socket, so nobody can dial in late — but the run
                // uses the elastic data split and id space, matching
                // what the wire twin of a join scenario starts from.
                run_federated_elastic(&cfg, exec.as_mut(), &shards, &test, eval_samples, eval_every, &[])
            } else {
                run_federated(&cfg, exec.as_mut(), &shards, &test, eval_samples, eval_every)
            };
            print_fed_outcome(&cfg, &out);
            out.log.save(Path::new(&out_dir)).map_err(|e| format!("saving: {e}"))?;
            save_fed_artifacts(&out_dir, &out)?;
        }
        TransportKind::Pool => {
            println!("[repro] backend: native (parallel client loop)");
            let out = run_federated_parallel(&cfg, &shards, &test, eval_samples, eval_every, 500);
            print_fed_outcome(&cfg, &out);
            out.log.save(Path::new(&out_dir)).map_err(|e| format!("saving: {e}"))?;
            save_fed_artifacts(&out_dir, &out)?;
        }
        TransportKind::Tcp => {
            run_tcp_leader(&cfg, &listen, &test, eval_samples, eval_every, &out_dir, fail_at_round)?
        }
        TransportKind::Sharded => run_sharded_leader(
            &cfg,
            &listen,
            &test,
            eval_samples,
            eval_every,
            &out_dir,
            fail_at_round,
        )?,
        TransportKind::ShardedWire => {
            run_tree_root(&cfg, &listen, &test, eval_samples, eval_every, &out_dir, fail_at_round)?
        }
        TransportKind::GossipTcp => {
            run_gossip_coordinator(&cfg, &listen, &test, eval_samples, eval_every, &out_dir)?
        }
    }
    Ok(())
}

/// Where the leader drops its periodic checkpoint (None disables).
fn checkpoint_path(cfg: &FedConfig, out_dir: &str) -> Option<PathBuf> {
    (cfg.checkpoint_every != 0).then(|| Path::new(out_dir).join("checkpoint.bin"))
}

/// `repro resume` — reload a checkpoint written by a federated leader
/// and replay the remaining rounds, byte-identical to the uninterrupted
/// run.  The engine picks up `p`, the eval RNG cursor, the straggler
/// history, the run log, and the comm ledger from the file; workers
/// reconnect with a fresh `Hello` (their per-round state is a pure
/// function of the shared seed and the round's broadcast, so nothing
/// client-side needs saving).
fn cmd_resume(args: &Args) -> Result<(), String> {
    use std::net::TcpListener;
    use std::sync::Arc;
    use zampling::sparse::QMatrix;

    let ckpt_file = args.get("checkpoint").ok_or("missing --checkpoint <path>")?.to_string();
    let listen = args.str_or("listen", "127.0.0.1:7707");
    let out_dir = args.str_or("out", "results");
    let cfg = load_fed_config(args)?;
    args.reject_unknown()?;

    let ckpt = Checkpoint::load(Path::new(&ckpt_file)).map_err(|e| format!("{e:#}"))?;
    let population = ckpt.manifest.population as usize;
    println!(
        "[repro] resuming from {ckpt_file}: round {}/{} with {population} clients",
        ckpt.manifest.next_round, cfg.rounds
    );

    let seeds = SeedTree::new(cfg.train.seed);
    let (train, test) = load_splits(&cfg.train);

    let mut transport = cfg.transport;
    if transport == TransportKind::Pool {
        println!("[repro] resume: pool transport degrades to sequential (local)");
        transport = TransportKind::Local;
    }
    if transport == TransportKind::Local {
        let shards = train.partition_iid(cfg.max_clients, &seeds);
        let mut exec = make_executor(&cfg.train)?;
        let out = resume_federated(&cfg, exec.as_mut(), &shards, &test, ckpt)
            .map_err(|e| format!("{e:#}"))?;
        print_fed_outcome(&cfg, &out);
        out.log.save(Path::new(&out_dir)).map_err(|e| format!("saving: {e}"))?;
        save_fed_artifacts(&out_dir, &out)?;
        return Ok(());
    }

    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let exec = make_executor(&cfg.train)?;
    let engine = RoundEngine::resume(&cfg, ckpt, Arc::clone(&q), &test)
        .map_err(|e| format!("{e:#}"))?
        .verbose(true)
        .checkpoint_to(cfg.checkpoint_every, checkpoint_path(&cfg, &out_dir));
    let mut policy = make_policy(cfg.policy);

    let out = match transport {
        TransportKind::Tcp => {
            println!("[repro] leader listening on {listen}, waiting for {population} workers");
            let listener =
                TcpListener::bind(listen.as_str()).map_err(|e| format!("binding {listen}: {e}"))?;
            // Startup blocks on the checkpointed population (everyone
            // must see the replayed round's broadcast for the restart to
            // be byte-identical); slots still cover the elastic id space.
            let roster: Vec<usize> = (0..population).collect();
            let leader = Leader::from_listener_subset(listener, cfg.max_clients, &roster)
                .map_err(|e| format!("{e:#}"))?;
            let mut transport = TcpTransport::new(leader, exec);
            engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?
        }
        TransportKind::Sharded => {
            let plan = ShardPlan::new(cfg.clients, cfg.shards);
            let addrs = shard_addresses(&listen, &cfg.shard_addrs, cfg.shards)?;
            let mut transport =
                ShardedTransport::accept(&addrs, plan, exec).map_err(|e| format!("{e:#}"))?;
            engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?
        }
        TransportKind::ShardedWire => {
            let mut transport =
                WireTreeTransport::accept(&listen, &cfg, exec).map_err(|e| format!("{e:#}"))?;
            engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?
        }
        _ => {
            return Err(format!(
                "resume supports local, pool, tcp, sharded, and sharded-wire transports (got {})",
                transport.as_str()
            ))
        }
    };

    print_fed_outcome(&cfg, &out);
    out.log.save(Path::new(&out_dir)).map_err(|e| format!("saving: {e}"))?;
    save_fed_artifacts(&out_dir, &out)?;
    Ok(())
}

/// Write the byte-comparable run artifacts every federated driver
/// produces: `final_probs.bin` (the aggregated `p`, little-endian f32s)
/// and `ledger.csv` (the sectioned comm ledger, wall-clock excluded).
/// `repro testnet` diffs these files against the in-process twin's.
fn save_fed_artifacts(out_dir: &str, out: &zampling::federated::FedOutcome) -> Result<(), String> {
    let dir = Path::new(out_dir);
    std::fs::create_dir_all(dir).map_err(|e| format!("creating {out_dir}: {e}"))?;
    let mut probs = Vec::with_capacity(out.final_probs.len() * 4);
    for p in &out.final_probs {
        probs.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(dir.join("final_probs.bin"), probs)
        .map_err(|e| format!("writing final_probs.bin: {e}"))?;
    std::fs::write(dir.join("ledger.csv"), out.ledger.to_csv())
        .map_err(|e| format!("writing ledger.csv: {e}"))?;
    Ok(())
}

fn print_fed_outcome(cfg: &FedConfig, out: &zampling::federated::FedOutcome) {
    for r in &out.log.rounds {
        println!(
            "round {:>3}  sampled {:.4} ± {:.4}  expected {:.4}  up {}b down {}b",
            r.round,
            r.mean_sampled_acc,
            r.sampled_acc_std,
            r.expected_acc,
            r.uplink_bits,
            r.downlink_bits
        );
    }
    let rep = out.ledger.savings(cfg.train.arch.num_params());
    println!(
        "savings: client {:.1}x server {:.1}x (naive = 32m = {} bits/round/client)",
        rep.client_savings, rep.server_savings, rep.naive_bits
    );
    print_throughput(&out.ledger);
}

/// The ledger's bandwidth view: bits/round says what a round costs,
/// this says how fast the transport moved it.  Silent when no round
/// carried a measured wall clock (e.g. baseline recorders).
fn print_throughput(ledger: &zampling::comm::CommLedger) {
    if let Some(bps) = ledger.cumulative_throughput_bps() {
        println!(
            "throughput: {:.3} Mbit/s over {:.2} s measured round wall-clock",
            bps / 1e6,
            ledger.total_wall().as_secs_f64()
        );
    }
}

/// TCP leader: serve rounds to `serve-client` worker processes — the
/// [`RoundEngine`] over a [`TcpTransport`].
///
/// Fault-tolerant orchestration: each round the configured policy
/// selects a participant subset, masks are collected in arrival order
/// under the configured deadline (heartbeats from slow-but-alive workers
/// may extend it up to `round_timeout_max_ms`), the aggregate is
/// renormalized by whatever actually arrived, and participants/drops go
/// in the ledger.  Worker disconnects (and reconnects with a fresh
/// `Hello`) never abort the run.
fn run_tcp_leader(
    cfg: &FedConfig,
    listen: &str,
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    out_dir: &str,
    fail_at_round: Option<u32>,
) -> Result<(), String> {
    use std::net::TcpListener;
    use std::sync::Arc;
    use zampling::sparse::QMatrix;

    println!("[repro] leader listening on {listen}, waiting for {} workers", cfg.clients);
    // Slots exist for the whole elastic id space, but startup only
    // blocks on the initial roster — a late worker's `Hello` lands in a
    // live slot and the engine admits it at the next round boundary.
    let listener = TcpListener::bind(listen).map_err(|e| format!("binding {listen}: {e}"))?;
    let startup: Vec<usize> = (0..cfg.clients).collect();
    let leader = Leader::from_listener_subset(listener, cfg.max_clients, &startup)
        .map_err(|e| format!("{e:#}"))?;

    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();
    let exec = make_executor(&cfg.train)?;

    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "federated_tcp",
    )
    .verbose(true)
    .checkpoint_to(cfg.checkpoint_every, checkpoint_path(cfg, out_dir))
    .fail_at_round(fail_at_round);
    let mut transport = TcpTransport::new(leader, exec);
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?;

    let rep = out.ledger.savings(cfg.train.arch.num_params());
    println!(
        "savings: client {:.1}x server {:.1}x; {} client-drops over {} rounds",
        rep.client_savings,
        rep.server_savings,
        out.ledger.total_dropped(),
        cfg.rounds
    );
    print_throughput(&out.ledger);
    println!(
        "leader done: sent {} KiB, received {} KiB",
        transport.leader.sent_bytes / 1024,
        transport.leader.recv_bytes / 1024
    );
    out.log.save(Path::new(out_dir)).map_err(|e| format!("saving: {e}"))?;
    save_fed_artifacts(out_dir, &out)?;
    Ok(())
}

/// Sharded root: `cfg.shards` per-shard leaders (each its own listener,
/// reusing the concurrent `Leader` machinery) serve rounds to
/// `serve-client` workers; per-shard partial vote sums merge at this
/// process before the renormalized aggregation — the
/// [`RoundEngine`] over a [`ShardedTransport`].
fn run_sharded_leader(
    cfg: &FedConfig,
    listen: &str,
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    out_dir: &str,
    fail_at_round: Option<u32>,
) -> Result<(), String> {
    use std::sync::Arc;
    use zampling::sparse::QMatrix;

    let plan = ShardPlan::new(cfg.clients, cfg.shards);
    let addrs = shard_addresses(listen, &cfg.shard_addrs, cfg.shards)?;
    for (s, addr) in addrs.iter().enumerate() {
        let r = plan.range(s);
        println!(
            "[repro] shard {s} listening on {addr}, waiting for clients {}..{}",
            r.start, r.end
        );
    }
    let exec = make_executor(&cfg.train)?;
    let mut transport =
        ShardedTransport::accept(&addrs, plan, exec).map_err(|e| format!("{e:#}"))?;

    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();

    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "federated_sharded",
    )
    .verbose(true)
    .checkpoint_to(cfg.checkpoint_every, checkpoint_path(cfg, out_dir))
    .fail_at_round(fail_at_round);
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?;

    let rep = out.ledger.savings(cfg.train.arch.num_params());
    println!(
        "savings: client {:.1}x server {:.1}x; {} client-drops over {} rounds; merge traffic {} KiB",
        rep.client_savings,
        rep.server_savings,
        out.ledger.total_dropped(),
        cfg.rounds,
        out.ledger.total_merge_bits() / 8 / 1024
    );
    print_throughput(&out.ledger);
    for (s, (up, down, merge, received, dropped)) in
        out.ledger.shard_totals().into_iter().enumerate()
    {
        println!(
            "shard {s}: up {} KiB  down {} KiB  merge {} KiB  received {received}  dropped {dropped}",
            up / 8 / 1024,
            down / 8 / 1024,
            merge / 8 / 1024
        );
    }
    println!(
        "shard miss pressure at end of run: {:?}",
        out.history.shard_misses(transport.plan())
    );
    for (s, leader) in transport.leaders().iter().enumerate() {
        println!(
            "shard {s} leader done: sent {} KiB, received {} KiB",
            leader.sent_bytes / 1024,
            leader.recv_bytes / 1024
        );
    }
    out.log.save(Path::new(out_dir)).map_err(|e| format!("saving: {e}"))?;
    save_fed_artifacts(out_dir, &out)?;
    Ok(())
}

/// Wire-tree root: the [`RoundEngine`] over a
/// [`WireTreeTransport`] — one merge link per direct child of the root,
/// each a `serve-shard` process aggregating its whole subtree (flat
/// tree = the sharded topology with leaders promoted to processes; see
/// `federated::tree`).
fn run_tree_root(
    cfg: &FedConfig,
    listen: &str,
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    out_dir: &str,
    fail_at_round: Option<u32>,
) -> Result<(), String> {
    use std::sync::Arc;
    use zampling::sparse::QMatrix;

    let tree = ShardTree::from_cfg(cfg).map_err(|e| format!("{e:#}"))?;
    let addrs = tree_addresses(listen, cfg.shards)?;
    println!(
        "[repro] tree root on {listen}: {} shard(s), depth {}, direct children {:?}",
        cfg.shards,
        tree.depth(),
        tree.root_children()
    );
    for s in 0..cfg.shards {
        println!("[repro] shard {s}: workers at {}, merges at {}", addrs.workers[s], addrs.merges[s]);
    }
    let exec = make_executor(&cfg.train)?;
    let mut transport = WireTreeTransport::accept(listen, cfg, exec).map_err(|e| format!("{e:#}"))?;

    let seeds = SeedTree::new(cfg.train.seed);
    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let mut init_rng = seeds.rng("p-init", 0);
    let p0 = ProbVector::init_uniform(cfg.train.n, &mut init_rng).probs().to_vec();

    let engine = RoundEngine::new(
        cfg,
        cfg.clients,
        Arc::clone(&q),
        p0,
        test,
        eval_samples,
        eval_every,
        "federated_sharded",
    )
    .verbose(true)
    .checkpoint_to(cfg.checkpoint_every, checkpoint_path(cfg, out_dir))
    .fail_at_round(fail_at_round);
    let mut policy = make_policy(cfg.policy);
    let out = engine.run(&mut transport, policy.as_mut()).map_err(|e| format!("{e:#}"))?;

    let rep = out.ledger.savings(cfg.train.arch.num_params());
    println!(
        "savings: client {:.1}x server {:.1}x; {} client-drops over {} rounds; merge traffic {} KiB",
        rep.client_savings,
        rep.server_savings,
        out.ledger.total_dropped(),
        cfg.rounds,
        out.ledger.total_merge_bits() / 8 / 1024
    );
    print_throughput(&out.ledger);
    for (s, (up, down, merge, received, dropped)) in
        out.ledger.shard_totals().into_iter().enumerate()
    {
        println!(
            "subtree {s}: up {} KiB  down {} KiB  merge {} KiB  received {received}  dropped {dropped}",
            up / 8 / 1024,
            down / 8 / 1024,
            merge / 8 / 1024
        );
    }
    out.log.save(Path::new(out_dir)).map_err(|e| format!("saving: {e}"))?;
    save_fed_artifacts(out_dir, &out)?;
    Ok(())
}

/// Shard-leader process of the wire aggregation tree (`repro
/// serve-shard`): leads the clients its `ShardPlan` range owns, merges
/// its child shards' `ShardVotes` frames into its own vote sum, and
/// ships one frame upward per round.  `--fail-at-round R` is the chaos
/// knob the testnet harness drives: exit cleanly the moment round `R`'s
/// frame arrives, before any round work, so the subtree's death is
/// deterministic.
fn cmd_serve_shard(args: &Args) -> Result<(), String> {
    let base = args
        .get("addr")
        .ok_or("missing --addr host:port (the tree root's --listen address)")?
        .to_string();
    let shard_id = args.usize_or("shard-id", usize::MAX);
    if shard_id == usize::MAX {
        return Err("missing --shard-id".into());
    }
    let fail_at_round = parse_round_arg(args, "fail-at-round")?;
    let cfg = load_fed_config(args)?;
    args.reject_unknown()?;
    if cfg.transport != TransportKind::ShardedWire {
        return Err(format!(
            "serve-shard needs transport = sharded-wire (got {})",
            cfg.transport.as_str()
        ));
    }
    zampling::federated::serve_shard(&cfg, shard_id, &base, fail_at_round)
        .map_err(|e| format!("{e:#}"))
}

/// Parse an optional `--<key> R` round-number chaos knob.
fn parse_round_arg(args: &Args, key: &str) -> Result<Option<u32>, String> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => v.parse::<u32>().map(Some).map_err(|_| format!("bad --{key} '{v}'")),
    }
}

/// `repro testnet` — spawn a whole multi-process fleet from one scenario
/// TOML and (per the scenario's `compare` mode) byte-check the wire run
/// against its in-process twin.  All the machinery lives in
/// [`zampling::testnet`]; this is just argument plumbing.
fn cmd_testnet(args: &Args) -> Result<(), String> {
    let scenario = args.get("scenario").ok_or("missing --scenario <toml>")?.to_string();
    let out = args.str_or("out", "results/testnet");
    args.reject_unknown()?;
    let report = zampling::testnet::run_scenario(Path::new(&scenario), Path::new(&out))
        .map_err(|e| format!("{e:#}"))?;
    println!("{report}");
    Ok(())
}

/// Gossip coordinator: kick decentralized rounds off and evaluate the
/// consensus — the [`RoundEngine`] over a
/// [`zampling::federated::gossip::WirePeerTransport`].  Masks never
/// pass through this process: they travel peer-to-peer between the
/// `serve-peer` nodes' tiny leaders; the coordinator only ships the
/// (unbilled) `PeerRound`/`Report` coordination frames and keeps the
/// per-directed-edge ledger.
fn run_gossip_coordinator(
    cfg: &FedConfig,
    listen: &str,
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
    out_dir: &str,
) -> Result<(), String> {
    use std::net::TcpListener;

    let topo = Topology::from_cfg(cfg)?;
    let peer_addrs = peer_addresses(listen, &cfg.peer_addrs, cfg.clients)?;
    println!(
        "[repro] gossip coordinator on {listen}: {} peers, {} topology, {} directed edges",
        cfg.clients,
        if cfg.topology_adj.is_empty() { cfg.topology.as_str() } else { "custom" },
        topo.num_messages()
    );
    for (i, addr) in peer_addrs.iter().enumerate() {
        println!("[repro] peer {i} expected at {addr}, neighbours {:?}", topo.neighbors[i]);
    }
    let listener = TcpListener::bind(listen).map_err(|e| format!("binding {listen}: {e}"))?;
    let exec = make_executor(&cfg.train)?;
    let out = run_gossip_wire(cfg, &topo, listener, test, eval_samples, eval_every, exec, true)
        .map_err(|e| format!("{e:#}"))?;

    let rep = out.ledger.savings(cfg.train.arch.num_params());
    println!(
        "savings: client {:.1}x server {:.1}x; {} peer-drops over {} rounds",
        rep.client_savings,
        rep.server_savings,
        out.ledger.total_dropped(),
        cfg.rounds
    );
    print_throughput(&out.ledger);
    println!(
        "edge ledger: {} KiB over {} directed edges ({} bits per edge per round)",
        out.ledger.total_edge_bits() / 8 / 1024,
        topo.num_messages(),
        cfg.train.n
    );
    for (i, (sent, recv)) in out.ledger.node_edge_totals(cfg.clients).into_iter().enumerate() {
        println!("peer {i}: sent {} KiB  received {} KiB", sent / 8 / 1024, recv / 8 / 1024);
    }
    out.log.save(Path::new(out_dir)).map_err(|e| format!("saving: {e}"))?;
    save_fed_artifacts(out_dir, &out)?;
    Ok(())
}

/// Gossip node: one decentralized party (`repro serve-peer`).  Runs a
/// tiny leader for its topology neighbours, dials the coordinator and
/// every neighbour, then gossips one mask per round per live edge.
fn cmd_serve_peer(args: &Args) -> Result<(), String> {
    use std::net::TcpListener;

    let base = args
        .get("addr")
        .ok_or("missing --addr host:port (the coordinator's --listen address)")?
        .to_string();
    let node_id = args.usize_or("node-id", usize::MAX);
    if node_id == usize::MAX {
        return Err("missing --node-id".into());
    }
    let die_after_round = parse_round_arg(args, "die-after-round")?;
    let cfg = load_fed_config(args)?;
    args.reject_unknown()?;

    let topo = Topology::from_cfg(&cfg)?;
    if node_id >= cfg.clients {
        return Err(format!("node-id {node_id} ≥ clients {}", cfg.clients));
    }
    let peer_addrs = peer_addresses(&base, &cfg.peer_addrs, cfg.clients)?;
    // Bind our own listener before dialing anyone, so every peer's
    // dials land in a bound backlog regardless of launch order.
    let listener = TcpListener::bind(&peer_addrs[node_id])
        .map_err(|e| format!("binding {}: {e}", peer_addrs[node_id]))?;
    println!(
        "[peer {node_id}] listening on {}, neighbours {:?}, coordinator {base}",
        peer_addrs[node_id], topo.neighbors[node_id]
    );

    // Every peer derives the identical data split from the shared seed.
    let seeds = SeedTree::new(cfg.train.seed);
    let (train, _test) = load_splits(&cfg.train);
    let shard = train.partition_iid(cfg.clients, &seeds).swap_remove(node_id);
    println!("[peer {node_id}] shard rows: {}", shard.len());

    let mut exec = make_executor(&cfg.train)?;
    run_peer(
        &cfg,
        &topo,
        node_id,
        listener,
        &peer_addrs,
        &base,
        exec.as_mut(),
        &shard,
        die_after_round,
    )
    .map_err(|e| format!("{e:#}"))?;
    println!("[peer {node_id}] shutdown");
    Ok(())
}

/// TCP worker: local shard training driven by the leader (single or
/// sharded — under `federated.shards > 1` the worker derives its own
/// shard leader's address from the shared config and its client id).
fn cmd_serve_client(args: &Args) -> Result<(), String> {
    use std::sync::Arc;
    use zampling::federated::protocol::{decode_server, peek_server_frame, ServerFrameKind, ServerMsg};
    use zampling::sparse::QMatrix;

    let addr_arg = args.get("addr").ok_or("missing --addr host:port")?.to_string();
    let client_id = args.usize_or("client-id", usize::MAX);
    if client_id == usize::MAX {
        return Err("missing --client-id".into());
    }
    let fail_at_round = parse_round_arg(args, "fail-at-round")?;
    let cfg = load_fed_config(args)?;
    args.reject_unknown()?;

    // Resolve which leader this worker dials: an explicit comma list in
    // --addr wins, then the config's shard-addrs, then ports derived
    // from the base address — the same rule the sharded root applies,
    // so both sides agree without coordination.  With shards = 1 every
    // path degenerates to the single --addr.
    let parts: Vec<String> = addr_arg
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if parts.is_empty() {
        return Err("empty --addr".into());
    }
    // Elastic membership: any id below `max-clients` is a legal worker;
    // ids at or beyond the starting roster join at a round boundary.
    if client_id >= cfg.max_clients {
        return Err(format!("client-id {client_id} ≥ max-clients {}", cfg.max_clients));
    }
    // Multi-shard transports run a fixed roster (elastic ids only exist
    // under shards = 1, enforced at config parse), so the plan over the
    // starting roster is total for every id that reaches it.
    let owner =
        if cfg.shards > 1 { ShardPlan::new(cfg.clients, cfg.shards).owner(client_id) } else { 0 };
    // Under the wire tree the worker-facing ports live in the tree
    // address plan (shard s leads workers on base + 1 + s); otherwise
    // the flat sharded rule applies.
    let addr = if cfg.transport == TransportKind::ShardedWire {
        tree_addresses(&parts[0], cfg.shards)?.workers[owner].clone()
    } else {
        let explicit: &[String] = if parts.len() > 1 { &parts } else { &cfg.shard_addrs };
        shard_addresses(&parts[0], explicit, cfg.shards)?[owner].clone()
    };
    if cfg.shards > 1 {
        println!("[worker {client_id}] shard {owner} leader at {addr}");
    }

    // Every worker derives the identical data split from the shared
    // seed, partitioned over the full elastic id space so a late
    // joiner's shard matches what the sim twin assigns it.
    let seeds = SeedTree::new(cfg.train.seed);
    let (train, _test) = load_splits(&cfg.train);
    let shard = train.partition_iid(cfg.max_clients, &seeds).swap_remove(client_id);
    println!("[worker {client_id}] shard rows: {}", shard.len());

    let q = Arc::new(QMatrix::generate(&cfg.train.arch, cfg.train.n, cfg.train.d, &seeds));
    let csc = Arc::new(q.to_csc(None));
    let sub = seeds.subtree("client", client_id as u64);
    let mut state = LocalZampling::from_parts(
        &cfg.train,
        q,
        csc,
        ProbVector::from_probs(vec![0.5; cfg.train.n]),
        &sub,
    );
    let mut exec = make_executor(&cfg.train)?;

    let codec = if cfg.entropy_code_uplink { MaskCodec::Arithmetic } else { MaskCodec::Raw };
    // Retry the dial: under testnet the fleet spawns workers and
    // leaders concurrently (and respawns restarted workers), so the
    // leader's listener may come up after this process does.
    let dial_timeout = std::time::Duration::from_secs(30);
    let mut worker = Worker::connect_retry(&addr, client_id as u32, codec, dial_timeout)
        .map_err(|e| format!("{e:#}"))?;
    loop {
        // The raw frame feeds the *same* `client_round` body the
        // in-process simulators run, so every transport trains
        // identical numbers; the dispatch only peeks the header so the
        // probs vector is decoded once (inside `client_round`).
        //
        // A dead leader (e.g. killed mid-round and then restarted via
        // `repro resume`) surfaces here as a failed read: re-dial with
        // a fresh `Hello` and keep serving.  Client round state is
        // derived from the shared seed and the round's broadcast, so
        // the replayed round trains exactly what the uninterrupted run
        // would have.  A clean end of run arrives as a `Shutdown` frame
        // before the leader closes, so this path only fires on faults.
        let frame = match worker.recv_raw() {
            Ok(frame) => frame,
            Err(e) => {
                println!("[worker {client_id}] leader link lost ({e:#}); reconnecting");
                worker = Worker::connect_retry(&addr, client_id as u32, codec, dial_timeout)
                    .map_err(|e| format!("{e:#}"))?;
                continue;
            }
        };
        match peek_server_frame(&frame).map_err(|e| format!("{e:#}"))? {
            ServerFrameKind::Round => {
                // Chaos schedule: exit cleanly the moment the doomed
                // round's frame arrives, before doing any round work —
                // the leader sees a dead connection and drops us.
                if let Some(fail_round) = fail_at_round {
                    let ServerMsg::Round { round, .. } =
                        decode_server(&frame).map_err(|e| format!("{e:#}"))?
                    else {
                        return Err(format!("worker {client_id}: peeked Round, decoded non-Round"));
                    };
                    if round == fail_round {
                        println!("[worker {client_id}] failing at round {round} (chaos schedule)");
                        return Ok(());
                    }
                }
                // Between local epochs the worker heartbeats, so a
                // leader running with a deadline cap can tell "slow but
                // alive" from "dead" and extend the round deadline.  A
                // failed heartbeat is ignored here — the mask send below
                // will surface the broken connection.
                let mut beat = || {
                    let _ = worker.send_heartbeat();
                };
                let out = client_round(
                    &cfg,
                    &mut state,
                    exec.as_mut(),
                    &shard,
                    &seeds,
                    &frame,
                    codec,
                    client_id,
                    Some(&mut beat),
                )
                .map_err(|e| format!("{e:#}"))?;
                // A failed uplink is the same fault as a failed read:
                // the leader died holding our connection.  Reconnect and
                // wait for the resumed leader to replay the round.
                if let Err(e) = worker.send_frame(&out.frame) {
                    println!("[worker {client_id}] mask send failed ({e:#}); reconnecting");
                    worker = Worker::connect_retry(&addr, client_id as u32, codec, dial_timeout)
                        .map_err(|e| format!("{e:#}"))?;
                }
            }
            ServerFrameKind::PeerRound => {
                return Err(format!(
                    "worker {client_id}: unexpected gossip PeerRound frame \
                     (serve-client workers only speak the centralized protocol; \
                     use serve-peer for gossip nodes)"
                ));
            }
            ServerFrameKind::Shutdown => {
                println!("[worker {client_id}] shutdown");
                return Ok(());
            }
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<(), String> {
    let id = args.str_or("id", "");
    let scale = Scale::parse(&args.str_or("scale", "ci"))?;
    let _out = args.str_or("out", "results");
    args.reject_unknown()?;
    match id.as_str() {
        "fig3" | "table2" => {
            let cells = experiments::compression_sweep::run(scale);
            experiments::compression_sweep::print_table(&cells);
        }
        "fig4" | "table1" => {
            let mut rows = vec![experiments::federated::run_fedavg_row(scale, 5)];
            rows.push(experiments::federated::run_fedpm_row(scale, 5));
            for factor in [8usize, 32] {
                rows.push(experiments::federated::run_zampling_row(factor, scale, 5));
            }
            experiments::federated::print_table1(&rows);
        }
        "dropout" => {
            let points = experiments::federated::run_dropout_sweep(scale, 5);
            experiments::federated::print_dropout_sweep(&points);
            let policies = experiments::federated::run_policy_comparison(scale, 5);
            experiments::federated::print_policy_comparison(&policies);
            let shard_failure = experiments::federated::run_shard_failure(scale, 5);
            experiments::federated::print_shard_failure(&shard_failure);
        }
        "table4" => {
            let rows = experiments::sensitivity::run(scale, 0);
            experiments::sensitivity::print_table(&rows);
        }
        "fig5" => {
            let points = experiments::integrality_gap::run(scale);
            experiments::integrality_gap::print_figure(&points);
        }
        "fig6" => {
            let bars = experiments::zhou_comparison::run(scale);
            experiments::zhou_comparison::print_figure(&bars);
        }
        "population" => {
            let rows = experiments::population::run(scale).map_err(|e| format!("{e:#}"))?;
            experiments::population::print_table(&rows);
        }
        "theory" => print_theory_report(),
        other => return Err(format!("unknown experiment '{other}'")),
    }
    Ok(())
}

fn print_theory_report() {
    use zampling::util::bench::{row, table};
    use zampling::zonotope as z;
    table("Theory validators (§2)", &["claim", "measured", "predicted"]);
    let q = z::square_q(8192, 3, 64, 1);
    row(&[
        "L2.3 empty cols (d=3)".to_string(),
        format!("{:.4}", q.empty_columns() as f64 / q.n as f64),
        format!("{:.4}", (-3.0f64).exp()),
    ]);
    let q2 = z::square_q(4096, 2, 64, 2);
    row(&[
        "L2.2 E#nnz(w) (d=2)".to_string(),
        format!("{:.0}", z::measure_nonzero_weights(&q2, 8, 3)),
        format!("{:.0}", z::expected_nonzero_weights(q2.m, 2)),
    ]);
    let q3 = z::square_q(4096, 16, 256, 4);
    row(&[
        "L2.1 Var(w) (fan 256)".to_string(),
        format!("{:.5}", z::measure_w_variance(&q3, 0..q3.m, 6, 5)),
        format!("{:.5}", 2.0 / 256.0),
    ]);
    let q4 = z::square_q(4096, 8, 128, 6);
    let (lo, hi) = z::predicted_max_row_activation(8, 128);
    row(&[
        "P2.4 max|Q_i p| (d=8)".to_string(),
        format!("{:.4}", z::mean_max_row_activation(&q4)),
        format!("[{lo:.4}, {hi:.4}]"),
    ]);
    let mc = z::mc_zonotope_volume(3, 3, 8.0, 20_000, 7);
    let closed = z::expected_zonotope_volume(3, 3, 8.0);
    row(&["P2.5 E|det| (n=3)".to_string(), format!("{mc:.5}"), format!("{closed:.5}")]);
}

fn cmd_comm_report(args: &Args) -> Result<(), String> {
    let cfg = load_fed_config(args)?;
    args.reject_unknown()?;
    let m = cfg.train.arch.num_params();
    let n = cfg.train.n;
    use zampling::util::bench::{row, table};
    table(
        &format!("comm-report: m={m} n={n} (m/n={}) clients={}", m / n, cfg.clients),
        &["direction", "payload", "bits/round/client", "savings vs naive"],
    );
    let naive = 32.0 * m as f64;
    row(&[
        "downlink".into(),
        "p as f32".into(),
        format!("{}", 32 * n),
        format!("{:.1}x", naive / (32.0 * n as f64)),
    ]);
    row(&[
        "uplink".into(),
        "mask bits".into(),
        format!("{n}"),
        format!("{:.1}x", naive / n as f64),
    ]);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let dir = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;
    print_artifact_info(&dir);
    for arch in [ArchSpec::small(), ArchSpec::mnistfc()] {
        println!("ArchSpec {}: m={}", arch.name, arch.num_params());
    }
    println!(
        "pool: {} parallel lanes",
        zampling::runtime::pool::global().parallelism()
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_artifact_info(dir: &str) {
    match zampling::runtime::PjrtRuntime::new(Path::new(dir)) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            println!(
                "train_batch: {}  eval_batch: {}",
                rt.manifest.train_batch, rt.manifest.eval_batch
            );
            for (name, a) in &rt.manifest.archs {
                println!("arch {name}: m={} layers={:?}", a.num_params, a.layers);
            }
            for f in &rt.manifest.fused {
                println!("fused {}: n={} d={} c={} ({}x)", f.arch, f.n, f.d, f.c, f.compression);
            }
        }
        Err(e) => println!("no artifacts loaded ({e:#}); native backend still available"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn print_artifact_info(_dir: &str) {
    println!("built without the 'pjrt' feature; native backend only");
}
