//! Theory validators for §2: random-convex-geometry quantities computed
//! on actual `Q` draws, checked against the paper's closed forms.
//!
//! * Lemma 2.1 — Kaiming-He recovery: `Var(w_i) → E[p²]·6/n_ℓ`.
//! * Lemma 2.2 — `E[#nonzero(w)] = m(1 − 2^{−d})` under `z ~ Bern(U)`.
//! * Lemma 2.3 — empty-column fraction `≈ e^{−d}` for large `m = n`.
//! * Prop 2.4  — `max_p E|Q_i p| = Θ(√(d/n_ℓ))`.
//! * Prop 2.5  — zonotope volume `E vol = n!(3/d)^{n/2}/Γ(1+n/2) · Π n_i^{-1/2}`
//!   (Monte-Carlo cross-check in low dimension via the Vitale determinant
//!   identity).
//! * Prop 2.6  — `dim C_τ` of the averaged `p` dominates the mean of the
//!   per-client dimensions (Jensen).

use crate::nn::ArchSpec;
use crate::rng::{Normal, Rng, SeedTree, Xoshiro256pp};
use crate::sparse::QMatrix;

/// Lemma 2.2 closed form.
pub fn expected_nonzero_weights(m: usize, d: usize) -> f64 {
    m as f64 * (1.0 - 0.5f64.powi(d as i32))
}

/// Empirical `#nonzero(Qz)` with `z_j ~ Bern(p_j), p_j ~ U(0,1)`,
/// averaged over `trials` fresh (p, z) draws.
pub fn measure_nonzero_weights(q: &QMatrix, trials: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let mut total = 0usize;
    let mut z = vec![0.0f32; q.n];
    let mut w = vec![0.0f32; q.m];
    for _ in 0..trials {
        for zj in z.iter_mut() {
            let p = rng.next_f64();
            *zj = rng.bernoulli(p) as u8 as f32;
        }
        q.spmv_into(&z, &mut w);
        total += w.iter().filter(|&&x| x != 0.0).count();
    }
    total as f64 / trials as f64
}

/// Lemma 2.3 closed form: expected empty-column fraction `(1 − d/n)^m`
/// (`≈ e^{−d}` at `m = n ≫ d`).
pub fn expected_empty_column_fraction(m: usize, n: usize, d: usize) -> f64 {
    (1.0 - d as f64 / n as f64).powi(m as i32)
}

/// Prop 2.4: maximize `|Q_i p|` over `p ∈ [0,1]^n` (exact: pick the sign
/// class with the larger absolute sum), averaged over rows.  The paper
/// predicts `Θ(√(d/n_ℓ))`.
pub fn mean_max_row_activation(q: &QMatrix) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..q.m {
        let (_, vals) = q.row(i);
        let pos: f64 = vals.iter().filter(|&&v| v > 0.0).map(|&v| v as f64).sum();
        let neg: f64 = vals.iter().filter(|&&v| v < 0.0).map(|&v| -v as f64).sum();
        acc += pos.max(neg);
    }
    acc / q.m as f64
}

/// Prop 2.4's asymptotic constant: `E max = d/2 · σ·√(2/π)` ≤ bound ≤
/// `d·σ·√(2/π)` with `σ = √(6/(d·n_ℓ))` — return the midpoint prediction
/// `0.75·d·σ·√(2/π)` for single-fan-in matrices.
pub fn predicted_max_row_activation(d: usize, fan_in: usize) -> (f64, f64) {
    let sigma = (6.0 / (d as f64 * fan_in as f64)).sqrt();
    let unit = sigma * (2.0 / std::f64::consts::PI).sqrt();
    (0.5 * d as f64 * unit, d as f64 * unit)
}

/// Lemma 2.1: empirical variance of `w = Qp`, `p ~ U(0,1)^n`, for the
/// rows of one fan-in class; the paper predicts `E[p²]·6/n_ℓ = 2/n_ℓ`.
pub fn measure_w_variance(q: &QMatrix, rows: std::ops::Range<usize>, trials: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let mut p = vec![0.0f32; q.n];
    let mut w = vec![0.0f32; q.m];
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    let mut count = 0usize;
    for _ in 0..trials {
        for pj in p.iter_mut() {
            *pj = rng.next_f32();
        }
        q.spmv_into(&p, &mut w);
        for i in rows.clone() {
            let x = w[i] as f64;
            sum += x;
            sumsq += x * x;
            count += 1;
        }
    }
    let mean = sum / count as f64;
    sumsq / count as f64 - mean * mean
}

// ---------------------------------------------------------------------------
// Prop 2.5: zonotope volume.
// ---------------------------------------------------------------------------

/// Closed form of Prop 2.5 for the isotropic case `n_i = fan` for all i:
/// `E vol = n! (3/(dπ))^{n/2} vol(B_n) fan^{-n/2}` with
/// `vol(B_n) = π^{n/2}/Γ(1+n/2)` — i.e. `n!(3/d)^{n/2}/Γ(1+n/2)·fan^{-n/2}`.
pub fn expected_zonotope_volume(n: usize, d: usize, fan: f64) -> f64 {
    let n_f = n as f64;
    ln_factorial(n).exp() * (3.0 / d as f64).powf(n_f / 2.0) / gamma(1.0 + n_f / 2.0)
        * fan.powf(-n_f / 2.0)
}

/// Monte-Carlo estimate of `E vol(Z_Q)` in the exactly-n-generators case:
/// such a zonotope is a parallelepiped, so `vol(Z_Q) = |det Q|` and the
/// paper's closed form (which already folds in Vitale's `n!`) is compared
/// against the plain average of `|det Q|` over fresh draws.
pub fn mc_zonotope_volume(n: usize, d: usize, fan: f64, trials: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from(seed);
    let mut normal = Normal::new();
    let sigma = (6.0 / (d as f64 * fan)).sqrt();
    let mut acc = 0.0f64;
    for _ in 0..trials {
        // n×n dense Gaussian matrix (the d = n case of Eq. 1).
        let mut a: Vec<f64> = (0..n * n).map(|_| normal.sample(&mut rng) * sigma).collect();
        acc += det_abs(&mut a, n);
    }
    acc / trials as f64
}

/// |det| by partial-pivot LU (destroys `a`).
fn det_abs(a: &mut [f64], n: usize) -> f64 {
    let mut det = 1.0f64;
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col] == 0.0 {
            return 0.0;
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
        }
        det *= a[col * n + col];
        let inv = 1.0 / a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] * inv;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
        }
    }
    det.abs()
}

fn ln_factorial(n: usize) -> f64 {
    (1..=n).map(|k| (k as f64).ln()).sum()
}

/// Lanczos gamma (g = 7, n = 9) — plenty for the low dims we cross-check.
pub fn gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// Prop 2.6: `dim C_τ(mean p) ≥ mean_k dim C_τ(p_k)`.
pub fn dim_c_tau(p: &[f32], tau: f32) -> usize {
    p.iter().filter(|&&x| x >= tau && x <= 1.0 - tau).count()
}

/// Average client vectors then compare dimensions (returns lhs, rhs of
/// the proposition).
pub fn jensen_dimension_check(clients: &[Vec<f32>], tau: f32) -> (usize, f64) {
    assert!(!clients.is_empty());
    let n = clients[0].len();
    let mut mean = vec![0.0f32; n];
    for c in clients {
        for (m, &x) in mean.iter_mut().zip(c) {
            *m += x;
        }
    }
    for m in mean.iter_mut() {
        *m /= clients.len() as f32;
    }
    let lhs = dim_c_tau(&mean, tau);
    let rhs =
        clients.iter().map(|c| dim_c_tau(c, tau) as f64).sum::<f64>() / clients.len() as f64;
    (lhs, rhs)
}

/// Generate a square Q for the n = m lemmas on a synthetic single-layer
/// "architecture" with uniform fan-in.
pub fn square_q(n: usize, d: usize, fan_in: usize, seed: u64) -> QMatrix {
    // A fake single-layer arch with m = n params, all fan_in equal:
    // fan_in × (n/fan_in) weights (+ no bias) is awkward; instead reuse
    // the generator directly with a constant fan-in table.
    let arch = ArchSpec::new("square", &[fan_in, n / fan_in]);
    let _ = arch; // (kept simple: the generator below)
    let seeds = SeedTree::new(seed);
    let mut rng = seeds.rng("q-matrix", 0);
    let mut normal = Normal::new();
    let mut rid = Vec::with_capacity(n * d);
    let mut rv = Vec::with_capacity(n * d);
    let mut scratch = Vec::with_capacity(d);
    let sigma = (6.0 / (d as f64 * fan_in as f64)).sqrt();
    for _ in 0..n {
        crate::rng::sample_distinct(&mut rng, n, d, &mut scratch);
        rid.extend_from_slice(&scratch);
        for _ in 0..d {
            rv.push((normal.sample(&mut rng) * sigma) as f32);
        }
    }
    QMatrix { m: n, n, d, rid, rv }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma_2_2_nonzero_count() {
        for d in [1usize, 2, 4] {
            let q = square_q(4096, d, 64, 7);
            let measured = measure_nonzero_weights(&q, 8, 11);
            let expected = expected_nonzero_weights(q.m, d);
            let rel = (measured - expected).abs() / expected;
            assert!(rel < 0.02, "d={d}: measured {measured} expected {expected}");
        }
    }

    #[test]
    fn lemma_2_3_empty_columns() {
        // n = m: fraction ≈ e^{-d}.
        for d in [1usize, 3] {
            let q = square_q(8192, d, 64, 3);
            let frac = q.empty_columns() as f64 / q.n as f64;
            let expected = (-(d as f64)).exp();
            assert!(
                (frac - expected).abs() < 0.02,
                "d={d}: frac {frac} vs e^-d {expected}"
            );
        }
    }

    #[test]
    fn prop_2_4_max_activation_scaling() {
        // measured mean max must sit inside [d/2, d]·σ√(2/π) and scale
        // like √d overall.
        let fan = 128usize;
        let mut prev = 0.0;
        for d in [2usize, 8, 32] {
            let q = square_q(4096, d, fan, 5);
            let measured = mean_max_row_activation(&q);
            let (lo, hi) = predicted_max_row_activation(d, fan);
            assert!(measured >= lo * 0.95 && measured <= hi * 1.05,
                "d={d}: measured {measured} outside [{lo}, {hi}]");
            assert!(measured > prev, "not increasing in d");
            prev = measured;
        }
    }

    #[test]
    fn lemma_2_1_w_variance() {
        let fan = 256usize;
        let d = 16usize;
        let q = square_q(4096, d, fan, 9);
        let var = measure_w_variance(&q, 0..q.m, 6, 13);
        let expected = 2.0 / fan as f64; // E[p²]·6/n_ℓ = (1/3)·6/fan
        assert!((var / expected - 1.0).abs() < 0.1, "var {var} expected {expected}");
    }

    #[test]
    fn prop_2_5_volume_low_dim() {
        // d = n (dense) Gaussian square matrices: E|det Q| = E vol(Z_Q)
        // must match the closed form within MC error for n = 2..4.
        for n in [2usize, 3, 4] {
            let fan = 8.0;
            let mc = mc_zonotope_volume(n, n, fan, 20_000, 17);
            let closed = expected_zonotope_volume(n, n, fan);
            let rel = (mc - closed).abs() / closed;
            assert!(rel < 0.1, "n={n}: mc {mc} closed {closed}");
        }
    }

    #[test]
    fn gamma_known_values() {
        assert!((gamma(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-9);
        assert!((gamma(5.0) - 24.0).abs() < 1e-7);
    }

    #[test]
    fn prop_2_6_jensen() {
        let mut rng = Xoshiro256pp::seed_from(23);
        for _ in 0..20 {
            let clients: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { rng.next_f32() }).collect())
                .collect();
            let (lhs, rhs) = jensen_dimension_check(&clients, 0.05);
            assert!(lhs as f64 >= rhs - 1e-9, "lhs {lhs} < rhs {rhs}");
        }
    }
}
