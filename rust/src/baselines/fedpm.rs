//! FedPM — Isik et al. [13] "Sparse Random Networks for
//! Communication-Efficient Federated Learning".
//!
//! The Table 1 comparator.  Structurally it is Federated Zampling's
//! special case **n = m, d = 1**: the influence matrix is diagonal
//! (`w_i = q_ii · z_i` over frozen random weights), scores pass through a
//! *sigmoid* (their parametrization) rather than the clip, clients uplink
//! 1-bit masks entropy-coded to ≈ 0.95 bits/param, and the server still
//! downlinks floats (hence their server savings ≈ 1×).
//!
//! Implemented against the same executor/dataset substrate so the
//! comparison isolates the protocol, not the plumbing.

use crate::comm::{arith, CommLedger, FloatVec, RoundCost};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::{one_hot_into, ArchSpec};
use crate::rng::{Normal, Rng, SeedTree};
use crate::zampling::{eval_dataset, DenseExecutor, ScoreOptimizer};

/// Frozen diagonal "Q": one Kaiming-He random weight per parameter.
pub struct DiagonalQ {
    pub weights: Vec<f32>,
}

impl DiagonalQ {
    pub fn generate(arch: &ArchSpec, seeds: &SeedTree) -> Self {
        let mut rng = seeds.rng("fedpm-q", 0);
        let mut normal = Normal::new();
        let fan_in = arch.fan_in_table();
        let weights = (0..arch.num_params())
            .map(|i| {
                // d = 1 in Eq. (1): σ² = 6 / fan_in.
                let sigma = (6.0 / fan_in[i] as f64).sqrt();
                (normal.sample(&mut rng) * sigma) as f32
            })
            .collect();
        Self { weights }
    }

    /// `w = diag(q) · z`.
    pub fn apply(&self, mask: &[bool], out: &mut [f32]) {
        for ((o, &q), &b) in out.iter_mut().zip(&self.weights).zip(mask) {
            *o = if b { q } else { 0.0 };
        }
    }

    /// Expected network `w = diag(q) · p`.
    pub fn apply_probs(&self, probs: &[f32], out: &mut [f32]) {
        for ((o, &q), &p) in out.iter_mut().zip(&self.weights).zip(probs) {
            *o = q * p;
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

pub struct FedPmOutcome {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub final_probs: Vec<f32>,
    /// Mean uplink bits per parameter over the run (their "bit-rate").
    pub uplink_bits_per_param: f64,
}

/// Run FedPM: sigmoid-score training-by-pruning + entropy-coded masks.
pub fn run_fedpm(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_samples: usize,
    eval_every: usize,
) -> FedPmOutcome {
    assert_eq!(shards.len(), cfg.clients);
    let seeds = SeedTree::new(cfg.train.seed);
    let arch = exec.arch().clone();
    let m = arch.num_params();
    let q = DiagonalQ::generate(&arch, &seeds);

    // Server probabilities start uniform (their Bern(0.5)-ish init).
    let mut probs: Vec<f32> = {
        let mut r = seeds.rng("fedpm-p-init", 0);
        (0..m).map(|_| r.next_f32()).collect()
    };

    let out_dim = arch.output_dim();
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);

    let mut log = RunLog::new("fedpm");
    let mut ledger = CommLedger::default();
    let mut grad = vec![0.0f32; m];
    let mut w = vec![0.0f32; m];
    let mut y1h_buf: Vec<f32> = Vec::new();
    let mut mask = vec![false; m];
    let mut eval_rng = seeds.rng("fedpm-eval", 0);

    for round in 0..cfg.rounds {
        let down_bytes = FloatVec::encode(&probs).len();
        let mut up_bytes_total = 0usize;
        let mut acc_ones = vec![0u32; m];
        let mut round_loss = 0.0f64;

        for (k, shard) in shards.iter().enumerate() {
            // Client: scores are logits of the received probabilities.
            let mut scores: Vec<f32> = probs.iter().map(|&p| logit(p)).collect();
            let mut opt = ScoreOptimizer::new(cfg.train.optimizer, cfg.train.lr, m);
            let mut rng = seeds.subtree("client", k as u64).rng("fedpm-round", round as u64);

            for _ in 0..cfg.local_epochs {
                let mut loss_sum = 0.0f64;
                let mut rows_sum = 0usize;
                for b in shard.batches(exec.train_batch().min(cfg.train.batch), &mut rng) {
                    let rows = b.y.len();
                    if y1h_buf.len() < rows * out_dim {
                        y1h_buf.resize(rows * out_dim, 0.0);
                    }
                    one_hot_into(&b.y, out_dim, &mut y1h_buf);
                    // Sample mask from sigmoid(scores), build w, step.
                    for (mi, &s) in mask.iter_mut().zip(&scores) {
                        *mi = rng.next_f32() < sigmoid(s);
                    }
                    q.apply(&mask, &mut w);
                    let r = exec.train_step(&w, &b.x, &y1h_buf[..rows * out_dim], rows, &mut grad);
                    // Straight-through: ∂w/∂s = q · σ'(s).
                    for i in 0..m {
                        let sg = sigmoid(scores[i]);
                        grad[i] *= q.weights[i] * sg * (1.0 - sg);
                    }
                    opt.step(&mut grad);
                    for (s, g) in scores.iter_mut().zip(&grad) {
                        *s -= g;
                    }
                    loss_sum += r.loss as f64 * rows as f64;
                    rows_sum += rows;
                }
                round_loss = loss_sum / rows_sum.max(1) as f64;
            }

            // Uplink: one Bernoulli(σ(s)) sample, arithmetic-coded.
            for (mi, &s) in mask.iter_mut().zip(&scores) {
                *mi = rng.next_f32() < sigmoid(s);
            }
            up_bytes_total += arith::encode(&mask).len();
            for (a, &b) in acc_ones.iter_mut().zip(mask.iter()) {
                *a += b as u32;
            }
        }

        for (p, &a) in probs.iter_mut().zip(&acc_ones) {
            *p = a as f32 / cfg.clients as f32;
        }
        ledger.record(RoundCost {
            downlink_bits: down_bytes as u64 * 8 * cfg.clients as u64,
            uplink_bits: up_bytes_total as u64 * 8,
            clients: cfg.clients as u32,
            participants: cfg.clients as u32,
            dropped: 0,
            // Sequentially-simulated clients: wall-clock would measure
            // this process's compute, not transport rate — unmeasured.
            wall_ns: 0,
        });

        if round % eval_every == 0 || round + 1 == cfg.rounds {
            // Mean sampled accuracy like the Zampling eval.
            let mut accs = crate::metrics::Summary::default();
            for _ in 0..eval_samples {
                for (mi, &p) in mask.iter_mut().zip(&probs) {
                    *mi = eval_rng.next_f32() < p;
                }
                q.apply(&mask, &mut w);
                let (_, acc) = eval_dataset(exec, &w, &test.x, &test_y1h, test.len());
                accs.push(acc);
            }
            q.apply_probs(&probs, &mut w);
            let (_, expected) = eval_dataset(exec, &w, &test.x, &test_y1h, test.len());
            log.push(RoundRecord {
                round,
                mean_sampled_acc: accs.mean(),
                sampled_acc_std: accs.std(),
                expected_acc: expected,
                train_loss: round_loss,
                uplink_bits: up_bytes_total as u64 * 8,
                downlink_bits: down_bytes as u64 * 8 * cfg.clients as u64,
            });
        }
    }

    let total_up = ledger.total_uplink_bits() as f64;
    let uplink_bits_per_param =
        total_up / (cfg.rounds as f64 * cfg.clients as f64 * m as f64);
    FedPmOutcome { log, ledger, final_probs: probs, uplink_bits_per_param }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zampling::NativeExecutor;

    #[test]
    fn fedpm_learns_with_subbit_uplink() {
        let mut cfg = FedConfig::paper(1);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = ArchSpec::small().num_params();
        cfg.train.d = 1;
        cfg.train.lr = 0.1;
        cfg.clients = 3;
        cfg.rounds = 5;
        let seeds = SeedTree::new(2);
        let (train, test) = Dataset::synthetic_pair(900, 256, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 256);
        let out = run_fedpm(&cfg, &mut exec, &shards, &test, 4, 2);

        let first = out.log.rounds.first().unwrap().mean_sampled_acc;
        let last = out.log.rounds.last().unwrap().mean_sampled_acc;
        assert!(last > first, "{first} → {last}");
        // Uplink ≈ 1 bit/param → client savings ≈ 32 (Isik's 33.69 with
        // their slightly-below-1 bit-rate).
        let rep = out.ledger.savings(cfg.train.arch.num_params());
        assert!(rep.client_savings > 25.0, "{rep:?}");
        assert!(out.uplink_bits_per_param < 1.1, "{}", out.uplink_bits_per_param);
        // Server still ships floats → ~1× server savings.
        assert!(rep.server_savings < 1.2, "{rep:?}");
    }

    #[test]
    fn diagonal_q_matches_eq1_variance() {
        let arch = ArchSpec::small();
        let q = DiagonalQ::generate(&arch, &SeedTree::new(3));
        let first_layer = 784 * 20;
        let vals = &q.weights[..first_layer];
        let var: f64 = vals.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / vals.len() as f64;
        let expect = 6.0 / 784.0;
        assert!((var / expect - 1.0).abs() < 0.1, "var={var} expect={expect}");
    }
}
