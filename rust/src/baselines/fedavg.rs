//! FedAvg (McMahan et al.) — the naive dense baseline.
//!
//! Per round: server broadcasts the full weight vector `w` as floats
//! (`32m` bits/client down), each client runs local SGD epochs on its
//! shard, uplinks its full updated weights (`32m` bits up), and the
//! server averages.  This is the denominator of every savings factor in
//! Table 1.

use crate::comm::{CommLedger, FloatVec, RoundCost};
use crate::config::FedConfig;
use crate::data::Dataset;
use crate::metrics::{RoundRecord, RunLog};
use crate::nn::one_hot_into;
use crate::rng::{Normal, SeedTree};
use crate::zampling::{eval_dataset, DenseExecutor};

pub struct FedAvgOutcome {
    pub log: RunLog,
    pub ledger: CommLedger,
    pub final_weights: Vec<f32>,
}

/// He-normal initial weights from the shared seed.
pub fn init_weights(arch: &crate::nn::ArchSpec, seeds: &SeedTree) -> Vec<f32> {
    let mut rng = seeds.rng("fedavg-init", 0);
    let mut normal = Normal::new();
    let mut w = vec![0.0f32; arch.num_params()];
    for s in arch.slices() {
        let std = (2.0 / s.fan_in as f64).sqrt();
        for i in 0..s.w_len {
            w[s.offset + i] = (normal.sample(&mut rng) * std) as f32;
        }
    }
    w
}

/// Run FedAvg with plain local SGD (lr from the config).
pub fn run_fedavg(
    cfg: &FedConfig,
    exec: &mut dyn DenseExecutor,
    shards: &[Dataset],
    test: &Dataset,
    eval_every: usize,
) -> FedAvgOutcome {
    assert_eq!(shards.len(), cfg.clients);
    let seeds = SeedTree::new(cfg.train.seed);
    let arch = exec.arch().clone();
    let m = arch.num_params();
    let mut w_global = init_weights(&arch, &seeds);

    let out_dim = arch.output_dim();
    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);

    let mut log = RunLog::new("fedavg");
    let mut ledger = CommLedger::default();
    let mut grad = vec![0.0f32; m];
    let mut y1h_buf: Vec<f32> = Vec::new();

    for round in 0..cfg.rounds {
        let mut w_sum = vec![0.0f32; m];
        let mut round_loss = 0.0f64;
        // Wire cost: serialize once to measure honestly.
        let down_bytes = FloatVec::encode(&w_global).len();
        let mut up_bytes_total = 0usize;

        for (k, shard) in shards.iter().enumerate() {
            let mut w_local = FloatVec::decode(&FloatVec::encode(&w_global));
            let mut epoch_rng = seeds.subtree("client", k as u64).rng("fedavg-epoch", round as u64);
            let lr = cfg.train.lr as f32;
            for _ in 0..cfg.local_epochs {
                let mut loss_sum = 0.0f64;
                let mut rows = 0usize;
                for b in shard.batches(exec.train_batch().min(cfg.train.batch), &mut epoch_rng) {
                    let br = b.y.len();
                    if y1h_buf.len() < br * out_dim {
                        y1h_buf.resize(br * out_dim, 0.0);
                    }
                    one_hot_into(&b.y, out_dim, &mut y1h_buf);
                    let r = exec.train_step(&w_local, &b.x, &y1h_buf[..br * out_dim], br, &mut grad);
                    for (wi, gi) in w_local.iter_mut().zip(&grad) {
                        *wi -= lr * gi;
                    }
                    loss_sum += r.loss as f64 * br as f64;
                    rows += br;
                }
                round_loss = loss_sum / rows.max(1) as f64;
            }
            let up = FloatVec::encode(&w_local);
            up_bytes_total += up.len();
            let w_back = FloatVec::decode(&up);
            for (s, v) in w_sum.iter_mut().zip(&w_back) {
                *s += v;
            }
        }
        for (g, s) in w_global.iter_mut().zip(&w_sum) {
            *g = s / cfg.clients as f32;
        }
        ledger.record(RoundCost {
            downlink_bits: down_bytes as u64 * 8 * cfg.clients as u64,
            uplink_bits: up_bytes_total as u64 * 8,
            clients: cfg.clients as u32,
            participants: cfg.clients as u32,
            dropped: 0,
            // Sequentially-simulated clients: wall-clock would measure
            // this process's compute, not transport rate — unmeasured.
            wall_ns: 0,
        });

        if round % eval_every == 0 || round + 1 == cfg.rounds {
            let (loss, acc) = eval_dataset(exec, &w_global, &test.x, &test_y1h, test.len());
            log.push(RoundRecord {
                round,
                mean_sampled_acc: acc, // deterministic network: no sampling
                sampled_acc_std: 0.0,
                expected_acc: acc,
                train_loss: if round_loss.is_finite() { round_loss } else { loss },
                uplink_bits: up_bytes_total as u64 * 8,
                downlink_bits: down_bytes as u64 * 8 * cfg.clients as u64,
            });
        }
    }

    FedAvgOutcome { log, ledger, final_weights: w_global }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    #[test]
    fn fedavg_learns_and_has_unit_savings() {
        let mut cfg = FedConfig::paper(1);
        cfg.train.arch = ArchSpec::small();
        cfg.train.n = cfg.train.arch.num_params();
        cfg.train.lr = 0.1;
        cfg.clients = 3;
        cfg.rounds = 5;
        let seeds = SeedTree::new(0);
        let (train, test) = Dataset::synthetic_pair(900, 300, &seeds);
        let shards = train.partition_iid(cfg.clients, &seeds);
        let mut exec = NativeExecutor::new(cfg.train.arch.clone(), cfg.train.batch, 300);
        let out = run_fedavg(&cfg, &mut exec, &shards, &test, 1);
        let first = out.log.rounds.first().unwrap().expected_acc;
        let last = out.log.rounds.last().unwrap().expected_acc;
        assert!(last > first, "{first} → {last}");
        let rep = out.ledger.savings(cfg.train.arch.num_params());
        assert!((rep.client_savings - 1.0).abs() < 0.01, "{rep:?}");
        assert!((rep.server_savings - 1.0).abs() < 0.01, "{rep:?}");
    }
}
