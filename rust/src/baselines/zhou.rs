//! Zhou et al. [31] supermask training — the Fig. 6 comparator.
//!
//! Local (centralized) training-by-pruning: a frozen random diagonal
//! weight bank, a trainable score per weight squashed by a **sigmoid**
//! into a sampling probability, a fresh mask per batch, straight-through
//! gradients.  Equivalent to Local Zampling at n = m, d = 1 modulo the
//! sigmoid-vs-clip parametrization (paper footnote 5).  Reported metric
//! in Fig. 6 is **best mask** over 100 end-of-training samples.

use super::fedpm::DiagonalQ;
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::metrics::Summary;
use crate::nn::one_hot_into;
use crate::rng::{Rng, SeedTree};
use crate::zampling::{eval_dataset, DenseExecutor, ScoreOptimizer};

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub struct ZhouOutcome {
    pub mean_sampled_acc: f64,
    pub sampled_acc_std: f64,
    pub best_mask_acc: f64,
    pub expected_acc: f64,
}

/// Train a supermask locally and evaluate like §B.1 (best of
/// `eval_samples` masks).
pub fn train_zhou(
    cfg: &TrainConfig,
    exec: &mut dyn DenseExecutor,
    train: &Dataset,
    test: &Dataset,
    eval_samples: usize,
) -> ZhouOutcome {
    let seeds = SeedTree::new(cfg.seed);
    let arch = exec.arch().clone();
    let m = arch.num_params();
    let q = DiagonalQ::generate(&arch, &seeds);

    // Scores init at 0 → p = 0.5 everywhere (their uniform-mask start).
    let mut scores = vec![0.0f32; m];
    let mut opt = ScoreOptimizer::new(cfg.optimizer, cfg.lr, m);
    let mut rng = seeds.rng("zhou-train", 0);

    let out_dim = arch.output_dim();
    let mut y1h_buf: Vec<f32> = Vec::new();
    let mut mask = vec![false; m];
    let mut w = vec![0.0f32; m];
    let mut grad = vec![0.0f32; m];

    let mut test_y1h = vec![0.0f32; test.len() * out_dim];
    one_hot_into(&test.y, out_dim, &mut test_y1h);

    let mut best_val = f64::INFINITY;
    let mut stale = 0usize;
    for _epoch in 0..cfg.epochs {
        for b in train.batches(exec.train_batch().min(cfg.batch), &mut rng) {
            let rows = b.y.len();
            if y1h_buf.len() < rows * out_dim {
                y1h_buf.resize(rows * out_dim, 0.0);
            }
            one_hot_into(&b.y, out_dim, &mut y1h_buf);
            for (mi, &s) in mask.iter_mut().zip(&scores) {
                *mi = rng.next_f32() < sigmoid(s);
            }
            q.apply(&mask, &mut w);
            exec.train_step(&w, &b.x, &y1h_buf[..rows * out_dim], rows, &mut grad);
            for i in 0..m {
                let sg = sigmoid(scores[i]);
                grad[i] *= q.weights[i] * sg * (1.0 - sg);
            }
            opt.step(&mut grad);
            for (s, g) in scores.iter_mut().zip(&grad) {
                *s -= g;
            }
        }
        // Early stopping on the expected network's validation loss.
        let probs: Vec<f32> = scores.iter().map(|&s| sigmoid(s)).collect();
        q.apply_probs(&probs, &mut w);
        let (val_loss, _) = eval_dataset(exec, &w, &test.x, &test_y1h, test.len());
        if val_loss < best_val - cfg.min_delta {
            best_val = val_loss;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    // Evaluation: sample `eval_samples` masks, report mean/std/best.
    let probs: Vec<f32> = scores.iter().map(|&s| sigmoid(s)).collect();
    let mut eval_rng = seeds.rng("zhou-eval", 0);
    let mut accs = Summary::default();
    let mut best = 0.0f64;
    for _ in 0..eval_samples {
        for (mi, &p) in mask.iter_mut().zip(&probs) {
            *mi = eval_rng.next_f32() < p;
        }
        q.apply(&mask, &mut w);
        let (_, acc) = eval_dataset(exec, &w, &test.x, &test_y1h, test.len());
        accs.push(acc);
        best = best.max(acc);
    }
    q.apply_probs(&probs, &mut w);
    let (_, expected) = eval_dataset(exec, &w, &test.x, &test_y1h, test.len());

    ZhouOutcome {
        mean_sampled_acc: accs.mean(),
        sampled_acc_std: accs.std(),
        best_mask_acc: best,
        expected_acc: expected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::zampling::NativeExecutor;

    #[test]
    fn zhou_supermask_learns_above_chance() {
        let mut cfg = TrainConfig::local(ArchSpec::small(), 1, 1, 0).ci();
        cfg.lr = 0.1;
        cfg.epochs = 6;
        cfg.train_rows = 768;
        cfg.test_rows = 256;
        let seeds = SeedTree::new(cfg.seed);
        let (train, test) = Dataset::synthetic_pair(cfg.train_rows, cfg.test_rows, &seeds);
        let mut exec = NativeExecutor::new(cfg.arch.clone(), cfg.batch, 256);
        let out = train_zhou(&cfg, &mut exec, &train, &test, 8);
        assert!(out.best_mask_acc > 0.3, "best {}", out.best_mask_acc);
        assert!(out.best_mask_acc >= out.mean_sampled_acc);
    }
}
