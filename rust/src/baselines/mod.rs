//! Baselines the paper compares against.
//!
//! * [`fedavg`] — the naive protocol: floats both directions (the "1×"
//!   row every savings factor in Table 1 is measured against).
//! * [`fedpm`] — Isik et al. [13]: training-by-pruning with a *diagonal*
//!   Q (n = m, d = 1), 1-bit uplink masks + arithmetic coding, float
//!   downlink.  The paper's Table 1 comparator (33.69× client savings).
//! * [`zhou`] — Zhou et al. [31] supermask training: the Local-Zampling
//!   special case n = m, d = 1 with *sigmoid* scores instead of the clip
//!   (Fig. 6's comparator).

pub mod fedavg;
pub mod fedpm;
pub mod zhou;
