//! Post-training compression of `(Q, p)` — the paper's §4 conjecture:
//! *"we can remove the columns of Q related to trivial p̄s, and reduce the
//! rows of Q when weights are summed to 0. We conjecture this will
//! decrease further the communication cost."*
//!
//! Columns split three ways by the trained probabilities:
//! * `p_j ≤ τ`      — the mask bit is (almost surely) 0: drop the column;
//! * `p_j ≥ 1 − τ`  — the bit is (almost surely) 1: fold `q_{·j}` into a
//!   fixed weight offset `w_fix` that no longer needs a bit;
//! * otherwise      — keep: this is a live coordinate of `C_τ`.
//!
//! The pruned model transmits only `n' = |live|` bits per round, and the
//! reconstruction becomes `w = w_fix + Q' z'`.  [`PrunedModel::residual`]
//! quantifies the (probabilistic) approximation error of the freeze.

use super::QMatrix;

/// Result of pruning `(Q, p)` at threshold `τ`.
pub struct PrunedModel {
    /// Reduced matrix over the live columns only (column ids remapped).
    pub q: QMatrix,
    /// Fixed weight contribution from the frozen-at-1 columns.
    pub w_fix: Vec<f32>,
    /// For each live column, its original index.
    pub live_cols: Vec<u32>,
    /// Live probabilities (the reduced trainable vector).
    pub probs: Vec<f32>,
    /// Columns frozen at 1 / dropped at 0 (diagnostics).
    pub frozen_one: usize,
    pub frozen_zero: usize,
}

impl QMatrix {
    /// Prune trivial columns at threshold `τ` (Definition 2.2's
    /// complement).  `probs.len()` must equal `n`.
    pub fn prune(&self, probs: &[f32], tau: f32) -> PrunedModel {
        assert_eq!(probs.len(), self.n);
        assert!((0.0..0.5).contains(&tau), "need 0 ≤ τ < 0.5");
        // Classify columns.
        #[derive(Clone, Copy, PartialEq)]
        enum Class {
            Zero,
            One,
            Live(u32),
        }
        let mut classes = Vec::with_capacity(self.n);
        let mut live_cols = Vec::new();
        let mut live_probs = Vec::new();
        for (j, &p) in probs.iter().enumerate() {
            if p <= tau {
                classes.push(Class::Zero);
            } else if p >= 1.0 - tau {
                classes.push(Class::One);
            } else {
                classes.push(Class::Live(live_cols.len() as u32));
                live_cols.push(j as u32);
                live_probs.push(p);
            }
        }
        let n_live = live_cols.len();

        // Rebuild the row layout over live columns; fold ones into w_fix.
        // Rows keep a ragged count here, so the reduced matrix stores a
        // uniform degree again by padding with (live col 0, value 0.0) —
        // the same inert-padding trick as the CSC.
        let mut w_fix = vec![0.0f32; self.m];
        let mut rows: Vec<Vec<(u32, f32)>> = vec![Vec::new(); self.m];
        let mut max_deg = 0usize;
        for i in 0..self.m {
            let (ids, vals) = self.row(i);
            for (k, &j) in ids.iter().enumerate() {
                match classes[j as usize] {
                    Class::Zero => {}
                    Class::One => w_fix[i] += vals[k],
                    Class::Live(new_j) => rows[i].push((new_j, vals[k])),
                }
            }
            max_deg = max_deg.max(rows[i].len());
        }
        let d2 = max_deg.max(1);
        let mut rid = Vec::with_capacity(self.m * d2);
        let mut rv = Vec::with_capacity(self.m * d2);
        for row in &rows {
            for &(j, v) in row {
                rid.push(j);
                rv.push(v);
            }
            for _ in row.len()..d2 {
                rid.push(0);
                rv.push(0.0);
            }
        }

        PrunedModel {
            q: QMatrix { m: self.m, n: n_live.max(1), d: d2, rid, rv },
            w_fix,
            live_cols,
            probs: live_probs,
            frozen_one: classes.iter().filter(|&&c| c == Class::One).count(),
            frozen_zero: classes.iter().filter(|&&c| c == Class::Zero).count(),
        }
    }
}

impl PrunedModel {
    /// Live (transmitted) coordinate count `n'`.
    pub fn n_live(&self) -> usize {
        self.live_cols.len()
    }

    /// Reconstruct `w = w_fix + Q' z'` for a live-coordinate mask.
    pub fn reconstruct(&self, z_live: &[f32], w: &mut [f32]) {
        assert_eq!(z_live.len().max(1), self.q.n);
        if self.live_cols.is_empty() {
            w.copy_from_slice(&self.w_fix);
            return;
        }
        self.q.spmv_into(z_live, w);
        for (wi, &f) in w.iter_mut().zip(&self.w_fix) {
            *wi += f;
        }
    }

    /// Extra uplink savings factor vs the unpruned protocol.
    pub fn extra_savings(&self, n_original: usize) -> f64 {
        n_original as f64 / self.n_live().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::rng::{Rng, SeedTree, Xoshiro256pp};

    fn setup(tau: f32) -> (QMatrix, Vec<f32>, PrunedModel) {
        let arch = ArchSpec::small();
        let q = QMatrix::generate(&arch, 512, 4, &SeedTree::new(3));
        let mut r = Xoshiro256pp::seed_from(4);
        // Trained-looking p: most coordinates saturated.
        let probs: Vec<f32> = (0..512)
            .map(|_| match r.next_below(10) {
                0..=3 => 0.0,
                4..=7 => 1.0,
                _ => 0.2 + 0.6 * r.next_f32(),
            })
            .collect();
        let pruned = q.prune(&probs, tau);
        (q, probs, pruned)
    }

    #[test]
    fn exact_reconstruction_when_trivials_are_hard() {
        // With τ = 0: only exactly-0/1 columns freeze, so for any mask
        // consistent with the frozen bits, reconstruction is exact.
        let (q, probs, pruned) = setup(0.0);
        let mut r = Xoshiro256pp::seed_from(5);
        let mut z_full = vec![0.0f32; q.n];
        let mut z_live = vec![0.0f32; pruned.n_live()];
        for (k, &j) in pruned.live_cols.iter().enumerate() {
            let bit = r.bernoulli(probs[j as usize] as f64) as u8 as f32;
            z_live[k] = bit;
            z_full[j as usize] = bit;
        }
        for (j, &p) in probs.iter().enumerate() {
            if p >= 1.0 {
                z_full[j] = 1.0;
            }
        }
        let mut w_a = vec![0.0f32; q.m];
        let mut w_b = vec![0.0f32; q.m];
        q.spmv_into(&z_full, &mut w_a);
        pruned.reconstruct(&z_live, &mut w_b);
        for (a, b) in w_a.iter().zip(&w_b) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn savings_track_trivial_fraction() {
        let (_, probs, pruned) = setup(0.05);
        let live_expected =
            probs.iter().filter(|&&p| p > 0.05 && p < 0.95).count();
        assert_eq!(pruned.n_live(), live_expected);
        assert!(pruned.extra_savings(512) > 2.0, "{}", pruned.extra_savings(512));
        assert_eq!(pruned.frozen_zero + pruned.frozen_one + pruned.n_live(), 512);
    }

    #[test]
    fn all_columns_frozen_degenerates_gracefully() {
        let arch = ArchSpec::small();
        let q = QMatrix::generate(&arch, 64, 3, &SeedTree::new(6));
        let probs = vec![1.0f32; 64];
        let pruned = q.prune(&probs, 0.1);
        assert_eq!(pruned.n_live(), 0);
        let mut w_fix_check = vec![0.0f32; q.m];
        q.spmv_into(&vec![1.0; 64], &mut w_fix_check);
        let mut w = vec![0.0f32; q.m];
        pruned.reconstruct(&[], &mut w);
        assert_eq!(w, w_fix_check);
    }

    #[test]
    #[should_panic(expected = "0 ≤ τ < 0.5")]
    fn rejects_bad_tau() {
        let arch = ArchSpec::small();
        let q = QMatrix::generate(&arch, 8, 2, &SeedTree::new(7));
        q.prune(&vec![0.5; 8], 0.5);
    }
}
