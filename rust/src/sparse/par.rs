//! Multi-threaded sparse products (std scoped threads; no rayon offline).
//!
//! Row-parallel `spmv` and column-parallel `spmv_t`: both products are
//! embarrassingly parallel over their output dimension, so the splits are
//! contiguous output chunks with zero synchronization beyond the join.
//! The L3 perf pass (EXPERIMENTS.md §Perf) benchmarks these against the
//! serial kernels; they win only for the MnistFc-scale `m`.

use super::{CscView, QMatrix};

/// Threads to use: capped so coordination overhead never dominates the
/// small-arch configs.
fn threads_for(work_items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    // ~64k gather-accumulates per thread amortizes spawn cost.
    hw.min(work_items / 65_536).max(1)
}

/// Parallel `w = Q z`.
pub fn spmv_par_into(q: &QMatrix, z: &[f32], w: &mut [f32]) {
    assert_eq!(z.len(), q.n);
    assert_eq!(w.len(), q.m);
    let nt = threads_for(q.nnz());
    if nt <= 1 {
        q.spmv_into(z, w);
        return;
    }
    let chunk = q.m.div_ceil(nt);
    let d = q.d;
    std::thread::scope(|scope| {
        for (t, w_chunk) in w.chunks_mut(chunk).enumerate() {
            let start = t * chunk;
            let rid = &q.rid;
            let rv = &q.rv;
            scope.spawn(move || {
                for (i_local, wi) in w_chunk.iter_mut().enumerate() {
                    let i = start + i_local;
                    let ids = &rid[i * d..(i + 1) * d];
                    let vals = &rv[i * d..(i + 1) * d];
                    let mut acc = 0.0f32;
                    for k in 0..d {
                        acc += vals[k] * z[ids[k] as usize];
                    }
                    *wi = acc;
                }
            });
        }
    });
}

/// Parallel `g_s = Qᵀ g_w`.
pub fn spmv_t_par_into(csc: &CscView, g_w: &[f32], g_s: &mut [f32]) {
    assert_eq!(g_s.len(), csc.n);
    let nnz: usize = csc.degrees.iter().map(|&x| x as usize).sum();
    let nt = threads_for(nnz);
    if nt <= 1 {
        csc.spmv_t_into(g_w, g_s);
        return;
    }
    let chunk = csc.n.div_ceil(nt);
    let c = csc.c;
    std::thread::scope(|scope| {
        for (t, gs_chunk) in g_s.chunks_mut(chunk).enumerate() {
            let start = t * chunk;
            let cid = &csc.cid;
            let cv = &csc.cv;
            let degrees = &csc.degrees;
            scope.spawn(move || {
                for (j_local, gj) in gs_chunk.iter_mut().enumerate() {
                    let j = start + j_local;
                    let deg = degrees[j] as usize;
                    let ids = &cid[j * c..j * c + deg];
                    let vals = &cv[j * c..j * c + deg];
                    let mut acc = 0.0f32;
                    for k in 0..deg {
                        acc += vals[k] * g_w[ids[k] as usize];
                    }
                    *gj = acc;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::rng::{Rng, SeedTree, Xoshiro256pp};

    #[test]
    fn parallel_matches_serial() {
        let arch = ArchSpec::mnistfc();
        let q = QMatrix::generate(&arch, arch.num_params() / 16, 6, &SeedTree::new(21));
        let csc = q.to_csc(None);
        let mut r = Xoshiro256pp::seed_from(22);
        let z: Vec<f32> = (0..q.n).map(|_| r.next_f32()).collect();
        let g: Vec<f32> = (0..q.m).map(|_| r.next_f32() - 0.5).collect();

        let mut w_ser = vec![0.0; q.m];
        let mut w_par = vec![0.0; q.m];
        q.spmv_into(&z, &mut w_ser);
        spmv_par_into(&q, &z, &mut w_par);
        assert_eq!(w_ser, w_par);

        let mut s_ser = vec![0.0; q.n];
        let mut s_par = vec![0.0; q.n];
        csc.spmv_t_into(&g, &mut s_ser);
        spmv_t_par_into(&csc, &g, &mut s_par);
        assert_eq!(s_ser, s_par);
    }

    #[test]
    fn parallel_small_input_falls_back() {
        let arch = ArchSpec::new("tiny", &[4, 3, 2]);
        let q = QMatrix::generate(&arch, 10, 2, &SeedTree::new(1));
        let z = vec![0.5; 10];
        let mut w = vec![0.0; q.m];
        spmv_par_into(&q, &z, &mut w); // must not panic on tiny sizes
        assert_eq!(w, q.spmv(&z));
    }
}
