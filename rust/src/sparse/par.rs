//! Pool-parallel sparse products.
//!
//! Row-parallel `spmv` (float and bitset masks) and column-parallel
//! `spmv_t`: all three products are embarrassingly parallel over their
//! output dimension, so the splits are contiguous output chunks with zero
//! synchronization beyond the pool latch.  Each chunk runs the *same*
//! row/column core as the serial kernels (`QMatrix::spmv_rows` etc.), so
//! parallel results are bit-identical to serial ones.
//!
//! Shards dispatch onto [`pool::global`] — the persistent worker pool —
//! instead of the seed's per-call `std::thread::scope`, which spent
//! ~50–100 µs spawning threads per product (comparable to the product
//! itself at MnistFc scale).  Sizing comes from [`pool::threads_for`]:
//! ~64k gather-accumulates per shard, so small-arch configs stay serial.

use super::{CscView, QMatrix};
use crate::runtime::pool;

/// Parallel `w = Q z`.
pub fn spmv_par_into(q: &QMatrix, z: &[f32], w: &mut [f32]) {
    assert_eq!(z.len(), q.n);
    assert_eq!(w.len(), q.m);
    let nt = pool::threads_for(q.nnz());
    if nt <= 1 {
        q.spmv_rows(z, w, 0);
        return;
    }
    let chunk = q.m.div_ceil(nt);
    pool::global().run_chunks(nt, w, chunk, |w_chunk, row0| q.spmv_rows(z, w_chunk, row0));
}

/// Parallel `w = Q z` for a bitset mask (the sampled-regime hot path).
pub fn spmv_bits_par_into(q: &QMatrix, bits: &[u64], w: &mut [f32]) {
    assert!(bits.len() * 64 >= q.n);
    assert_eq!(w.len(), q.m);
    let nt = pool::threads_for(q.nnz());
    if nt <= 1 {
        q.spmv_bits_rows(bits, w, 0);
        return;
    }
    let chunk = q.m.div_ceil(nt);
    pool::global()
        .run_chunks(nt, w, chunk, |w_chunk, row0| q.spmv_bits_rows(bits, w_chunk, row0));
}

/// Parallel `g_s = Qᵀ g_w`.
pub fn spmv_t_par_into(csc: &CscView, g_w: &[f32], g_s: &mut [f32]) {
    assert_eq!(g_s.len(), csc.n);
    let nnz: usize = csc.degrees.iter().map(|&x| x as usize).sum();
    let nt = pool::threads_for(nnz);
    if nt <= 1 {
        csc.spmv_t_cols(g_w, g_s, 0);
        return;
    }
    let chunk = csc.n.div_ceil(nt);
    pool::global()
        .run_chunks(nt, g_s, chunk, |gs_chunk, col0| csc.spmv_t_cols(g_w, gs_chunk, col0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ArchSpec;
    use crate::rng::{Rng, SeedTree, Xoshiro256pp};

    #[test]
    fn parallel_matches_serial() {
        let arch = ArchSpec::mnistfc();
        let q = QMatrix::generate(&arch, arch.num_params() / 16, 6, &SeedTree::new(21));
        let csc = q.to_csc(None);
        let mut r = Xoshiro256pp::seed_from(22);
        let z: Vec<f32> = (0..q.n).map(|_| r.next_f32()).collect();
        let g: Vec<f32> = (0..q.m).map(|_| r.next_f32() - 0.5).collect();

        let mut w_ser = vec![0.0; q.m];
        let mut w_par = vec![0.0; q.m];
        q.spmv_into(&z, &mut w_ser);
        spmv_par_into(&q, &z, &mut w_par);
        assert_eq!(w_ser, w_par);

        let mut s_ser = vec![0.0; q.n];
        let mut s_par = vec![0.0; q.n];
        csc.spmv_t_into(&g, &mut s_ser);
        spmv_t_par_into(&csc, &g, &mut s_par);
        assert_eq!(s_ser, s_par);
    }

    #[test]
    fn parallel_bits_matches_serial_bits() {
        let arch = ArchSpec::mnistfc();
        let q = QMatrix::generate(&arch, arch.num_params() / 8, 10, &SeedTree::new(31));
        let mut r = Xoshiro256pp::seed_from(32);
        let mut bits = vec![0u64; q.n.div_ceil(64)];
        for j in 0..q.n {
            if r.bernoulli(0.5) {
                bits[j >> 6] |= 1 << (j & 63);
            }
        }
        let mut w_ser = vec![0.0; q.m];
        let mut w_par = vec![0.0; q.m];
        q.spmv_bits_into(&bits, &mut w_ser);
        spmv_bits_par_into(&q, &bits, &mut w_par);
        assert_eq!(w_ser, w_par);
    }

    #[test]
    fn parallel_small_input_falls_back() {
        let arch = ArchSpec::new("tiny", &[4, 3, 2]);
        let q = QMatrix::generate(&arch, 10, 2, &SeedTree::new(1));
        let z = vec![0.5; 10];
        let mut w = vec![0.0; q.m];
        spmv_par_into(&q, &z, &mut w); // must not panic on tiny sizes
        assert_eq!(w, q.spmv(&z));

        let mut bits = vec![u64::MAX; 1];
        bits[0] = 0b1010101010;
        let mut wb = vec![0.0; q.m];
        spmv_bits_par_into(&q, &bits, &mut wb); // tiny sizes stay serial
        let mut wb_ser = vec![0.0; q.m];
        q.spmv_bits_into(&bits, &mut wb_ser);
        assert_eq!(wb, wb_ser);
    }
}
