//! Sparse influence-matrix substrate: the `Q` of `w = Q·z` (Eq. 1).
//!
//! * [`QMatrix`] — row-gather storage (exactly `d` entries per row:
//!   `rid[m·d]` column ids + `rv[m·d]` values), generated from a
//!   [`SeedTree`] so server and clients materialize bit-identical matrices
//!   from the shared seed without ever sending `Q` (§1.3 Initialization).
//! * [`CscView`] — the transpose in padded-CSC form used by the backward
//!   product `g_s = Qᵀ g_w` and exported to the fused HLO artifact.
//! * `spmv` / `spmv_t` — the two hot-path products, with `_into` variants
//!   that write into caller-owned buffers (allocation-free round loop) and
//!   multi-threaded variants for large `m` (see `par` module).
//!
//! Non-zero values are drawn `N(0, 6/(d·n_ℓ))` where `n_ℓ` is the fan-in
//! of the target neuron of weight `i` — Lemma 2.1 shows this recovers
//! Kaiming-He initialization in expectation over `p ~ U[0,1]`.

mod gen;
mod par;
mod prune;

pub use gen::csc_pad_width;
pub use par::{spmv_bits_par_into, spmv_par_into, spmv_t_par_into};
pub use prune::PrunedModel;

use crate::nn::ArchSpec;
use crate::rng::SeedTree;

/// Row-gather sparse matrix: `m` rows, exactly `d` stored entries per row.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub m: usize,
    pub n: usize,
    pub d: usize,
    /// `[m * d]` column indices, row-major.
    pub rid: Vec<u32>,
    /// `[m * d]` values, row-major.
    pub rv: Vec<f32>,
}

/// Padded-CSC transpose view: `n` columns, padded to width `c`.
/// Padding slots are `(row 0, value 0.0)` and therefore inert.
#[derive(Clone, Debug)]
pub struct CscView {
    pub n: usize,
    pub c: usize,
    /// `[n * c]` row indices, column-major-padded.
    pub cid: Vec<u32>,
    /// `[n * c]` values.
    pub cv: Vec<f32>,
    /// True (unpadded) degree of each column.
    pub degrees: Vec<u32>,
}

impl QMatrix {
    /// Generate `Q` for an architecture per §1.3: for each row `i`, sample
    /// `d` distinct column indices and values `N(0, 6/(d·fan_in(i)))`.
    ///
    /// The rng stream is `seeds.rng("q-matrix", 0)` — every party holding
    /// the root seed reconstructs the same matrix.
    pub fn generate(arch: &ArchSpec, n: usize, d: usize, seeds: &SeedTree) -> Self {
        gen::generate(arch, n, d, seeds)
    }

    /// Number of stored entries (`m·d`).
    pub fn nnz(&self) -> usize {
        self.m * self.d
    }

    /// Row `i`'s (indices, values) pair.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let s = i * self.d;
        (&self.rid[s..s + self.d], &self.rv[s..s + self.d])
    }

    /// `w = Q z` into a fresh vector.
    pub fn spmv(&self, z: &[f32]) -> Vec<f32> {
        let mut w = vec![0.0; self.m];
        self.spmv_into(z, &mut w);
        w
    }

    /// `w = Q z` into `w` (allocation-free hot path).
    pub fn spmv_into(&self, z: &[f32], w: &mut [f32]) {
        assert_eq!(z.len(), self.n);
        assert_eq!(w.len(), self.m);
        self.spmv_rows(z, w, 0);
    }

    /// Row-range core shared by the serial and pool-parallel paths:
    /// fills `w_chunk` with rows `[row0, row0 + w_chunk.len())`.
    pub(crate) fn spmv_rows(&self, z: &[f32], w_chunk: &mut [f32], row0: usize) {
        let d = self.d;
        for (i_local, wi) in w_chunk.iter_mut().enumerate() {
            let i = row0 + i_local;
            let (ids, vals) = (&self.rid[i * d..(i + 1) * d], &self.rv[i * d..(i + 1) * d]);
            let mut acc = 0.0f32;
            for k in 0..d {
                acc += vals[k] * z[ids[k] as usize];
            }
            *wi = acc;
        }
    }

    /// `w = Q z` for a *binary* mask given as a bitset (one bit per entry
    /// of `z`) — the wire format of the federated protocol.
    ///
    /// Branchless: the bit is extracted and used as a 0/1 multiplier so
    /// the inner loop vectorizes like the float path (§Perf: the branchy
    /// version ran at 1.3 GB/s vs 10+ GB/s for this form).
    pub fn spmv_bits_into(&self, bits: &[u64], w: &mut [f32]) {
        assert!(bits.len() * 64 >= self.n);
        assert_eq!(w.len(), self.m);
        self.spmv_bits_rows(bits, w, 0);
    }

    /// Row-range core of [`Self::spmv_bits_into`].
    pub(crate) fn spmv_bits_rows(&self, bits: &[u64], w_chunk: &mut [f32], row0: usize) {
        let d = self.d;
        for (i_local, wi) in w_chunk.iter_mut().enumerate() {
            let i = row0 + i_local;
            let (ids, vals) = (&self.rid[i * d..(i + 1) * d], &self.rv[i * d..(i + 1) * d]);
            // Two accumulators halve the FP dependency chain (§Perf).
            let (mut a0, mut a1) = (0.0f32, 0.0f32);
            let mut k = 0;
            while k + 1 < d {
                let j0 = ids[k] as usize;
                let j1 = ids[k + 1] as usize;
                a0 += vals[k] * (((bits[j0 >> 6] >> (j0 & 63)) & 1) as f32);
                a1 += vals[k + 1] * (((bits[j1 >> 6] >> (j1 & 63)) & 1) as f32);
                k += 2;
            }
            if k < d {
                let j = ids[k] as usize;
                a0 += vals[k] * (((bits[j >> 6] >> (j & 63)) & 1) as f32);
            }
            *wi = a0 + a1;
        }
    }

    /// Build the padded-CSC transpose.  `pad_to` must be ≥ the max column
    /// degree; pass [`csc_pad_width`]`(m, n, d)` to match the shape the
    /// fused HLO artifact was lowered with, or `None` for tight padding.
    pub fn to_csc(&self, pad_to: Option<usize>) -> CscView {
        let mut degrees = vec![0u32; self.n];
        for &j in &self.rid {
            degrees[j as usize] += 1;
        }
        let max_deg = degrees.iter().copied().max().unwrap_or(0) as usize;
        let c = match pad_to {
            Some(c) => {
                assert!(
                    c >= max_deg,
                    "csc pad width {c} < max column degree {max_deg}; regenerate artifact"
                );
                c
            }
            None => max_deg.max(1),
        };
        let mut cid = vec![0u32; self.n * c];
        let mut cv = vec![0.0f32; self.n * c];
        let mut fill = vec![0u32; self.n];
        for i in 0..self.m {
            let (ids, vals) = self.row(i);
            for (k, &j) in ids.iter().enumerate() {
                let j = j as usize;
                let slot = j * c + fill[j] as usize;
                cid[slot] = i as u32;
                cv[slot] = vals[k];
                fill[j] += 1;
            }
        }
        debug_assert_eq!(fill, degrees);
        CscView { n: self.n, c, cid, cv, degrees }
    }

    /// Number of all-zero columns (Lemma 2.3's census).
    pub fn empty_columns(&self) -> usize {
        let mut seen = vec![false; self.n];
        for &j in &self.rid {
            seen[j as usize] = true;
        }
        seen.iter().filter(|&&s| !s).count()
    }

    /// Materialize dense `[m, n]` (tests only — O(m·n) memory).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut q = vec![0.0f32; self.m * self.n];
        for i in 0..self.m {
            let (ids, vals) = self.row(i);
            for (k, &j) in ids.iter().enumerate() {
                q[i * self.n + j as usize] += vals[k];
            }
        }
        q
    }
}

impl CscView {
    /// `g_s = Qᵀ g_w` into a fresh vector.
    pub fn spmv_t(&self, g_w: &[f32]) -> Vec<f32> {
        let mut g_s = vec![0.0; self.n];
        self.spmv_t_into(g_w, &mut g_s);
        g_s
    }

    /// `g_s = Qᵀ g_w` into `g_s` (allocation-free hot path).
    ///
    /// Iterates only the true degree of each column, not the padding.
    pub fn spmv_t_into(&self, g_w: &[f32], g_s: &mut [f32]) {
        assert_eq!(g_s.len(), self.n);
        self.spmv_t_cols(g_w, g_s, 0);
    }

    /// Column-range core shared by the serial and pool-parallel paths:
    /// fills `gs_chunk` with columns `[col0, col0 + gs_chunk.len())`.
    pub(crate) fn spmv_t_cols(&self, g_w: &[f32], gs_chunk: &mut [f32], col0: usize) {
        let c = self.c;
        for (j_local, gj) in gs_chunk.iter_mut().enumerate() {
            let j = col0 + j_local;
            let deg = self.degrees[j] as usize;
            let ids = &self.cid[j * c..j * c + deg];
            let vals = &self.cv[j * c..j * c + deg];
            let mut acc = 0.0f32;
            for k in 0..deg {
                acc += vals[k] * g_w[ids[k] as usize];
            }
            *gj = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn small_q(n: usize, d: usize, seed: u64) -> QMatrix {
        QMatrix::generate(&ArchSpec::small(), n, d, &SeedTree::new(seed))
    }

    #[test]
    fn generate_shape_and_distinct_indices() {
        let q = small_q(1000, 5, 0);
        assert_eq!(q.m, 16_330);
        assert_eq!(q.rid.len(), q.m * 5);
        for i in (0..q.m).step_by(977) {
            let (ids, _) = q.row(i);
            let mut sorted: Vec<u32> = ids.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "row {i} has duplicate columns");
            assert!(sorted.iter().all(|&j| (j as usize) < 1000));
        }
    }

    #[test]
    fn generate_is_deterministic_across_parties() {
        let a = small_q(512, 3, 42);
        let b = small_q(512, 3, 42);
        assert_eq!(a.rid, b.rid);
        assert_eq!(a.rv, b.rv);
        let c = small_q(512, 3, 43);
        assert_ne!(a.rv, c.rv);
    }

    #[test]
    fn value_variance_matches_eq1() {
        // First-layer weights of the small arch have fan_in 784:
        // Var(q) = 6 / (d * 784).
        let d = 8;
        let q = small_q(2048, d, 7);
        let first_layer = 784 * 20;
        let vals = &q.rv[..first_layer * d];
        let mean: f64 = vals.iter().map(|&v| v as f64).sum::<f64>() / vals.len() as f64;
        let var: f64 =
            vals.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / vals.len() as f64;
        let expect = 6.0 / (d as f64 * 784.0);
        assert!((var / expect - 1.0).abs() < 0.05, "var={var} expect={expect}");
        assert!(mean.abs() < 3.0 * (expect / vals.len() as f64).sqrt() + 1e-4);
    }

    #[test]
    fn spmv_matches_dense() {
        let q = small_q(200, 4, 1);
        let mut r = Xoshiro256pp::seed_from(2);
        let z: Vec<f32> = (0..200).map(|_| r.next_f32()).collect();
        let w = q.spmv(&z);
        let dense = q.to_dense();
        for i in (0..q.m).step_by(499) {
            let want: f32 = (0..q.n).map(|j| dense[i * q.n + j] * z[j]).sum();
            assert!((w[i] - want).abs() < 1e-4, "row {i}: {} vs {want}", w[i]);
        }
    }

    #[test]
    fn spmv_bits_matches_float_mask() {
        let q = small_q(300, 6, 3);
        let mut r = Xoshiro256pp::seed_from(4);
        let zb: Vec<bool> = (0..300).map(|_| r.bernoulli(0.4)).collect();
        let zf: Vec<f32> = zb.iter().map(|&b| b as u8 as f32).collect();
        let mut bits = vec![0u64; 300usize.div_ceil(64)];
        for (j, &b) in zb.iter().enumerate() {
            if b {
                bits[j >> 6] |= 1 << (j & 63);
            }
        }
        let w_float = q.spmv(&zf);
        let mut w_bits = vec![0.0; q.m];
        q.spmv_bits_into(&bits, &mut w_bits);
        // The bits kernel uses dual accumulators (different summation
        // order), so equality is up to f32 reassociation.
        for (a, b) in w_float.iter().zip(&w_bits) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn csc_transpose_roundtrip() {
        let q = small_q(128, 4, 5);
        let csc = q.to_csc(None);
        // Σ degrees == nnz, and the adjoint identity <u, Qv> == <Qᵀu, v>.
        assert_eq!(csc.degrees.iter().map(|&x| x as usize).sum::<usize>(), q.nnz());
        let mut r = Xoshiro256pp::seed_from(6);
        let u: Vec<f32> = (0..q.m).map(|_| r.next_f32() - 0.5).collect();
        let v: Vec<f32> = (0..q.n).map(|_| r.next_f32() - 0.5).collect();
        let qv = q.spmv(&v);
        let qtu = csc.spmv_t(&u);
        let lhs: f64 = u.iter().zip(&qv).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = qtu.iter().zip(&v).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }

    #[test]
    fn csc_pad_is_inert() {
        let q = small_q(64, 3, 8);
        let tight = q.to_csc(None);
        let padded = q.to_csc(Some(tight.c + 17));
        let mut r = Xoshiro256pp::seed_from(9);
        let g: Vec<f32> = (0..q.m).map(|_| r.next_f32()).collect();
        assert_eq!(tight.spmv_t(&g), padded.spmv_t(&g));
    }

    #[test]
    #[should_panic(expected = "csc pad width")]
    fn csc_pad_too_small_panics() {
        let q = small_q(8, 4, 10); // tiny n → huge column degrees
        q.to_csc(Some(1));
    }

    #[test]
    fn empty_columns_census_d1_approx_e_inv() {
        // Lemma 2.3: for n = m ≫ d, the empty-column fraction ≈ e^{-d}.
        let arch = ArchSpec::small();
        let m = arch.num_params();
        let q = QMatrix::generate(&arch, m, 1, &SeedTree::new(11));
        let frac = q.empty_columns() as f64 / m as f64;
        let expect = (-1.0f64).exp();
        assert!((frac - expect).abs() < 0.01, "frac={frac} expect={expect}");
    }
}
