//! Generation of the influence matrix `Q` (Eq. 1) and the padded-CSC
//! width formula shared with the AOT compile path.

use super::QMatrix;
use crate::nn::ArchSpec;
use crate::rng::{sample_distinct, Normal, SeedTree};

/// Padded CSC width — the closed-form high-probability bound on the max
/// column degree.  **Must match `python/compile/aot.py::csc_pad_width`**:
/// the fused artifacts are lowered with this width, and `QMatrix::to_csc`
/// asserts the realized degrees fit.
///
/// Column degrees are Binomial(m, d/n) with mean μ = m·d/n; μ + 6√μ + 16
/// rounded up to a multiple of 8 exceeds the max of n such binomials
/// except with negligible probability.
pub fn csc_pad_width(m: usize, n: usize, d: usize) -> usize {
    let mu = m as f64 * d as f64 / n as f64;
    (((mu + 6.0 * mu.sqrt() + 16.0) / 8.0).ceil() as usize) * 8
}

/// Generate `Q` per §1.3: row `i` gets `d` distinct uniform column ids and
/// values `N(0, 6/(d·fan_in(i)))`.
pub fn generate(arch: &ArchSpec, n: usize, d: usize, seeds: &SeedTree) -> QMatrix {
    let m = arch.num_params();
    assert!(n >= 1 && n <= m, "need 1 <= n <= m (n={n}, m={m})");
    assert!(d >= 1 && d <= n, "need 1 <= d <= n (d={d}, n={n})");

    let fan_in = arch.fan_in_table();
    let mut rng = seeds.rng("q-matrix", 0);
    let mut normal = Normal::new();
    let mut rid = Vec::with_capacity(m * d);
    let mut rv = Vec::with_capacity(m * d);
    let mut scratch = Vec::with_capacity(d);

    for i in 0..m {
        sample_distinct(&mut rng, n, d, &mut scratch);
        rid.extend_from_slice(&scratch);
        let sigma = (6.0 / (d as f64 * fan_in[i] as f64)).sqrt();
        for _ in 0..d {
            rv.push((normal.sample(&mut rng) * sigma) as f32);
        }
    }

    QMatrix { m, n, d, rid, rv }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_width_matches_python_examples() {
        // Values printed by `python -m compile.aot` for the shipped
        // artifacts; pinned here so drift between the two implementations
        // is caught by `cargo test` without running python.
        assert_eq!(csc_pad_width(16_330, 2041, 4), 88);
        assert_eq!(csc_pad_width(266_610, 266_610, 10), 48);
        assert_eq!(csc_pad_width(266_610, 33_326, 10), 152);
        assert_eq!(csc_pad_width(266_610, 8_331, 10), 448);
    }

    #[test]
    fn pad_width_bounds_realized_degrees() {
        let arch = ArchSpec::small();
        let m = arch.num_params();
        for (n, d) in [(m / 8, 4), (m / 32, 10), (509, 3)] {
            let q = generate(&arch, n, d, &SeedTree::new(13));
            let csc = q.to_csc(None);
            let max_deg = *csc.degrees.iter().max().unwrap() as usize;
            let pad = csc_pad_width(m, n, d);
            assert!(max_deg <= pad, "n={n} d={d}: max_deg={max_deg} pad={pad}");
        }
    }

    #[test]
    #[should_panic(expected = "need 1 <= d <= n")]
    fn rejects_d_larger_than_n() {
        generate(&ArchSpec::small(), 4, 5, &SeedTree::new(0));
    }
}
