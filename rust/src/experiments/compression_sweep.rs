//! Fig. 3 / Table 2: Local Zampling accuracy vs compression factor m/n,
//! for weight degrees d ∈ {1, 5, 10, 50, 100} and m/n = 2^i.
//!
//! §3.1: SmallArch, 5 seeds, lr 1e-3 Adam, 100 sampled networks at the
//! end → mean ± std of the sampled accuracy.

use super::{eval_samples, load_data, native_exec, scaled, seeds, Scale};
use crate::config::TrainConfig;
use crate::metrics::Summary;
use crate::nn::ArchSpec;
use crate::zampling::train_local;

/// One cell of Table 2.
#[derive(Clone, Debug)]
pub struct Cell {
    pub d: usize,
    pub factor: usize,
    pub mean_sampled_acc: f64,
    pub acc_std: f64,
    pub expected_acc: f64,
    pub seeds: usize,
}

/// The sweep grids.
pub fn d_grid(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Ci => vec![1, 5, 10],
        Scale::Paper => vec![1, 5, 10, 50, 100],
    }
}

pub fn factor_grid(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Ci => vec![1, 4, 16, 32],
        // Table 2 reports up to 32; Fig. 3 sweeps to 2^10.
        Scale::Paper => (0..=10).map(|i| 1usize << i).collect(),
    }
}

/// Run one (d, factor) cell across seeds.
pub fn run_cell(d: usize, factor: usize, scale: Scale) -> Cell {
    let mut acc = Summary::default();
    let mut exp = Summary::default();
    let mut per_seed_stds = Summary::default();
    for seed in seeds(scale) {
        let cfg = scaled(TrainConfig::local(ArchSpec::small(), factor, d, seed), scale);
        // d can exceed n at extreme compression; clamp like the generator
        // requires (paper never hits this: smallest n in Table 2 is m/32).
        let mut cfg = cfg;
        cfg.d = cfg.d.min(cfg.n);
        let (train, test) = load_data(&cfg);
        let mut exec = native_exec(&cfg);
        let out = train_local(&cfg, &mut exec, &train, &test, eval_samples(scale));
        acc.push(out.report.mean_sampled_acc);
        per_seed_stds.push(out.report.sampled_acc_std);
        exp.push(out.report.expected_acc);
    }
    Cell {
        d,
        factor,
        mean_sampled_acc: acc.mean(),
        // Combine across-seed spread with within-seed sampling spread.
        acc_std: (acc.std().powi(2) + per_seed_stds.mean().powi(2)).sqrt(),
        expected_acc: exp.mean(),
        seeds: acc.n,
    }
}

/// Full sweep; rows ordered (d desc, factor asc) like Table 2.
pub fn run(scale: Scale) -> Vec<Cell> {
    let mut cells = Vec::new();
    let mut ds = d_grid(scale);
    ds.sort_unstable_by(|a, b| b.cmp(a));
    for d in ds {
        for factor in factor_grid(scale) {
            cells.push(run_cell(d, factor, scale));
        }
    }
    cells
}

/// Render rows in the Table 2 layout (percent accuracy).
pub fn print_table(cells: &[Cell]) {
    use crate::util::bench::{row, table};
    let factors: Vec<usize> = {
        let mut f: Vec<usize> = cells.iter().map(|c| c.factor).collect();
        f.sort_unstable();
        f.dedup();
        f
    };
    let mut header = vec!["d \\ m/n".to_string()];
    header.extend(factors.iter().map(|f| f.to_string()));
    table("Table 2: mean sampled accuracy (± std)", &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut ds: Vec<usize> = cells.iter().map(|c| c.d).collect();
    ds.sort_unstable_by(|a, b| b.cmp(a));
    ds.dedup();
    for d in ds {
        let mut cells_row = vec![format!("{d}")];
        for &f in &factors {
            if let Some(c) = cells.iter().find(|c| c.d == d && c.factor == f) {
                cells_row.push(format!(
                    "{:.2}±{:.2}",
                    c.mean_sampled_acc * 100.0,
                    c.acc_std * 100.0
                ));
            } else {
                cells_row.push("-".into());
            }
        }
        row(&cells_row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cell_runs_and_orders_sanely() {
        // Ultra-small smoke: factor 1 should beat factor 32 with the same
        // budget (the paper's trade-off, visible even at CI scale).
        let lo = run_cell(5, 1, Scale::Ci);
        let hi = run_cell(5, 32, Scale::Ci);
        assert!(lo.mean_sampled_acc > hi.mean_sampled_acc,
            "compression did not hurt: {} vs {}", lo.mean_sampled_acc, hi.mean_sampled_acc);
        assert!(lo.seeds >= 2);
    }
}
